"""Shared helpers for the benchmark harness. Each ``table*.py`` module is a
standalone script reproducing one paper table/figure; ``run.py`` executes
them as subprocesses (so the dry-run benchmarks can claim their own fake
device count) and aggregates the CSV output."""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")


def emit(name: str, rows: List[Dict], keys: List[str]) -> None:
    """Print a CSV block and persist JSON next to the dry-run artifacts."""
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# {name}")
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    sys.stdout.flush()


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters
