"""Benchmark harness entry: one module per paper table/figure.

Each module runs in its own subprocess because the Table-2 roofline
benchmark needs 512 fake devices while the training benchmarks need the
single real CPU device (jax pins the device count at first init).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run table4_cf  # one
"""
import os
import subprocess
import sys
import time

MODULES = [
    "table1_flops",     # Table 1: params + FLOPs, dense vs E8T2
    "table2_parallel",  # Table 2: parallel-config roofline MFU sweep
    "table3_quality",   # Table 3/§5: upcycled vs dense-CT quality
    "table4_cf",        # Table 4/Fig 2: capacity-factor ablation
    "fig3_router",      # Fig 3: mixtral vs st router
    "kernel_bench",     # Pallas kernels vs XLA refs
    "roofline_report",  # §Roofline table from the dry-run artifacts
]


def main() -> None:
    picked = sys.argv[1:] or MODULES
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + ":" + root
    failures = []
    for mod in picked:
        t0 = time.time()
        print(f"==== benchmarks.{mod} ====", flush=True)
        r = subprocess.run(
            [sys.executable, "-m", f"benchmarks.{mod}"], env=env, cwd=root,
            capture_output=True, text=True,
        )
        print(r.stdout)
        if r.returncode != 0:
            failures.append(mod)
            print(f"FAILED ({r.returncode}):\n{r.stderr[-3000:]}", flush=True)
        print(f"==== {mod} done in {time.time()-t0:.0f}s ====\n", flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
