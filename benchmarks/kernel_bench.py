"""Kernel microbench: Pallas expert_gemm / grouped_gemm / flash_attention vs
their XLA reference paths, forward AND backward, plus the padded-vs-sorted
dropless dispatcher comparison.

On this CPU container the Pallas kernels run in interpret mode (Python), so
kernel wall-times are NOT hardware-representative; we therefore report
(a) XLA-path fwd and fwd+bwd wall time as the throughput baseline,
(b) kernel-vs-ref max error (fwd and grad), and (c) derived activation /
HBM-traffic accounting — the quantities the kernels exist to optimize on
TPU. The quant rows pair bf16 against int8 on both fused-dequant paths
(grouped GEMM and paged attention) and gate the ``bytes_per_row``
reduction at >= 1.8x. The backward rows carry the recompute accounting: the custom_vjp saves
only O(N*D) residuals, so ``residual_bytes`` (measured from the actual VJP
residual pytree) vs ``xla_saved_bytes`` (the (N,F) gate/up/h intermediates
autodiff would keep) is the per-layer activation-memory win, asserted here
so a regression that starts saving an (N, F) residual fails the bench.

Output: CSV on stdout, JSON via benchmarks.common.emit, and a
machine-readable ``BENCH_kernels.json`` at the repo root so the perf
trajectory is tracked across PRs.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.expert_gemm import grouped_gemm_residuals
from repro.kernels.ops import (
    expert_gemm,
    flash_attention,
    grouped_gemm,
    grouped_gemm_xla,
)
from repro.kernels.ref import expert_gemm_ref, flash_attention_ref

ROOT_JSON = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_kernels.json")


def _grad_err(loss_a, loss_b, args):
    ga = jax.grad(loss_a, argnums=tuple(range(len(args))))(*args)
    gb = jax.grad(loss_b, argnums=tuple(range(len(args))))(*args)
    return max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(ga, gb)
    )


def expert_gemm_rows(rng, rows):
    for (E, C, D, F) in [(4, 256, 512, 1024), (8, 128, 256, 768)]:
        xe = jnp.asarray(rng.standard_normal((E, C, D)), jnp.bfloat16) * 0.3
        wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
        wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
        wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.bfloat16) * 0.05
        args = (xe, wg, wu, wd)
        ref = jax.jit(expert_gemm_ref)
        us_fwd = timed(ref, *args) * 1e6
        ref_loss = jax.jit(lambda *a: jnp.sum(jnp.square(expert_gemm_ref(*a))))
        us_bwd = timed(jax.jit(jax.grad(ref_loss, argnums=(0, 1, 2, 3))), *args) * 1e6
        err = float(jnp.max(jnp.abs(
            expert_gemm(*args).astype(jnp.float32) - ref(*args).astype(jnp.float32)
        )))
        saved = 2 * E * C * F * 2 * 2  # gate+up bf16, write+read, bytes
        rows.append({
            "name": f"expert_gemm E{E} C{C} D{D} F{F}",
            "us_fwd_xla_ref": round(us_fwd, 1),
            "us_fwdbwd_xla_ref": round(us_bwd, 1),
            "kernel_max_err": round(err, 5),
            "gemm_rows": E * C,
            "activation_bytes": E * C * (D + F + D) * 2,
            "derived": f"fused epilogue saves {saved/1e6:.1f}MB HBM traffic/layer",
        })


def grouped_gemm_rows(rng, rows):
    """Fwd+bwd on the sorted dropless layout at the llama3-e8t2 routing
    shape, with the recompute residual accounting."""
    E, k, T, D, F = 8, 2, 1024, 256, 512
    N = T * k
    bc = 128
    wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.bfloat16) * 0.05
    gs = jnp.full((E,), N // E, jnp.int32)  # balanced routing
    xs = jnp.asarray(rng.standard_normal((N, D)), jnp.bfloat16) * 0.3
    args = (xs, wg, wu, wd)

    xla_loss = jax.jit(lambda *a: jnp.sum(jnp.square(grouped_gemm_xla(*a, gs))))
    us_fwd = timed(jax.jit(grouped_gemm_xla), *args, gs) * 1e6
    us_bwd = timed(jax.jit(jax.grad(xla_loss, argnums=(0, 1, 2, 3))), *args) * 1e6

    # gradient parity kernel vs XLA (N is already bc-aligned and balanced)
    k_loss = lambda *a: jnp.sum(jnp.square(grouped_gemm(*a, gs, row_block=bc)))
    grad_err = _grad_err(k_loss, lambda *a: xla_loss(*a), args)

    # recompute accounting: measured VJP residuals vs what autodiff keeps
    res = grouped_gemm_residuals(xs, wg, wu, wd, gs, blocks=(bc, 512, 512))
    residual_bytes = sum(int(np.prod(r.shape)) * r.dtype.itemsize for r in res)
    res_shapes = [tuple(r.shape) for r in res]
    assert (N, F) not in res_shapes, (
        f"recompute regression: (N, F) intermediate saved as residual: {res_shapes}"
    )
    xla_saved = 3 * N * F * 2  # gate, up, h in bf16 kept by plain autodiff
    rows.append({
        "name": f"grouped_gemm_bwd e8t2 N{N} D{D} F{F} bc{bc}",
        "us_fwd_xla_ref": round(us_fwd, 1),
        "us_fwdbwd_xla_ref": round(us_bwd, 1),
        "kernel_max_err": round(grad_err, 5),
        "gemm_rows": N,
        "activation_bytes": residual_bytes,
        "derived": (
            f"recompute saves {xla_saved/1e6:.1f}MB residuals/layer "
            f"(O(N*F) -> O(N*D): {residual_bytes/1e6:.1f}MB saved inputs)"
        ),
    })


def dispatcher_comparison(rng, rows):
    """Dropless expert-FFN cost, padded (E, C=T, D) layout vs. the sorted
    dispatcher's flat (T*k, D) layout, at the llama3-e8t2 routing shape
    (E=8, top_k=2; D/F reduced so the XLA baseline runs on CPU)."""
    E, k, T, D, F = 8, 2, 1024, 256, 512
    C = T  # padded dropless worst case: one expert could take every token
    wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.bfloat16) * 0.05

    xe = jnp.asarray(rng.standard_normal((E, C, D)), jnp.bfloat16) * 0.3
    us_pad = timed(jax.jit(expert_gemm_ref), xe, wg, wu, wd) * 1e6

    # balanced routing, as the load-balance loss drives it
    gs = jnp.full((E,), T * k // E, jnp.int32)
    xs = jnp.asarray(rng.standard_normal((T * k, D)), jnp.bfloat16) * 0.3
    us_sort = timed(jax.jit(grouped_gemm_xla), xs, wg, wu, wd, gs) * 1e6

    act_bytes = lambda rows_: rows_ * (D + F + D) * 2  # x in, h, y out (bf16)
    rows.append({
        "name": f"dispatch e8t2 padded-dropless E{E} C{C} D{D} F{F}",
        "us_fwd_xla_ref": round(us_pad, 1),
        "kernel_max_err": 0.0,
        "gemm_rows": E * C,
        "activation_bytes": act_bytes(E * C),
        "derived": f"{E*C} gemm rows, {act_bytes(E*C)/1e6:.1f}MB activations",
    })
    rows.append({
        "name": f"dispatch e8t2 sorted-dropless N{T*k} D{D} F{F}",
        "us_fwd_xla_ref": round(us_sort, 1),
        "kernel_max_err": 0.0,
        "gemm_rows": T * k,
        "activation_bytes": act_bytes(T * k),
        "derived": (
            f"{T*k} gemm rows, {act_bytes(T*k)/1e6:.1f}MB activations "
            f"({E*C/(T*k):.0f}x fewer rows than padded)"
        ),
    })


def quant_rows(rng, rows):
    """bf16 vs int8 streamed-operand bytes on BOTH fused-dequant paths.

    ``bytes_per_row`` counts the stationary operand each kernel streams
    from HBM per compute row — expert weights + per-channel scales per
    grouped-GEMM row, referenced KV pages + per-token scale sidecar per
    decode query. That is the term int8 shrinks (the activation traffic is
    identical across each pair, so including it would only dilute the
    ratio the quantization actually changes). Asserted here: >= 1.8x
    reduction on both paths — the bandwidth claim behind the quant flags."""
    from repro.core.quant import quantize_kv, quantize_weight
    from repro.kernels.ops import (
        grouped_gemm_q8,
        paged_attention,
        paged_attention_q8,
    )
    from repro.kernels.ref import (
        grouped_gemm_q8_ref,
        paged_attention_q8_ref,
        paged_attention_ref,
    )

    # -- grouped GEMM: int8 weights, bf16 activations -------------------------
    E, k, T, D, F = 8, 2, 1024, 256, 512
    N, bc = T * k, 128
    wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.bfloat16) * 0.05
    gs = jnp.full((E,), N // E, jnp.int32)
    xs = jnp.asarray(rng.standard_normal((N, D)), jnp.bfloat16) * 0.3

    (qg, sg), (qu, su), (qd, sd) = map(quantize_weight, (wg, wu, wd))
    qargs = (xs, qg, qu, qd, sg, su, sd)
    us_bf16 = timed(jax.jit(grouped_gemm_xla), xs, wg, wu, wd, gs) * 1e6
    us_q8 = timed(jax.jit(grouped_gemm_q8_ref), *qargs, gs) * 1e6
    err_bf16 = float(jnp.max(jnp.abs(
        grouped_gemm(xs, wg, wu, wd, gs, row_block=bc).astype(jnp.float32)
        - grouped_gemm_xla(xs, wg, wu, wd, gs).astype(jnp.float32))))
    err_q8 = float(jnp.max(jnp.abs(
        grouped_gemm_q8(*qargs, gs, row_block=bc).astype(jnp.float32)
        - grouped_gemm_q8_ref(*qargs, gs).astype(jnp.float32))))
    quant_err = float(jnp.max(jnp.abs(
        grouped_gemm_q8_ref(*qargs, gs).astype(jnp.float32)
        - grouped_gemm_xla(xs, wg, wu, wd, gs).astype(jnp.float32))))
    bpr_bf16 = E * 3 * D * F * 2 / N
    bpr_q8 = E * (3 * D * F * 1 + (2 * F + D) * 2) / N  # int8 + bf16 scales
    gemm_ratio = bpr_bf16 / bpr_q8
    for tag, us, err, bpr, extra in (
        ("bf16", us_bf16, err_bf16, bpr_bf16, "weight traffic baseline"),
        ("int8", us_q8, err_q8, bpr_q8,
         f"{gemm_ratio:.2f}x fewer weight bytes/row; "
         f"quant err {quant_err:.3f} vs bf16"),
    ):
        rows.append({
            "name": f"grouped_gemm_{tag} e8t2 N{N} D{D} F{F}",
            "us_fwd_xla_ref": round(us, 1),
            "kernel_max_err": round(err, 5),
            "gemm_rows": N,
            "activation_bytes": N * (D + F + D) * 2,
            "bytes_per_row": round(bpr, 1),
            "derived": extra,
        })
    assert gemm_ratio >= 1.8, (
        f"int8 grouped-GEMM weight bytes/row only {gemm_ratio:.2f}x smaller "
        f"(need >= 1.8x)"
    )

    # -- paged attention: int8 KV pages + f32 scale sidecar -------------------
    P, ps, B, H, KV, d = 32, 8, 4, 8, 2, 64
    maxP = 6
    kp = jnp.asarray(rng.standard_normal((P, ps, KV, d)), jnp.bfloat16) * 0.3
    vp = jnp.asarray(rng.standard_normal((P, ps, KV, d)), jnp.bfloat16) * 0.3
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.bfloat16) * 0.3
    bt = jnp.asarray(
        rng.permutation(P)[: B * maxP].reshape(B, maxP), jnp.int32
    )
    sl = jnp.asarray(rng.integers(ps, maxP * ps, B), jnp.int32)
    kq, ks = quantize_kv(kp)
    vq, vs = quantize_kv(vp)

    us_pa_bf16 = timed(jax.jit(paged_attention_ref), q, kp, vp, bt, sl) * 1e6
    us_pa_q8 = timed(jax.jit(paged_attention_q8_ref),
                     q, kq, vq, ks, vs, bt, sl) * 1e6
    err_pa_bf16 = float(jnp.max(jnp.abs(
        paged_attention(q, kp, vp, bt, sl).astype(jnp.float32)
        - paged_attention_ref(q, kp, vp, bt, sl).astype(jnp.float32))))
    err_pa_q8 = float(jnp.max(jnp.abs(
        paged_attention_q8(q, kq, vq, ks, vs, bt, sl).astype(jnp.float32)
        - paged_attention_q8_ref(q, kq, vq, ks, vs, bt, sl).astype(jnp.float32))))
    pa_quant_err = float(jnp.max(jnp.abs(
        paged_attention_q8_ref(q, kq, vq, ks, vs, bt, sl).astype(jnp.float32)
        - paged_attention_ref(q, kp, vp, bt, sl).astype(jnp.float32))))
    # per decode query: k+v entries of every referenced page (token x head)
    pa_bpr_bf16 = maxP * ps * KV * 2 * (d * 2)
    pa_bpr_q8 = maxP * ps * KV * 2 * (d * 1 + 4)  # int8 + f32 scale
    pa_ratio = pa_bpr_bf16 / pa_bpr_q8
    for tag, us, err, bpr, extra in (
        ("bf16", us_pa_bf16, err_pa_bf16, pa_bpr_bf16, "KV traffic baseline"),
        ("int8", us_pa_q8, err_pa_q8, pa_bpr_q8,
         f"{pa_ratio:.2f}x fewer KV bytes/query; "
         f"quant err {pa_quant_err:.3f} vs bf16"),
    ):
        rows.append({
            "name": f"paged_attn_{tag} P{P} ps{ps} B{B} H{H} KV{KV} d{d}",
            "us_fwd_xla_ref": round(us, 1),
            "kernel_max_err": round(err, 5),
            "gemm_rows": B * H,
            "activation_bytes": B * H * d * 2,
            "bytes_per_row": round(bpr, 1),
            "derived": extra,
        })
    assert pa_ratio >= 1.8, (
        f"int8 KV bytes/query only {pa_ratio:.2f}x smaller (need >= 1.8x)"
    )


def _sorted_routing(rng, E, k, T, bc):
    """Sorted-dispatcher index vectors for random top-k routing (distinct
    experts per token), mirroring SortedDispatcher._indices at row_block=bc."""
    N = T * k
    idx = np.stack([rng.permutation(E)[:k] for _ in range(T)])
    flat_e = jnp.asarray(idx.reshape(N).astype(np.int32))
    gates = jnp.asarray(rng.uniform(0.2, 1.0, size=(N,)).astype(np.float32))
    order = jnp.argsort(flat_e, stable=True)
    token = (order // k).astype(jnp.int32)
    slot = (order % k).astype(jnp.int32)
    sorted_e = flat_e[order]
    gs = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    padded = ((gs + bc - 1) // bc) * bc
    starts_pad = jnp.cumsum(padded) - padded
    starts = jnp.cumsum(gs) - gs
    pos = jnp.arange(N, dtype=jnp.int32) - starts[sorted_e]
    dest = (starts_pad[sorted_e] + pos).astype(jnp.int32)
    return token, slot, dest, gates[order], gs


def fused_dispatch_section(rng):
    """Dispatch-in-kernel vs materializing dispatch.

    Parity is measured by running the fused Pallas kernel against the
    unfused composition (scatter -> grouped GEMM -> fp32 gather/combine) on
    a routed batch; the HBM dispatch-buffer accounting is analytic at the
    llama3-e8t2 nominal shape and counts only the buffers the fusion
    removes: the permuted (N_pad, D) input and the (N_pad, D) expert
    output, each written once and read once in bf16, vs the fused path's
    (k*T+1, D) bf16 slot partials plus the int32/f32 scalar-prefetch
    vectors. Asserted: fused traffic strictly below unfused."""
    from repro.kernels.expert_gemm import _aligned_rows, _fused_unfused_ref
    from repro.kernels.ops import grouped_gemm_fused

    E, k, D, F, bc = 8, 2, 256, 512, 128
    T = 64  # parity shape kept small: interpret-mode grid is nt*nf*nd*bc
    token, slot, dest, gate_sorted, gs = _sorted_routing(rng, E, k, T, bc)
    wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.bfloat16) * 0.05
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.bfloat16) * 0.3
    y_fused = grouped_gemm_fused(
        x, wg, wu, wd, gs, token, dest, slot, gate_sorted, row_block=bc
    )
    y_ref = _fused_unfused_ref(
        x, wg, wu, wd, gs, token, dest, slot, gate_sorted, (bc, 512, 256), True
    )
    err = float(jnp.max(jnp.abs(
        y_fused.astype(jnp.float32) - y_ref.astype(jnp.float32)
    )))

    # traffic accounting at the nominal serving shape (balanced routing)
    Tn = 1024
    Nn = Tn * k
    Nn_pad = _aligned_rows(Nn, E, bc)
    # unfused: xs scatter-write + kernel read, ys kernel-write + gather read
    unfused_bytes = 2 * 2 * Nn_pad * D * 2
    # fused: slot partials (k*T+1, D) bf16 write + read, plus the
    # tok_pad/row_out (int32) and gate_pad (f32) prefetch vectors
    fused_bytes = 2 * (k * Tn + 1) * D * 2 * 2 + 2 * 3 * Nn_pad * 4
    assert fused_bytes < unfused_bytes, (
        f"fused dispatch traffic {fused_bytes} not below unfused "
        f"{unfused_bytes}"
    )
    section = {
        "name": f"fused_dispatch e8t2 N{Nn} D{D} bc{bc}",
        "parity_err": round(err, 5),
        "dispatch_bytes_unfused": unfused_bytes,
        "dispatch_bytes_fused": fused_bytes,
        "traffic_ratio": round(unfused_bytes / fused_bytes, 2),
        "fused_strictly_lower": fused_bytes < unfused_bytes,
    }
    print(f"# fused_dispatch: {section['traffic_ratio']:.2f}x less "
          f"dispatch-buffer HBM traffic, parity err {err:.5f}")
    return section


def autotune_section():
    """Autotuner evidence: tuned-vs-heuristic modeled kernel time on the
    grouped-GEMM traffic model, plus cache determinism (the second resolve
    must be a pure memo hit). Runs against a throwaway cache dir so the
    bench neither touches nor depends on the user's persisted winners."""
    import shutil
    import tempfile

    from repro.kernels import autotune as at
    from repro.kernels import expert_gemm as eg
    from repro.kernels.ops import _gg_cost, _tuned_ffn_blocks

    E, D, F, bc = 8, 256, 512, 128
    saved = {kk: os.environ.get(kk) for kk in
             ("REPRO_AUTOTUNE", "REPRO_AUTOTUNE_CACHE", "REPRO_HW_PROFILE")}
    tmpdir = tempfile.mkdtemp(prefix="repro_bench_tune_")
    os.environ["REPRO_AUTOTUNE"] = "1"
    os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(tmpdir, "cache.json")
    os.environ["REPRO_HW_PROFILE"] = "v5e"
    at.reset()
    try:
        fallback = tuple(
            eg._pick(b, d) for b, d in zip(eg.DEFAULT_BLOCKS[1:], (F, D))
        )
        cost = _gg_cost(E, D, F, bc, 2)
        # first resolve: served by the committed autotune_defaults.json (a
        # disk hit) or a fresh modeled search; second: pure memo hit
        _, bf, bd = _tuned_ffn_blocks("grouped_gemm", E, D, F, bc, 2)
        misses = at.stats()["misses"]
        _, bf2, bd2 = _tuned_ffn_blocks("grouped_gemm", E, D, F, bc, 2)
        hits = at.stats()["hits"]
        assert (bf2, bd2) == (bf, bd), "autotune cache not deterministic"
        c_fb, c_tu = cost(fallback), cost((bf, bd))
        us_fb = at.modeled_seconds(
            c_fb["flops"], c_fb["bytes"], c_fb["steps"]) * 1e6
        us_tu = at.modeled_seconds(
            c_tu["flops"], c_tu["bytes"], c_tu["steps"]) * 1e6
        assert us_tu <= us_fb + 1e-9, (
            f"tuned blocks modeled slower than heuristic: {us_tu} > {us_fb}"
        )
        section = {
            "name": f"autotune grouped_gemm e8 D{D} F{F} bc{bc}",
            "fallback_blocks": list(fallback),
            "tuned_blocks": [int(bf), int(bd)],
            "modeled_us_fallback": round(us_fb, 2),
            "modeled_us_tuned": round(us_tu, 2),
            "cache_misses": int(misses),
            "cache_hits": int(hits),
            "tuned_no_worse": bool(us_tu <= us_fb + 1e-9),
        }
        print(f"# autotune: {list(fallback)} -> {section['tuned_blocks']} "
              f"modeled {us_fb:.1f}us -> {us_tu:.1f}us, "
              f"{hits} cache hit(s) / {misses} miss(es) across resolves")
        return section
    finally:
        for kk, vv in saved.items():
            if vv is None:
                os.environ.pop(kk, None)
            else:
                os.environ[kk] = vv
        at.reset()
        shutil.rmtree(tmpdir, ignore_errors=True)


def flash_rows(rng, rows):
    for (B, S, H, KV, d) in [(2, 1024, 8, 2, 128), (1, 2048, 4, 4, 64)]:
        q = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.bfloat16) * 0.3
        k = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.bfloat16) * 0.3
        v = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.bfloat16) * 0.3
        kb, vb = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)
        ref = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
        ref_loss = jax.jit(
            lambda q, k, v: jnp.sum(jnp.square(flash_attention_ref(q, k, v, causal=True)))
        )
        us_fwd = timed(ref, q, kb, vb) * 1e6
        us_bwd = timed(jax.jit(jax.grad(ref_loss, argnums=(0, 1, 2))), q, kb, vb) * 1e6
        y = flash_attention(q, k, v, causal=True)
        err = float(jnp.max(jnp.abs(
            y.astype(jnp.float32) - ref(q, kb, vb).astype(jnp.float32)
        )))
        hbm_scores = B * H * S * S * 4 / 1e6
        # bwd residuals: q,k,v,out (bf16) + lse (f32); autodiff of the dense
        # ref would also keep the (B,H,S,S) probability matrix
        lse_bytes = B * H * S * 4
        rows.append({
            "name": f"flash_attn B{B} S{S} H{H} KV{KV} d{d}",
            "us_fwd_xla_ref": round(us_fwd, 1),
            "us_fwdbwd_xla_ref": round(us_bwd, 1),
            "kernel_max_err": round(err, 5),
            "gemm_rows": B * H * S,
            "activation_bytes": lse_bytes,
            "derived": (
                f"avoids {hbm_scores:.0f}MB fp32 score materialization "
                f"fwd+bwd; lse residual {lse_bytes/1e3:.0f}KB"
            ),
        })


def main():
    rng = np.random.default_rng(0)
    rows = []
    expert_gemm_rows(rng, rows)
    grouped_gemm_rows(rng, rows)
    dispatcher_comparison(rng, rows)
    quant_rows(rng, rows)
    flash_rows(rng, rows)
    fused = fused_dispatch_section(rng)
    tune = autotune_section()
    keys = ["name", "us_fwd_xla_ref", "us_fwdbwd_xla_ref", "kernel_max_err",
            "gemm_rows", "activation_bytes", "bytes_per_row", "derived"]
    emit("kernel_bench", rows, keys)
    with open(ROOT_JSON, "w") as f:
        json.dump({"schema": keys, "rows": rows,
                   "fused_dispatch": fused, "autotune": tune}, f, indent=1)
    print(f"# wrote {ROOT_JSON}")


if __name__ == "__main__":
    main()
