"""Kernel microbench: Pallas expert_gemm / grouped_gemm / flash_attention vs
their XLA reference paths, forward AND backward, plus the padded-vs-sorted
dropless dispatcher comparison.

On this CPU container the Pallas kernels run in interpret mode (Python), so
kernel wall-times are NOT hardware-representative; we therefore report
(a) XLA-path fwd and fwd+bwd wall time as the throughput baseline,
(b) kernel-vs-ref max error (fwd and grad), and (c) derived activation /
HBM-traffic accounting — the quantities the kernels exist to optimize on
TPU. The backward rows carry the recompute accounting: the custom_vjp saves
only O(N*D) residuals, so ``residual_bytes`` (measured from the actual VJP
residual pytree) vs ``xla_saved_bytes`` (the (N,F) gate/up/h intermediates
autodiff would keep) is the per-layer activation-memory win, asserted here
so a regression that starts saving an (N, F) residual fails the bench.

Output: CSV on stdout, JSON via benchmarks.common.emit, and a
machine-readable ``BENCH_kernels.json`` at the repo root so the perf
trajectory is tracked across PRs.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.expert_gemm import grouped_gemm_residuals
from repro.kernels.ops import (
    expert_gemm,
    flash_attention,
    grouped_gemm,
    grouped_gemm_xla,
)
from repro.kernels.ref import expert_gemm_ref, flash_attention_ref

ROOT_JSON = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_kernels.json")


def _grad_err(loss_a, loss_b, args):
    ga = jax.grad(loss_a, argnums=tuple(range(len(args))))(*args)
    gb = jax.grad(loss_b, argnums=tuple(range(len(args))))(*args)
    return max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(ga, gb)
    )


def expert_gemm_rows(rng, rows):
    for (E, C, D, F) in [(4, 256, 512, 1024), (8, 128, 256, 768)]:
        xe = jnp.asarray(rng.standard_normal((E, C, D)), jnp.bfloat16) * 0.3
        wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
        wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
        wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.bfloat16) * 0.05
        args = (xe, wg, wu, wd)
        ref = jax.jit(expert_gemm_ref)
        us_fwd = timed(ref, *args) * 1e6
        ref_loss = jax.jit(lambda *a: jnp.sum(jnp.square(expert_gemm_ref(*a))))
        us_bwd = timed(jax.jit(jax.grad(ref_loss, argnums=(0, 1, 2, 3))), *args) * 1e6
        err = float(jnp.max(jnp.abs(
            expert_gemm(*args).astype(jnp.float32) - ref(*args).astype(jnp.float32)
        )))
        saved = 2 * E * C * F * 2 * 2  # gate+up bf16, write+read, bytes
        rows.append({
            "name": f"expert_gemm E{E} C{C} D{D} F{F}",
            "us_fwd_xla_ref": round(us_fwd, 1),
            "us_fwdbwd_xla_ref": round(us_bwd, 1),
            "kernel_max_err": round(err, 5),
            "gemm_rows": E * C,
            "activation_bytes": E * C * (D + F + D) * 2,
            "derived": f"fused epilogue saves {saved/1e6:.1f}MB HBM traffic/layer",
        })


def grouped_gemm_rows(rng, rows):
    """Fwd+bwd on the sorted dropless layout at the llama3-e8t2 routing
    shape, with the recompute residual accounting."""
    E, k, T, D, F = 8, 2, 1024, 256, 512
    N = T * k
    bc = 128
    wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.bfloat16) * 0.05
    gs = jnp.full((E,), N // E, jnp.int32)  # balanced routing
    xs = jnp.asarray(rng.standard_normal((N, D)), jnp.bfloat16) * 0.3
    args = (xs, wg, wu, wd)

    xla_loss = jax.jit(lambda *a: jnp.sum(jnp.square(grouped_gemm_xla(*a, gs))))
    us_fwd = timed(jax.jit(grouped_gemm_xla), *args, gs) * 1e6
    us_bwd = timed(jax.jit(jax.grad(xla_loss, argnums=(0, 1, 2, 3))), *args) * 1e6

    # gradient parity kernel vs XLA (N is already bc-aligned and balanced)
    k_loss = lambda *a: jnp.sum(jnp.square(grouped_gemm(*a, gs, row_block=bc)))
    grad_err = _grad_err(k_loss, lambda *a: xla_loss(*a), args)

    # recompute accounting: measured VJP residuals vs what autodiff keeps
    res = grouped_gemm_residuals(xs, wg, wu, wd, gs, blocks=(bc, 512, 512))
    residual_bytes = sum(int(np.prod(r.shape)) * r.dtype.itemsize for r in res)
    res_shapes = [tuple(r.shape) for r in res]
    assert (N, F) not in res_shapes, (
        f"recompute regression: (N, F) intermediate saved as residual: {res_shapes}"
    )
    xla_saved = 3 * N * F * 2  # gate, up, h in bf16 kept by plain autodiff
    rows.append({
        "name": f"grouped_gemm_bwd e8t2 N{N} D{D} F{F} bc{bc}",
        "us_fwd_xla_ref": round(us_fwd, 1),
        "us_fwdbwd_xla_ref": round(us_bwd, 1),
        "kernel_max_err": round(grad_err, 5),
        "gemm_rows": N,
        "activation_bytes": residual_bytes,
        "derived": (
            f"recompute saves {xla_saved/1e6:.1f}MB residuals/layer "
            f"(O(N*F) -> O(N*D): {residual_bytes/1e6:.1f}MB saved inputs)"
        ),
    })


def dispatcher_comparison(rng, rows):
    """Dropless expert-FFN cost, padded (E, C=T, D) layout vs. the sorted
    dispatcher's flat (T*k, D) layout, at the llama3-e8t2 routing shape
    (E=8, top_k=2; D/F reduced so the XLA baseline runs on CPU)."""
    E, k, T, D, F = 8, 2, 1024, 256, 512
    C = T  # padded dropless worst case: one expert could take every token
    wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.bfloat16) * 0.05

    xe = jnp.asarray(rng.standard_normal((E, C, D)), jnp.bfloat16) * 0.3
    us_pad = timed(jax.jit(expert_gemm_ref), xe, wg, wu, wd) * 1e6

    # balanced routing, as the load-balance loss drives it
    gs = jnp.full((E,), T * k // E, jnp.int32)
    xs = jnp.asarray(rng.standard_normal((T * k, D)), jnp.bfloat16) * 0.3
    us_sort = timed(jax.jit(grouped_gemm_xla), xs, wg, wu, wd, gs) * 1e6

    act_bytes = lambda rows_: rows_ * (D + F + D) * 2  # x in, h, y out (bf16)
    rows.append({
        "name": f"dispatch e8t2 padded-dropless E{E} C{C} D{D} F{F}",
        "us_fwd_xla_ref": round(us_pad, 1),
        "kernel_max_err": 0.0,
        "gemm_rows": E * C,
        "activation_bytes": act_bytes(E * C),
        "derived": f"{E*C} gemm rows, {act_bytes(E*C)/1e6:.1f}MB activations",
    })
    rows.append({
        "name": f"dispatch e8t2 sorted-dropless N{T*k} D{D} F{F}",
        "us_fwd_xla_ref": round(us_sort, 1),
        "kernel_max_err": 0.0,
        "gemm_rows": T * k,
        "activation_bytes": act_bytes(T * k),
        "derived": (
            f"{T*k} gemm rows, {act_bytes(T*k)/1e6:.1f}MB activations "
            f"({E*C/(T*k):.0f}x fewer rows than padded)"
        ),
    })


def flash_rows(rng, rows):
    for (B, S, H, KV, d) in [(2, 1024, 8, 2, 128), (1, 2048, 4, 4, 64)]:
        q = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.bfloat16) * 0.3
        k = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.bfloat16) * 0.3
        v = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.bfloat16) * 0.3
        kb, vb = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)
        ref = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
        ref_loss = jax.jit(
            lambda q, k, v: jnp.sum(jnp.square(flash_attention_ref(q, k, v, causal=True)))
        )
        us_fwd = timed(ref, q, kb, vb) * 1e6
        us_bwd = timed(jax.jit(jax.grad(ref_loss, argnums=(0, 1, 2))), q, kb, vb) * 1e6
        y = flash_attention(q, k, v, causal=True)
        err = float(jnp.max(jnp.abs(
            y.astype(jnp.float32) - ref(q, kb, vb).astype(jnp.float32)
        )))
        hbm_scores = B * H * S * S * 4 / 1e6
        # bwd residuals: q,k,v,out (bf16) + lse (f32); autodiff of the dense
        # ref would also keep the (B,H,S,S) probability matrix
        lse_bytes = B * H * S * 4
        rows.append({
            "name": f"flash_attn B{B} S{S} H{H} KV{KV} d{d}",
            "us_fwd_xla_ref": round(us_fwd, 1),
            "us_fwdbwd_xla_ref": round(us_bwd, 1),
            "kernel_max_err": round(err, 5),
            "gemm_rows": B * H * S,
            "activation_bytes": lse_bytes,
            "derived": (
                f"avoids {hbm_scores:.0f}MB fp32 score materialization "
                f"fwd+bwd; lse residual {lse_bytes/1e3:.0f}KB"
            ),
        })


def main():
    rng = np.random.default_rng(0)
    rows = []
    expert_gemm_rows(rng, rows)
    grouped_gemm_rows(rng, rows)
    dispatcher_comparison(rng, rows)
    flash_rows(rng, rows)
    keys = ["name", "us_fwd_xla_ref", "us_fwdbwd_xla_ref", "kernel_max_err",
            "gemm_rows", "activation_bytes", "derived"]
    emit("kernel_bench", rows, keys)
    with open(ROOT_JSON, "w") as f:
        json.dump({"schema": keys, "rows": rows}, f, indent=1)
    print(f"# wrote {ROOT_JSON}")


if __name__ == "__main__":
    main()
