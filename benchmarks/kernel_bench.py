"""Kernel microbench: Pallas expert_gemm / flash_attention vs their XLA
reference paths, plus the padded-vs-sorted dropless dispatcher comparison.
On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-times are NOT hardware-representative; we therefore report (a) XLA-path
wall time as the throughput baseline, (b) kernel-vs-ref max error, and (c)
derived HBM-traffic savings of the fused SwiGLU epilogue (the quantity the
kernel exists to optimize on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.ops import expert_gemm, flash_attention, grouped_gemm_xla
from repro.kernels.ref import expert_gemm_ref, flash_attention_ref


def dispatcher_comparison(rng, rows):
    """Dropless expert-FFN cost, padded (E, C=T, D) layout vs. the sorted
    dispatcher's flat (T*k, D) layout, at the llama3-e8t2 routing shape
    (E=8, top_k=2; D/F reduced so the XLA baseline runs on CPU)."""
    E, k, T, D, F = 8, 2, 1024, 256, 512
    C = T  # padded dropless worst case: one expert could take every token
    wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.bfloat16) * 0.05

    xe = jnp.asarray(rng.standard_normal((E, C, D)), jnp.bfloat16) * 0.3
    us_pad = timed(jax.jit(expert_gemm_ref), xe, wg, wu, wd) * 1e6

    # balanced routing, as the load-balance loss drives it
    gs = jnp.full((E,), T * k // E, jnp.int32)
    xs = jnp.asarray(rng.standard_normal((T * k, D)), jnp.bfloat16) * 0.3
    us_sort = timed(jax.jit(grouped_gemm_xla), xs, wg, wu, wd, gs) * 1e6

    act_bytes = lambda rows_: rows_ * (D + F + D) * 2  # x in, h, y out (bf16)
    rows.append({
        "name": f"dispatch e8t2 padded-dropless E{E} C{C} D{D} F{F}",
        "us_per_call_xla_ref": round(us_pad, 1),
        "kernel_max_err": 0.0,
        "derived": f"{E*C} gemm rows, {act_bytes(E*C)/1e6:.1f}MB activations",
    })
    rows.append({
        "name": f"dispatch e8t2 sorted-dropless N{T*k} D{D} F{F}",
        "us_per_call_xla_ref": round(us_sort, 1),
        "kernel_max_err": 0.0,
        "derived": (
            f"{T*k} gemm rows, {act_bytes(T*k)/1e6:.1f}MB activations "
            f"({E*C/(T*k):.0f}x fewer rows than padded)"
        ),
    })


def main():
    rng = np.random.default_rng(0)
    rows = []
    for (E, C, D, F) in [(4, 256, 512, 1024), (8, 128, 256, 768)]:
        xe = jnp.asarray(rng.standard_normal((E, C, D)), jnp.bfloat16) * 0.3
        wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
        wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
        wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.bfloat16) * 0.05
        ref = jax.jit(expert_gemm_ref)
        us = timed(ref, xe, wg, wu, wd) * 1e6
        y = expert_gemm(xe, wg, wu, wd)
        err = float(jnp.max(jnp.abs(y.astype(jnp.float32) - ref(xe, wg, wu, wd).astype(jnp.float32))))
        saved = 2 * E * C * F * 2 * 2  # gate+up bf16, write+read, bytes
        rows.append({
            "name": f"expert_gemm E{E} C{C} D{D} F{F}",
            "us_per_call_xla_ref": round(us, 1),
            "kernel_max_err": round(err, 5),
            "derived": f"fused epilogue saves {saved/1e6:.1f}MB HBM traffic/layer",
        })
    dispatcher_comparison(rng, rows)
    for (B, S, H, KV, d) in [(2, 1024, 8, 2, 128), (1, 2048, 4, 4, 64)]:
        q = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.bfloat16) * 0.3
        k = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.bfloat16) * 0.3
        v = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.bfloat16) * 0.3
        kb, vb = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)
        ref = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
        us = timed(ref, q, kb, vb) * 1e6
        y = flash_attention(q, k, v, causal=True)
        err = float(jnp.max(jnp.abs(y.astype(jnp.float32) - ref(q, kb, vb).astype(jnp.float32))))
        hbm_scores = B * H * S * S * 4 / 1e6
        rows.append({
            "name": f"flash_attn B{B} S{S} H{H} KV{KV} d{d}",
            "us_per_call_xla_ref": round(us, 1),
            "kernel_max_err": round(err, 5),
            "derived": f"avoids {hbm_scores:.0f}MB fp32 score materialization",
        })
    emit("kernel_bench", rows, list(rows[0]))


if __name__ == "__main__":
    main()
