"""Paper Table 3 + §5 analog: upcycled MoE vs dense continued training.

The paper trains Llama3-8B -> E8T2 on 100B tokens and reports MMLU et al.
At container scale (1 CPU core) we reproduce the *relative* claim on the
synthetic 7:3 blend: starting from the same trained dense checkpoint and an
equal extra token budget, the upcycled E4T2 MoE (a) starts at the SAME loss
(upcycling warm start) and (b) ends at-or-below the dense continued-training
loss (the capacity win)."""
import jax

from benchmarks.common import emit
from benchmarks.pretrain_cache import CT_STEPS, base_cfg, data, get_pretrained, tcfg
from repro.config import MoEConfig
from repro.core.upcycle import upcycle_config, upcycle_params
from repro.train.trainer import Trainer


def main():
    cfg, params = get_pretrained()
    base = Trainer(cfg, tcfg(1), params=params, data_iter=None)
    rows = [{"model": "dense base (pre-trained)", "extra_steps": 0,
             "heldout_ce": round(base.eval_loss(6), 4), "start_ce": ""}]

    ct = Trainer(cfg, tcfg(CT_STEPS), params=params, data_iter=data(200))
    ct.run(CT_STEPS, log=lambda *_: None)
    ct_start = ct.history[0]["ce"]
    ct_eval = ct.eval_loss(6)
    rows.append({"model": "dense CT", "extra_steps": CT_STEPS,
                 "heldout_ce": round(ct_eval, 4), "start_ce": round(ct_start, 4)})

    moe_cfg = upcycle_config(cfg, MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0))
    moe_params = upcycle_params(cfg, moe_cfg, params, jax.random.PRNGKey(5))
    moe = Trainer(moe_cfg, tcfg(CT_STEPS), params=moe_params, data_iter=data(200))
    moe.run(CT_STEPS, log=lambda *_: None)
    moe_start = moe.history[0]["ce"]
    moe_eval = moe.eval_loss(6)
    rows.append({"model": "upcycled E4T2", "extra_steps": CT_STEPS,
                 "heldout_ce": round(moe_eval, 4), "start_ce": round(moe_start, 4)})
    rows.append({"model": "MoE advantage (dense CT - MoE)", "extra_steps": "",
                 "heldout_ce": round(ct_eval - moe_eval, 4),
                 "start_ce": round(abs(moe_start - ct_start), 4)})
    emit("table3_quality", rows, ["model", "extra_steps", "heldout_ce", "start_ce"])
    assert abs(moe_start - ct_start) < 0.15, (moe_start, ct_start)  # warm start


if __name__ == "__main__":
    main()
