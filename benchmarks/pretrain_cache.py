"""Shared pre-trained dense checkpoint for the training benchmarks
(table3/table4/fig3 all upcycle the SAME dense model, like the paper's
experiments all start from the same Llama 3-8B checkpoint). Sized for the
single-CPU-core container."""
import os

import jax

from benchmarks.common import OUT_DIR
from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.config import ModelConfig, TrainConfig
from repro.data.pipeline import make_train_iter
from repro.train.trainer import Trainer

BASE_STEPS = 350
CT_STEPS = 120
DATA_SEED = 11  # one synthetic "language" for every benchmark phase
CKPT = os.path.join(OUT_DIR, "dense_base_ckpt")


def base_cfg() -> ModelConfig:
    return ModelConfig(
        name="bench-dense", family="dense", num_layers=2, d_model=192,
        num_heads=6, num_kv_heads=2, d_ff=768, vocab_size=2048,
        vocab_divisor=256, rope_theta=10000.0, remat="none",
    )


def tcfg(steps: int) -> TrainConfig:
    return TrainConfig(global_batch=8, seq_len=128, lr=1.5e-3, lr_min=1.5e-4,
                       warmup_steps=20, total_steps=steps, log_every=20,
                       seed=DATA_SEED)


def data(sample_seed: int):
    """Fresh sampling stream of the SAME language."""
    c = base_cfg()
    t = tcfg(1)
    return make_train_iter(c.vocab_size, t.seq_len, t.global_batch,
                           seed=DATA_SEED, sample_seed=sample_seed)


def get_pretrained():
    """Returns (cfg, params) — trains once, then loads from cache."""
    cfg = base_cfg()
    if os.path.exists(os.path.join(CKPT, "manifest.json")):
        return cfg, load_checkpoint(CKPT)
    tr = Trainer(cfg, tcfg(BASE_STEPS), data_iter=data(100))
    tr.run(BASE_STEPS, log=lambda *_: None)
    save_checkpoint(CKPT, tr.params, step=BASE_STEPS)
    return cfg, tr.params


def eval_ce(cfg, params, batches: int = 6, seed: int = 999) -> float:
    import jax
    import jax.numpy as jnp

    from repro.train.state import TrainState

    tr = Trainer.__new__(Trainer)  # eval-only shell
    tr.cfg, tr.tcfg, tr.plan = cfg, tcfg(1), None
    tr.state = TrainState(jnp.zeros((), jnp.int32), params, None,
                          jax.random.PRNGKey(0))
    return tr.eval_loss(batches=batches, seed=seed)
