"""Paper Table 1: total/active params and forward FLOPs, Llama3-8B vs E8T2.

Analytic counts from the config system plus *compiled* FLOPs from
``cost_analysis()`` on a reduced-depth forward (depth scales linearly, so we
extrapolate layer-proportionally — the full 32L model does not fit a single
CPU host). Validates the paper's headline ratios: ~1.6x FLOPs for ~4-6x
params (our strict counting gives 5.9x/1.70x vs the paper's 4.3x/1.6x; the
paper's totals are not reproducible from its stated dims — see
EXPERIMENTS.md note)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.config import get_config
from repro.models.model import loss_fn, model_decl
from repro.sharding.rules import abstract_from_decls


def compiled_forward_flops(cfg, B=1, S=512, layers=2):
    cfg = cfg.replace(num_layers=layers)
    decls = model_decl(cfg)
    params = abstract_from_decls(decls)
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    from repro.models.model import forward
    from repro.roofline.hlo_analysis import analyze

    lowered = jax.jit(lambda p, b: forward(cfg, None, p, b)).lower(params, batch)
    # trip-count-aware FLOPs (builtin cost_analysis counts scan bodies once)
    return analyze(lowered.compile().as_text()).flops


def main():
    import dataclasses

    rows = []
    dense = get_config("llama3-8b")
    moe4 = get_config("llama3-e8t2")
    # Paper Table 1 counts ACTIVE FLOPs: with capacity-factor dispatch the
    # compiled program computes E*C = k*CF*T expert slots, so CF=1 is the
    # configuration whose compiled FLOPs equal the paper's active count
    # (and CF=4, the training config, pays 4x that in padded slots — the
    # MFU trade-off of Table 2).
    moe1 = moe4.replace(moe=dataclasses.replace(moe4.moe, capacity_factor=1.0))
    moe1 = moe1.replace(name="llama3-e8t2-cf1")
    S = 8192
    per_layer = {}
    for cfg in (dense, moe1, moe4.replace(name="llama3-e8t2-cf4")):
        t, a = cfg.param_counts()
        f2 = compiled_forward_flops(cfg, layers=2)
        f4 = compiled_forward_flops(cfg, layers=4)
        # isolate per-layer cost: at B=1,S=512 the V=128k logits matmul
        # dominates a 2-layer program and is identical across models
        layer_flops = (f4 - f2) / 2
        full = f2 + layer_flops * (cfg.num_layers - 2)
        per_layer[cfg.name] = layer_flops
        rows.append(
            {
                "model": cfg.name,
                "total_params_B": round(t / 1e9, 2),
                "active_params_B": round(a / 1e9, 2),
                "analytic_fwd_flops_bs1_8k": f"{cfg.flops_per_token(S) * S:.3e}",
                "compiled_fwd_flops_extrap_512tok": f"{full:.3e}",
            }
        )
    # per-LAYER compiled ratio (the logits head, identical in both models,
    # would otherwise dilute a short-sequence measurement)
    ratio_flops = per_layer["llama3-e8t2-cf1"] / per_layer["llama3-8b"]
    ratio_params = moe4.param_counts()[0] / dense.param_counts()[0]
    rows.append(
        {
            "model": "ratio (E8T2 CF1 / dense)",
            "total_params_B": round(ratio_params, 2),
            "active_params_B": round(moe4.param_counts()[1] / dense.param_counts()[1], 2),
            "analytic_fwd_flops_bs1_8k": round(
                moe4.flops_per_token(S) / dense.flops_per_token(S), 3
            ),
            "compiled_fwd_flops_extrap_512tok": round(ratio_flops, 3),
        }
    )
    emit("table1_flops", rows, list(rows[0]))
    # paper Table 1: ~1.6x active FLOPs; CF-padded compute is larger
    assert 1.3 < ratio_flops < 2.2, ratio_flops
    assert per_layer["llama3-e8t2-cf4"] > per_layer["llama3-e8t2-cf1"]


if __name__ == "__main__":
    main()
