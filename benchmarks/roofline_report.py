"""§Roofline deliverable: render the per-(arch x shape) roofline table from
the dry-run artifacts (single-pod mesh). Requires a prior
`python -m repro.launch.dryrun --all [--both-meshes]` run."""
import os

from repro.roofline.report import render


def main():
    d = "experiments/dryrun"
    if not os.path.isdir(d) or not os.listdir(d):
        print("no dry-run artifacts found; run repro.launch.dryrun --all first")
        return
    print(render(d))


if __name__ == "__main__":
    main()
