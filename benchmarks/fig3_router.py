"""Paper Figure 3: Mixtral-type vs ST-type router loss curves after
upcycling. Expected (and asserted): the Mixtral-type router starts at the
dense model's loss (function-preserving init); the ST-type starts measurably
higher and converges from above."""
import jax

from benchmarks.common import emit
from benchmarks.pretrain_cache import CT_STEPS, data, get_pretrained, tcfg
from repro.config import MoEConfig
from repro.core.upcycle import upcycle_config, upcycle_params
from repro.train.trainer import Trainer


def main():
    cfg, params = get_pretrained()
    curves = {}
    rows = []
    for rt in ("mixtral", "st"):
        moe_cfg = upcycle_config(
            cfg, MoEConfig(num_experts=4, top_k=2, capacity_factor=None, router_type=rt),
            name=f"e4t2-{rt}",
        )
        mp = upcycle_params(cfg, moe_cfg, params, jax.random.PRNGKey(5))
        t = tcfg(CT_STEPS)
        t = t.__class__(**{**t.__dict__, "log_every": 10})
        tr = Trainer(moe_cfg, t, params=mp, data_iter=data(200))
        init_ce = tr.eval_loss(6)  # held-out CE at init, before any training
        tr.run(CT_STEPS, log=lambda *_: None)
        curves[rt] = [(h["step"], h["ce"]) for h in tr.history]
        rows.append({"router": rt, "init_heldout_ce": round(init_ce, 4),
                     "start_ce": round(tr.history[0]["ce"], 4),
                     "final_ce": round(tr.history[-1]["ce"], 4),
                     "heldout_ce": round(tr.eval_loss(6), 4)})
    emit("fig3_router", rows, ["router", "init_heldout_ce", "start_ce", "final_ce", "heldout_ce"])
    print("# loss curves (step:ce)")
    for rt, c in curves.items():
        print(rt, " ".join(f"{s}:{v:.3f}" for s, v in c))
    mix, st = rows
    # Fig 3 claim: function-preserving (Mixtral) init starts strictly lower
    assert mix["init_heldout_ce"] < st["init_heldout_ce"] - 0.005, (mix, st)


if __name__ == "__main__":
    main()
