"""Serving bench: paged-KV vs ring-buffer engine on the e8t2 smoke config.

Runs the same mixed-length greedy workload through both cache backends and
emits machine-readable ``BENCH_serving.json`` at the repo root (plus the
usual CSV/JSON via benchmarks.common) with, per engine:

* ``tokens_per_s``        — end-to-end decode throughput (CPU wall time;
                            not hardware-representative, tracked for trend)
* ``p50_ms`` / ``p99_ms`` — per-token latency percentiles (each emitted
                            token is attributed its engine step's wall time)
* ``kv_bytes_resident``   — peak KV bytes actually pinned: the ring cache
                            pins ``max_batch * max_seq`` entries up front;
                            the paged cache pins only allocated pages
* ``page_utilization``    — peak allocated / pool size (paged only)
* ``prefill_traces``      — compiled prefill variants (ring: one per
                            length bucket; paged: 1 chunk + 1 decode step)

Asserted here (the acceptance gate): paged resident KV <= ring resident KV
at equal batch, and greedy outputs token-for-token identical across
engines.
"""
import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.config import get_config, smoke_config
from repro.models.model import model_decl
from repro.serving.engine import Request, ServingEngine
from repro.sharding.rules import init_from_decls

ROOT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serving.json",
)

MAX_BATCH, MAX_SEQ = 4, 96
N_REQ, MAX_NEW = 8, 12
PAGE_SIZE, PREFILL_CHUNK = 8, 16


def make_requests(cfg, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(6, 48, N_REQ)
    return [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, int(L)).astype(np.int32),
                max_new_tokens=MAX_NEW)
        for i, L in enumerate(lens)
    ]


def drive(engine, requests):
    """Run to drain, attributing each emitted token its step wall time."""
    for r in requests:
        engine.submit(r)
    per_token_ms = []
    t0 = time.perf_counter()
    while True:
        if engine.cache_mode == "paged":
            if not engine.sched.has_work:
                break
        elif not (any(engine.slots) or engine.queue):
            break
        before = sum(len(r.output) for r in requests)
        ts = time.perf_counter()
        engine.step()
        dt_ms = (time.perf_counter() - ts) * 1e3
        emitted = sum(len(r.output) for r in requests) - before
        per_token_ms.extend([dt_ms / max(emitted, 1)] * emitted)
    wall = time.perf_counter() - t0
    total = sum(len(r.output) for r in requests)
    lat = np.asarray(per_token_ms) if per_token_ms else np.zeros(1)
    kv = engine.kv_stats()
    return {
        "tokens": total,
        "tokens_per_s": round(total / wall, 2),
        "p50_ms": round(float(np.percentile(lat, 50)), 2),
        "p99_ms": round(float(np.percentile(lat, 99)), 2),
        "kv_bytes_resident": int(kv["kv_bytes_peak"]),
        "page_utilization": round(
            kv["peak_used_pages"] / max(kv["num_pages"], 1), 3
        )
        if engine.cache_mode == "paged"
        else 1.0,
        "peak_used_pages": int(kv["peak_used_pages"]),
        "num_pages": int(kv["num_pages"]),
        "prefill_traces": getattr(engine, "prefill_traces", 0),
    }, {r.rid: list(r.output) for r in requests}


def main():
    cfg = smoke_config(get_config("llama3-e8t2")).replace(dtype="float32")
    # dropless so chunked prefill routing matches full prefill routing
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=None))
    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(0))

    rows, outputs = [], {}
    for mode, kw in [
        ("ring", {}),
        ("paged", dict(page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK)),
    ]:
        engine = ServingEngine(cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                               cache_mode=mode, **kw)
        stats, outs = drive(engine, make_requests(cfg))
        stats["mode"] = mode
        rows.append(stats)
        outputs[mode] = outs

    ring, paged = rows[0], rows[1]
    parity = outputs["ring"] == outputs["paged"]
    assert parity, "greedy parity violated between ring and paged engines"
    assert paged["kv_bytes_resident"] <= ring["kv_bytes_resident"], (
        "paged mode must not pin more KV than the dense ring cache"
    )

    keys = ["mode", "tokens", "tokens_per_s", "p50_ms", "p99_ms",
            "kv_bytes_resident", "page_utilization", "peak_used_pages",
            "num_pages", "prefill_traces"]
    emit("serving_bench", rows, keys)
    report = {
        "config": cfg.name,
        "workload": {
            "requests": N_REQ, "max_new": MAX_NEW, "max_batch": MAX_BATCH,
            "max_seq": MAX_SEQ, "page_size": PAGE_SIZE,
            "prefill_chunk": PREFILL_CHUNK,
        },
        "engines": {r["mode"]: {k: r[k] for k in keys if k != "mode"} for r in rows},
        "parity_token_for_token": parity,
        "kv_bytes_saved": ring["kv_bytes_resident"] - paged["kv_bytes_resident"],
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {ROOT_JSON}")
    print(f"paged pins {paged['kv_bytes_resident']/1e6:.2f} MB peak vs ring "
          f"{ring['kv_bytes_resident']/1e6:.2f} MB "
          f"({report['kv_bytes_saved']/1e6:.2f} MB saved), parity={parity}")


if __name__ == "__main__":
    main()
