"""Serving bench: paged-KV vs ring-buffer engine on the e8t2 smoke config.

Runs the same mixed-length greedy workload through both cache backends and
emits machine-readable ``BENCH_serving.json`` at the repo root (plus the
usual CSV/JSON via benchmarks.common) with, per engine:

* ``tokens_per_s``        — end-to-end decode throughput (CPU wall time;
                            not hardware-representative, tracked for trend)
* ``p50_ms`` / ``p99_ms`` — per-token latency percentiles (each emitted
                            token is attributed its engine step's wall time)
* ``kv_bytes_resident``   — peak KV bytes actually pinned: the ring cache
                            pins ``max_batch * max_seq`` entries up front;
                            the paged cache pins only allocated pages
* ``page_utilization``    — peak allocated / pool size (paged only)
* ``prefill_traces``      — compiled prefill variants (ring: one per
                            length bucket; paged: 1 chunk + 1 decode step)

Asserted here (the acceptance gate): paged resident KV <= ring resident KV
at equal batch, and greedy outputs token-for-token identical across
engines.

**Prefix-reuse section** (``"prefix_reuse"``): cached vs cache-less paged
engine at 0% / 50% / 90% shared-prefix traffic — live-peak KV bytes
(shared pages counted once), tokens/s, hit tokens, COW clones; asserts
parity at every fraction and a strict live-bytes reduction at >= 50%.

**Speculation section** (``"speculation"``): the dense parent drafts
DRAFT_K tokens, the upcycled MoE verifies in one step — acceptance rate,
tokens/s vs the non-speculative baseline; asserts token parity and > 0.9
acceptance (function-preserving upcycling).

**Quantized-KV section** (``"quant"``): int8 KV pages (per-token scale
sidecar) vs bf16 pages at a FIXED pool byte budget, on a briefly-trained
greedy-parity probe model — page counts, peak concurrent resident
requests; asserts >= 1.5x residency for int8 and exact token parity.

**Multi-device scaling section** (``"scaling"`` in the JSON): subprocess
workers rerun a pool-bound paged workload on 1 / 2 / 4 fake CPU devices
(``--xla_force_host_platform_device_count`` — device count locks at first
jax init, hence subprocesses) through the mesh-aware engine, scaling the
DP shard count with the device count plus one EP x DP topology (dp=2,
ep=2) for the overlapped expert all-to-all. Per row: tokens/s, aggregate
and per-device peak resident KV bytes, and the scheduler's peak
concurrent-resident-request count. Asserted: >= 1.8x resident requests at
2 devices vs 1, and EP decode parity (every topology emits exactly the
single-device token streams).
"""
import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.config import get_config, smoke_config
from repro.models.model import model_decl
from repro.serving.engine import Request, ServingEngine
from repro.sharding.rules import init_from_decls

ROOT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serving.json",
)

MAX_BATCH, MAX_SEQ = 4, 96
N_REQ, MAX_NEW = 8, 12
PAGE_SIZE, PREFILL_CHUNK = 8, 16


def make_requests(cfg, seed=0):
    rng = np.random.default_rng(seed)
    lens = rng.integers(6, 48, N_REQ)
    return [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, int(L)).astype(np.int32),
                max_new_tokens=MAX_NEW)
        for i, L in enumerate(lens)
    ]


def drive(engine, requests):
    """Run to drain, attributing each emitted token its step wall time."""
    for r in requests:
        engine.submit(r)
    per_token_ms = []
    t0 = time.perf_counter()
    while True:
        if engine.cache_mode == "paged":
            if not engine.sched.has_work:
                break
        elif not (any(engine.slots) or engine.queue):
            break
        before = sum(len(r.output) for r in requests)
        ts = time.perf_counter()
        engine.step()
        dt_ms = (time.perf_counter() - ts) * 1e3
        emitted = sum(len(r.output) for r in requests) - before
        per_token_ms.extend([dt_ms / max(emitted, 1)] * emitted)
    wall = time.perf_counter() - t0
    total = sum(len(r.output) for r in requests)
    lat = np.asarray(per_token_ms) if per_token_ms else np.zeros(1)
    kv = engine.kv_stats()
    return {
        "tokens": total,
        "tokens_per_s": round(total / wall, 2),
        "p50_ms": round(float(np.percentile(lat, 50)), 2),
        "p99_ms": round(float(np.percentile(lat, 99)), 2),
        "kv_bytes_resident": int(kv["kv_bytes_peak"]),
        "page_utilization": round(
            kv["peak_used_pages"] / max(kv["num_pages"], 1), 3
        )
        if engine.cache_mode == "paged"
        else 1.0,
        "peak_used_pages": int(kv["peak_used_pages"]),
        "num_pages": int(kv["num_pages"]),
        "prefill_traces": getattr(engine, "prefill_traces", 0),
    }, {r.rid: list(r.output) for r in requests}


# -- graceful degradation under overload -------------------------------------
def run_resilience(cfg, params):
    """Overload a deliberately tiny shed-configured engine (the
    ``resilience`` report section): admission control must shed loudly
    (typed ShedError, request never enqueued), deadlines must evict on
    time, and the drained engine must end with zero resident pages. The
    counters come from :meth:`ServingEngine.health` — the same snapshot an
    external load-balancer polls."""
    from repro.resilience import ShedError

    engine = ServingEngine(
        cfg, params, max_batch=2, max_seq=MAX_SEQ, cache_mode="paged",
        page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK, num_pages=16,
        max_queue=2, shed_watermark=1, deadline_steps=40,
    )
    accepted, shed = [], 0
    for r in make_requests(cfg, seed=3):
        try:
            engine.submit(r)
            accepted.append(r)
        except ShedError:
            shed += 1
    steps = 0
    while engine.sched.has_work and steps < 2000:
        engine.step()
        steps += 1
    h = engine.health()
    assert h["shed_count"] == shed
    assert h["resident_pages"] == 0, "page leak after drain"
    assert shed + len(accepted) == N_REQ
    return {
        "workload": {"requests": N_REQ, "max_batch": 2, "num_pages": 16,
                     "max_queue": 2, "shed_watermark": 1, "deadline_steps": 40},
        "accepted": len(accepted),
        "shed_count": int(h["shed_count"]),
        "deadline_evictions": int(h["deadline_evictions"]),
        "completed_ok": sum(
            1 for r in accepted
            if r.status == "ok" and len(r.output) >= r.max_new_tokens
        ),
        "resident_pages_after_drain": int(h["resident_pages"]),
    }


# -- prefix-cache KV reuse ----------------------------------------------------
PREFIX_LEN, PREFIX_FRACS = 48, (0.0, 0.5, 0.9)  # 6 shared pages at ps=8


def _prefix_stem(cfg):
    return np.random.default_rng(4).integers(
        0, cfg.vocab_size, PREFIX_LEN
    ).astype(np.int32)


def _prefix_requests(cfg, frac, seed=5):
    """N_REQ requests; a ``frac`` fraction share a PREFIX_LEN-token stem
    (system-prompt traffic), spread evenly through the stream so every
    admission wave carries the same share — the live-KV peak then reflects
    concurrent sharing, not which wave happened to be all-random."""
    rng = np.random.default_rng(seed)
    stem = _prefix_stem(cfg)
    n_share = int(round(frac * N_REQ))
    share_ids = ({int(round(j * N_REQ / n_share)) for j in range(n_share)}
                 if n_share else set())
    reqs = []
    for i in range(N_REQ):
        tail = rng.integers(0, cfg.vocab_size, int(rng.integers(4, 16))).astype(np.int32)
        prompt = (np.concatenate([stem, tail]) if i in share_ids
                  else np.concatenate([rng.integers(0, cfg.vocab_size, PREFIX_LEN).astype(np.int32), tail]))
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=MAX_NEW))
    return reqs


def run_prefix_reuse(cfg, params):
    """Cached vs cache-less paged engine at 0% / 50% / 90% shared-prefix
    traffic. The headline metric is ``kv_bytes_live_peak`` — pages
    *referenced by live requests*, shared pages counted once (refcount-0
    cache residue is reclaimable on demand, like OS page cache, so it is
    excluded). Both engines first serve one bare-stem priming request
    (real prefix traffic finds the system prompt already warm; the
    cache-less engine pays the same priming work), then the measured
    workload. Asserted: token-for-token parity at every fraction, and a
    strict live-bytes reduction once >= 50% of traffic shares the stem."""
    rows = []
    for frac in PREFIX_FRACS:
        row = {"name": f"shared_{int(frac * 100)}pct", "shared_frac": frac}
        outs = {}
        for tag, cache in (("uncached", False), ("cached", True)):
            engine = ServingEngine(
                cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                cache_mode="paged", page_size=PAGE_SIZE,
                prefill_chunk=PREFILL_CHUNK, prefix_cache=cache,
            )
            engine.run([Request(rid=10_000, prompt=_prefix_stem(cfg),
                                max_new_tokens=1)])
            stats, outs[tag] = drive(engine, _prefix_requests(cfg, frac))
            kv = engine.kv_stats()
            row[tag] = {
                "tokens_per_s": stats["tokens_per_s"],
                "kv_bytes_live_peak": int(kv["kv_bytes_live_peak"]),
                "peak_live_pages": int(kv["peak_live_pages"]),
            }
            if cache:
                row["hit_tokens"] = int(kv["prefix"]["hit_tokens"])
                row["cow_clones"] = int(kv["prefix"]["cow_clones"])
                engine.page_pool.drop_prefix_cache()
                engine.page_pool.check_invariants()
                assert engine.page_pool.free_pages == engine.page_pool.num_pages
        row["parity"] = outs["cached"] == outs["uncached"]
        assert row["parity"], f"prefix cache changed tokens at frac={frac}"
        row["live_bytes_saved"] = (row["uncached"]["kv_bytes_live_peak"]
                                   - row["cached"]["kv_bytes_live_peak"])
        rows.append(row)
        print(f"  prefix {row['name']}: live peak "
              f"{row['cached']['kv_bytes_live_peak']/1e6:.2f} MB cached vs "
              f"{row['uncached']['kv_bytes_live_peak']/1e6:.2f} MB uncached, "
              f"{row.get('hit_tokens', 0)} hit tokens")
    for row in rows:
        if row["shared_frac"] >= 0.5:
            assert row["live_bytes_saved"] > 0, (
                f"prefix sharing saved no live KV at {row['name']}: {row}"
            )
    return {
        "workload": {
            "requests": N_REQ, "max_new": MAX_NEW, "max_batch": MAX_BATCH,
            "prefix_len": PREFIX_LEN, "page_size": PAGE_SIZE,
            "prefill_chunk": PREFILL_CHUNK,
        },
        "rows": rows,
    }


# -- speculative decoding -----------------------------------------------------
DRAFT_K = 4


def run_speculation(cfg):
    """Dense-parent speculative decoding on the paper's pairing: upcycle
    the dense parent into the served MoE (function-preserving), draft
    DRAFT_K tokens on the parent, verify in one MoE step. Asserted:
    token-for-token parity with non-speculative decode and near-total
    acceptance (the whole point of serving an upcycled checkpoint with its
    parent as drafter)."""
    from repro.core.upcycle import upcycle_params
    from repro.serving.speculative import SpeculativeEngine

    dense_cfg = cfg.replace(name=f"{cfg.name}-parent", family="dense", moe=None)
    dense_params = init_from_decls(model_decl(dense_cfg), jax.random.PRNGKey(0))
    kw = dict(max_batch=MAX_BATCH, max_seq=MAX_SEQ, page_size=PAGE_SIZE,
              prefill_chunk=PREFILL_CHUNK)
    spec = SpeculativeEngine.from_upcycle(dense_cfg, cfg, dense_params,
                                          draft_k=DRAFT_K, **kw)
    spec_stats, spec_outs = drive(spec, make_requests(cfg, seed=9))
    moe_params = upcycle_params(dense_cfg, cfg, dense_params,
                                jax.random.PRNGKey(0))
    base = ServingEngine(cfg, moe_params, cache_mode="paged", **kw)
    base_stats, base_outs = drive(base, make_requests(cfg, seed=9))
    assert spec_outs == base_outs, "speculative decode changed greedy tokens"
    s = spec.kv_stats()["speculation"]
    assert s["acceptance_rate"] > 0.9, s
    spec.page_pool.check_invariants()
    assert spec.page_pool.free_pages == spec.page_pool.num_pages
    print(f"  speculation: k={DRAFT_K}, acceptance {s['acceptance_rate']:.2%}, "
          f"{spec_stats['tokens_per_s']} tok/s speculative vs "
          f"{base_stats['tokens_per_s']} baseline")
    return {
        "workload": {"requests": N_REQ, "max_new": MAX_NEW,
                     "max_batch": MAX_BATCH, "page_size": PAGE_SIZE},
        "draft_k": DRAFT_K,
        "acceptance_rate": s["acceptance_rate"],
        "drafted_tokens": s["drafted_tokens"],
        "accepted_tokens": s["accepted_tokens"],
        "verify_steps": s["spec_steps"],
        "tokens_per_s_speculative": spec_stats["tokens_per_s"],
        "tokens_per_s_baseline": base_stats["tokens_per_s"],
        "parity_token_for_token": spec_outs == base_outs,
    }


# -- quantized KV pages at a fixed pool byte budget ---------------------------
# bf16 engine gets QUANT_PAGES_BF16 pages; the int8 engine gets however many
# pages fit the SAME byte budget (int8 payload + f32 scale sidecar per
# token-head vs 2 bytes/elem -> ~1.9x pages at head_dim 64+). Each request
# pins up to 5 pages (24-token prompt + 8 new at page_size 8, same
# accounting as the scaling section). QUANT_REQS and max_batch sit well
# above either pool's concurrent capacity so free pages — not the workload
# — bound peak residency on both sides.
QUANT_PAGES_BF16, QUANT_PROMPT, QUANT_NEW, QUANT_REQS = 20, 24, 8, 16


def run_quant_kv():
    """int8 KV pages vs bf16 pages at a FIXED page-pool byte budget (the
    ``quant`` report section). Params are first sharpened into a greedy-
    parity probe (see quant.sharpen_for_parity: random-init logits are
    near-uniform, so token parity there is a coin flip, not a claim), then
    the same workload runs through both engines. Asserted: >= 1.5x peak
    concurrent resident requests for int8 at equal pool bytes, and greedy
    outputs token-for-token identical — the residency win may not cost
    tokens."""
    from repro.core.quant import sharpen_for_parity
    from repro.serving.kv_cache import kv_page_bytes

    cfg = smoke_config(get_config("llama3-e8t2"))
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=None, dispatcher="allgather"))
    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(0))
    params, pattern = sharpen_for_parity(cfg, params)

    budget = QUANT_PAGES_BF16 * kv_page_bytes(cfg, PAGE_SIZE)
    q8_page = kv_page_bytes(cfg.replace(quant_kv="int8"), PAGE_SIZE)
    pages = {"bf16": QUANT_PAGES_BF16, "int8": budget // q8_page}

    def _requests():
        # rotations of the memorized pattern, limited to the rolls the probe
        # actually trained on (sharpen_for_parity's batch of 8): only there
        # do the top-1 margins provably dwarf the int8 error. rid 8+ repeat
        # the prompts — duplicate traffic, realistic and margin-safe.
        return [
            Request(rid=i,
                    prompt=np.roll(pattern, -(i % 8))[:QUANT_PROMPT]
                    .astype(np.int32),
                    max_new_tokens=QUANT_NEW)
            for i in range(QUANT_REQS)
        ]

    engines, outs = {}, {}
    for tag, quant in (("bf16", "none"), ("int8", "int8")):
        engine = ServingEngine(
            cfg, params, max_batch=QUANT_REQS, max_seq=MAX_SEQ,
            cache_mode="paged", page_size=PAGE_SIZE,
            prefill_chunk=PREFILL_CHUNK, num_pages=pages[tag],
            quant_kv=quant,
        )
        stats, outs[tag] = drive(engine, _requests())
        kv = engine.kv_stats()
        engine.page_pool.check_invariants()
        assert engine.page_pool.free_pages == engine.page_pool.num_pages
        engines[tag] = {
            "num_pages": pages[tag],
            "pool_bytes": pages[tag] * kv_page_bytes(engine.cfg, PAGE_SIZE),
            "page_bytes": kv_page_bytes(engine.cfg, PAGE_SIZE),
            "tokens_per_s": stats["tokens_per_s"],
            "kv_bytes_resident_peak": stats["kv_bytes_resident"],
            "peak_resident_requests": int(kv["peak_resident_requests"]),
        }
    parity = outs["bf16"] == outs["int8"]
    assert parity, "int8 KV pages changed greedy tokens on the probe model"
    ratio = (engines["int8"]["peak_resident_requests"]
             / max(engines["bf16"]["peak_resident_requests"], 1))
    assert ratio >= 1.5, (
        f"int8 pages admitted only {ratio:.2f}x the resident requests of "
        f"bf16 at equal pool bytes (need >= 1.5x): {engines}"
    )
    print(f"  quant-kv: {engines['int8']['num_pages']} int8 pages vs "
          f"{engines['bf16']['num_pages']} bf16 in {budget/1e6:.2f} MB, "
          f"resident requests {engines['int8']['peak_resident_requests']} vs "
          f"{engines['bf16']['peak_resident_requests']} ({ratio:.2f}x), "
          f"parity={parity}")
    return {
        "workload": {
            "requests": QUANT_REQS, "prompt_len": QUANT_PROMPT,
            "max_new": QUANT_NEW, "max_batch": QUANT_REQS,
            "page_size": PAGE_SIZE, "prefill_chunk": PREFILL_CHUNK,
        },
        "pool_bytes_budget": budget,
        "engines": engines,
        "resident_requests_ratio_int8": round(ratio, 2),
        "parity_token_for_token": parity,
    }


# -- multi-device scaling (subprocess workers) -------------------------------
# pool-bound workload: every request needs 5 pages (24-token prompt + 8 new
# at page_size 8) and each DP shard's sub-pool holds 11, so exactly two
# requests fit a shard concurrently — peak resident requests then scales
# with the shard count, which is the aggregate-pool claim under test.
SCALE_PROMPT, SCALE_NEW, SCALE_PPS = 24, 8, 11
SCALE_TOPOLOGIES = [  # (devices, dp, ep)
    (1, 1, 1),
    (2, 2, 1),
    (4, 4, 1),
    (4, 2, 2),  # EP x DP: decode through the overlapped expert all-to-all
]


def _bench_cfg():
    cfg = smoke_config(get_config("llama3-e8t2")).replace(dtype="float32")
    # dropless so chunked prefill routing matches full prefill routing
    return cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=None))


def scaling_worker(dp: int, ep: int) -> None:
    """Run the pool-bound workload on a dp x ep serving mesh; prints one
    JSON row (parsed by the parent from the last stdout line)."""
    from repro.launch.mesh import make_serving_mesh
    from repro.serving.kv_cache import kv_bytes_resident_per_shard

    cfg = _bench_cfg()
    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(0))
    engine = ServingEngine(
        cfg, params, max_batch=4 * dp, max_seq=MAX_SEQ, cache_mode="paged",
        page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK,
        num_pages=SCALE_PPS * dp, mesh=make_serving_mesh(dp, ep),
    )
    rng = np.random.default_rng(7)
    requests = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, SCALE_PROMPT).astype(np.int32),
                max_new_tokens=SCALE_NEW)
        for i in range(N_REQ)
    ]
    for r in requests:
        engine.submit(r)
    per_shard_peak = [0] * engine.dp_shards
    t0 = time.perf_counter()
    while engine.sched.has_work:
        engine.step()
        for s, b in enumerate(kv_bytes_resident_per_shard(cfg, engine.page_pool)):
            per_shard_peak[s] = max(per_shard_peak[s], b)
    wall = time.perf_counter() - t0
    engine.page_pool.check_invariants()
    assert engine.page_pool.free_pages == engine.page_pool.num_pages, "pool leak"
    kv = engine.kv_stats()
    total = sum(len(r.output) for r in requests)
    print(json.dumps({
        "devices": dp * ep, "dp": dp, "ep": ep,
        "dispatcher": engine.cfg.moe.dispatcher,
        "tokens": total,
        "tokens_per_s": round(total / wall, 2),
        "kv_bytes_resident_peak": int(kv["kv_bytes_peak"]),
        "kv_bytes_resident_per_shard_peak": per_shard_peak,
        "peak_resident_requests": int(kv["peak_resident_requests"]),
        "outputs": {str(r.rid): list(map(int, r.output)) for r in requests},
    }))


def run_scaling():
    """Launch one subprocess per topology and build the ``scaling`` report
    section (the parent process has already initialized jax at one device,
    so fake-device runs must be fresh processes)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows = []
    for devices, dp, ep in SCALE_TOPOLOGIES:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={devices}"
        ).strip()
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in [os.path.join(root, "src"), root,
                        env.get("PYTHONPATH", "")] if p
        )
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--scaling-worker", str(dp), str(ep)],
            capture_output=True, text=True, env=env, cwd=root, timeout=1800,
        )
        assert proc.returncode == 0, (
            f"scaling worker dp={dp} ep={ep} failed:\n{proc.stdout}\n{proc.stderr}"
        )
        rows.append(json.loads(proc.stdout.strip().splitlines()[-1]))
        r = rows[-1]
        print(f"  scaling dp={dp} ep={ep}: {r['tokens_per_s']} tok/s, "
              f"peak resident requests {r['peak_resident_requests']}, "
              f"peak KV/shard {r['kv_bytes_resident_per_shard_peak']}")

    base = next(r for r in rows if r["devices"] == 1)
    two = next(r for r in rows if r["devices"] == 2)
    ratio = two["peak_resident_requests"] / max(base["peak_resident_requests"], 1)
    assert ratio >= 1.8, (
        f"2-device aggregate pool admitted only {ratio:.2f}x the resident "
        f"requests of 1 device (need >= 1.8x)"
    )
    for r in rows:
        # no single shard's peak exceeds the aggregate peak, and EP x DP
        # decode emits exactly the single-device token streams
        assert max(r["kv_bytes_resident_per_shard_peak"]) <= r[
            "kv_bytes_resident_peak"
        ], r
        r["ep_decode_parity"] = r["outputs"] == base["outputs"]
        assert r["ep_decode_parity"], f"decode parity broken at dp={r['dp']} ep={r['ep']}"
    for r in rows:
        del r["outputs"]  # bulky; parity already folded into the flag
    return {
        "workload": {
            "requests": N_REQ, "prompt_len": SCALE_PROMPT,
            "max_new": SCALE_NEW, "pages_per_shard": SCALE_PPS,
            "page_size": PAGE_SIZE, "prefill_chunk": PREFILL_CHUNK,
        },
        "rows": rows,
        "resident_requests_scaling_2dev": round(ratio, 2),
    }


def main():
    cfg = _bench_cfg()
    # single-host sections have no EP plan: pick the legal dispatcher
    # explicitly rather than riding the quiet alltoall->allgather fallback,
    # which CI's REPRO_STRICT_DISPATCH=1 turns into a loud error
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatcher="allgather"))
    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(0))

    rows, outputs = [], {}
    for mode, kw in [
        ("ring", {}),
        ("paged", dict(page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK)),
    ]:
        engine = ServingEngine(cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                               cache_mode=mode, **kw)
        stats, outs = drive(engine, make_requests(cfg))
        stats["mode"] = mode
        rows.append(stats)
        outputs[mode] = outs

    ring, paged = rows[0], rows[1]
    parity = outputs["ring"] == outputs["paged"]
    assert parity, "greedy parity violated between ring and paged engines"
    assert paged["kv_bytes_resident"] <= ring["kv_bytes_resident"], (
        "paged mode must not pin more KV than the dense ring cache"
    )

    keys = ["mode", "tokens", "tokens_per_s", "p50_ms", "p99_ms",
            "kv_bytes_resident", "page_utilization", "peak_used_pages",
            "num_pages", "prefill_traces"]
    emit("serving_bench", rows, keys)
    report = {
        "config": cfg.name,
        "workload": {
            "requests": N_REQ, "max_new": MAX_NEW, "max_batch": MAX_BATCH,
            "max_seq": MAX_SEQ, "page_size": PAGE_SIZE,
            "prefill_chunk": PREFILL_CHUNK,
        },
        "engines": {r["mode"]: {k: r[k] for k in keys if k != "mode"} for r in rows},
        "parity_token_for_token": parity,
        "kv_bytes_saved": ring["kv_bytes_resident"] - paged["kv_bytes_resident"],
    }
    report["resilience"] = run_resilience(cfg, params)
    res = report["resilience"]
    print(f"overload resilience: {res['accepted']} accepted / "
          f"{res['shed_count']} shed, {res['deadline_evictions']} deadline "
          f"evictions, {res['completed_ok']} completed on time")
    print("prefix-cache KV reuse...")
    report["prefix_reuse"] = run_prefix_reuse(cfg, params)
    print("dense-parent speculative decoding...")
    report["speculation"] = run_speculation(cfg)
    print("quantized KV pages at fixed pool bytes (sharpening probe model)...")
    report["quant"] = run_quant_kv()
    if "--skip-scaling" not in sys.argv:
        print("multi-device scaling (subprocess workers)...")
        report["scaling"] = run_scaling()
    with open(ROOT_JSON, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {ROOT_JSON}")
    print(f"paged pins {paged['kv_bytes_resident']/1e6:.2f} MB peak vs ring "
          f"{ring['kv_bytes_resident']/1e6:.2f} MB "
          f"({report['kv_bytes_saved']/1e6:.2f} MB saved), parity={parity}")
    if "scaling" in report:
        print(f"resident-request scaling at 2 devices: "
              f"{report['scaling']['resident_requests_scaling_2dev']}x")


if __name__ == "__main__":
    if len(sys.argv) >= 4 and sys.argv[1] == "--scaling-worker":
        scaling_worker(int(sys.argv[2]), int(sys.argv[3]))
    else:
        main()
