"""Training-runtime bench: steady-state throughput, checkpoint cost
(async-overlapped vs blocking), checkpoint size, and resume latency.

Asserts the subsystem's headline guarantees so CI catches regressions:

* async save blocks the training loop for LESS than one steady step per
  checkpoint (the "<1 blocked step" acceptance bar) — and strictly less
  than the equivalent blocking save;
* a save -> restore -> continue run is bitwise the uninterrupted run.

CPU wall-times are not TPU-representative, but the RATIO of blocked-save
time to step time and the byte accounting are the quantities the async
double-buffered design exists to optimize.

Output: CSV on stdout, JSON via benchmarks.common.emit, and machine-readable
``BENCH_train.json`` at the repo root (CI artifact).
"""
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.config import ModelConfig, MoEConfig, TrainConfig
from repro.data.pipeline import make_train_iter
from repro.train.callbacks import CheckpointCallback, LoggingCallback
from repro.train.state import restore_train_state
from repro.train.trainer import Trainer

ROOT_JSON = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_train.json")
CKPT_DIR = os.environ.get("BENCH_CKPT_DIR", "experiments/bench/train_ckpt")

STEPS = 8
CKPT_EVERY = 2


def _cfg() -> ModelConfig:
    # small e4t2 MoE: big enough that a step dwarfs host-copy cost, small
    # enough to compile in seconds on CPU
    return ModelConfig(
        name="bench-e4t2", family="moe", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=1024,
        vocab_divisor=128,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=None,
                      dispatcher="sorted"),
    )


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        total += sum(os.path.getsize(os.path.join(root, f)) for f in files)
    return total


def _run(cfg, tcfg, steps, ckpt_dir, async_save, state=None, data_state=None):
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                         tcfg.blend_ratio, tcfg.seed)
    if data_state is not None:
        it.restore(data_state)
    tr = Trainer(cfg, tcfg, data_iter=it, state=state)
    log_cb = LoggingCallback(log=lambda *_: None, log_every=1)
    ckpt_cb = CheckpointCallback(ckpt_dir, every=CKPT_EVERY,
                                 keep_last=2, async_save=async_save)
    tr.run(steps, log=lambda *_: None, callbacks=[log_cb, ckpt_cb])
    return tr, log_cb, ckpt_cb


def main():
    cfg = _cfg()
    tcfg = TrainConfig(global_batch=8, seq_len=64, lr=3e-3, lr_min=3e-4,
                       warmup_steps=2, total_steps=STEPS, log_every=1, seed=0)

    rows = []
    stats = {}
    for mode in ("blocking", "async"):
        d = os.path.join(CKPT_DIR, mode)
        tr, log_cb, ckpt_cb = _run(cfg, tcfg, STEPS, d, async_save=(mode == "async"))
        ckpt_cb.manager.wait()
        steady_s = float(np.mean(log_cb.durations[1:]))
        blocked = ckpt_cb.blocked_s
        stats[mode] = {
            "steady_s": steady_s,
            "blocked_mean_s": float(np.mean(blocked)),
            "blocked_max_s": float(np.max(blocked)),
            "final_loss": tr.history[-1]["loss"],
        }
        rows.append({
            "mode": mode,
            "steps_per_s": round(1.0 / steady_s, 3),
            "ms_per_step_steady": round(steady_s * 1e3, 2),
            "save_blocked_ms_mean": round(np.mean(blocked) * 1e3, 2),
            "save_blocked_ms_max": round(np.max(blocked) * 1e3, 2),
            "saves": len(blocked),
            "ckpt_bytes": _dir_bytes(d),
        })

    # -- resume latency + exact-parity gate --------------------------------
    d = os.path.join(CKPT_DIR, "async")
    t0 = time.perf_counter()
    state, manifest = restore_train_state(d, cfg)
    jax.block_until_ready(jax.tree.leaves(state.params)[0])
    restore_s = time.perf_counter() - t0
    resumed, _, _ = _run(cfg, tcfg, 2, os.path.join(CKPT_DIR, "resume"),
                         async_save=True, state=state,
                         data_state=manifest["meta"].get("data_state"))
    straight, _, _ = _run(cfg, tcfg, STEPS + 2, os.path.join(CKPT_DIR, "straight"),
                          async_save=True)
    parity = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(resumed.params),
                        jax.tree.leaves(straight.params))
    )
    rows.append({
        "mode": "resume",
        "restore_ms": round(restore_s * 1e3, 2),
        "resumed_from_step": manifest["step"],
        "parity_bitwise": parity,
    })

    # -- supervised anomaly recovery (the ``anomaly`` report section) ------
    # injected NaN grads + a 2-step loss spike through the train.step fault
    # site; the in-jit guard skips the bad updates and the supervisor rolls
    # back to the last good checkpoint. Counters are deterministic; the
    # perf gate tracks them informationally.
    from repro.resilience import FaultSpec, faults
    from repro.train.callbacks import AnomalySupervisor

    d_anom = os.path.join(CKPT_DIR, "anomaly")
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                         tcfg.blend_ratio, tcfg.seed)
    tr_anom = Trainer(cfg, tcfg, data_iter=it)
    ck = CheckpointCallback(d_anom, every=CKPT_EVERY, keep_last=2,
                            async_save=True)
    sup = AnomalySupervisor(ckpt=ck, rollback_after=2, warmup_steps=3,
                            log=lambda *_: None)
    with faults.inject(
        FaultSpec("train.step", "nan_grads", at=4),
        FaultSpec("train.step", "loss_spike", at=6, count=2,
                  args={"shift": 1e5}),
    ):
        tr_anom.run(STEPS, log=lambda *_: None, callbacks=[ck, sup])
    ck.manager.wait()
    params_finite = all(
        bool(np.isfinite(np.asarray(x, np.float32)).all())
        for x in jax.tree.leaves(jax.device_get(tr_anom.params))
    )
    s = sup.summary()
    anomaly = {
        "skipped_updates": s["skipped_updates"],
        "rollbacks": s["rollbacks"],
        "interventions": len(s["interventions"]),
        "final_params_finite": params_finite,
    }
    assert params_finite, "NaN leaked through the anomaly guard"
    assert s["skipped_updates"] == 3 and s["rollbacks"] == 1, (
        f"supervised recovery drifted from the injected scenario: {s}"
    )

    keys = ["mode", "steps_per_s", "ms_per_step_steady", "save_blocked_ms_mean",
            "save_blocked_ms_max", "saves", "ckpt_bytes", "restore_ms",
            "resumed_from_step", "parity_bitwise"]
    emit("train_bench", rows, keys)

    a, b = stats["async"], stats["blocking"]
    report = {
        "config": cfg.name,
        "workload": {"steps": STEPS, "ckpt_every": CKPT_EVERY,
                     "global_batch": tcfg.global_batch, "seq_len": tcfg.seq_len},
        "rows": rows,
        "async_blocked_fraction_of_step": a["blocked_max_s"] / a["steady_s"],
        "blocking_save_fraction_of_step": b["blocked_max_s"] / b["steady_s"],
        "resume_parity_bitwise": parity,
        "anomaly": anomaly,
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {ROOT_JSON}")
    print(f"async save blocks {a['blocked_max_s']*1e3:.1f} ms "
          f"(max) vs {a['steady_s']*1e3:.1f} ms/step steady "
          f"({report['async_blocked_fraction_of_step']:.2%} of a step); "
          f"blocking save costs {b['blocked_max_s']*1e3:.1f} ms")
    print(f"anomaly supervision: {anomaly['skipped_updates']} updates "
          f"skipped, {anomaly['rollbacks']} rollback(s), params finite: "
          f"{anomaly['final_params_finite']}")

    # acceptance gates
    assert parity, "resume parity violated: save->restore->continue != straight run"
    assert a["blocked_max_s"] < a["steady_s"], (
        "async checkpoint must block the loop for less than one steady step: "
        f"{a['blocked_max_s']:.3f}s blocked vs {a['steady_s']:.3f}s/step"
    )
    assert a["blocked_mean_s"] <= b["blocked_mean_s"], (
        "async save should not block longer than the blocking save path"
    )


if __name__ == "__main__":
    main()
