import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Paper Table 2: training performance across parallel configurations.

The paper tunes (TP, CP, ETP, EP, PP, VP, CF) for Llama3-E8T2 on 128 H100s
and reports TFLOPS/GPU + MFU. Without hardware we report the ROOFLINE-MODEL
analog on 256 TPU chips: for each folding config we lower the real E8T2
train step, derive the three roofline terms, and compute

    roofline MFU = model_flops / (chips * peak * max(terms))

The paper's qualitative findings we check:
  1. EP placement beats expert-TP for the MoE layers (finding #1),
  2. the AllToAll dispatcher beats AllGather for small top-k (finding #2),
  3. CF=1 beats dropless on throughput (Table 2 rows 1 vs 4).
"""
import dataclasses  # noqa: E402

import jax  # noqa: E402

from benchmarks.common import emit  # noqa: E402
from repro.config import SHAPES, TrainConfig, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh, make_study_mesh  # noqa: E402
from repro.launch.specs import batch_specs, param_specs, rng_spec  # noqa: E402
from repro.models.model import model_decl  # noqa: E402
from repro.roofline.analysis import HW, roofline_from_hlo  # noqa: E402
from repro.sharding.rules import FoldingPlan  # noqa: E402
from repro.train.trainer import make_train_step  # noqa: E402

SHAPE = SHAPES["train_4k"]


def lower_config(cfg, mesh, label):
    from repro.launch.dryrun import _opt_specs

    plan = FoldingPlan.make(cfg, mesh)
    tcfg = TrainConfig(global_batch=SHAPE.global_batch, seq_len=SHAPE.seq_len)
    step = make_train_step(cfg, tcfg, plan)
    params_abs = param_specs(cfg, plan)
    args = (params_abs, _opt_specs(cfg, plan, params_abs),
            batch_specs(cfg, SHAPE, plan), rng_spec(plan))
    with mesh:
        compiled = jax.jit(step, donate_argnums=(0, 1)).lower(*args).compile()
    terms, _ = roofline_from_hlo(compiled.as_text(), mesh.devices.size)
    tokens = SHAPE.global_batch * SHAPE.seq_len
    model_flops = 3 * cfg.flops_per_token(SHAPE.seq_len) * tokens
    step_t = terms.step_time_s
    mfu = model_flops / (mesh.devices.size * HW["peak_flops"] * step_t)
    return {
        "config": label,
        "moe_mode": plan.moe_mode,
        "dispatcher": cfg.moe.dispatcher,
        "cf": cfg.moe.capacity_factor,
        "compute_s": round(terms.compute_s, 4),
        "memory_s": round(terms.memory_s, 4),
        "collective_s": round(terms.collective_s, 4),
        "dominant": terms.dominant,
        "roofline_step_s": round(step_t, 4),
        "roofline_mfu_pct": round(100 * mfu, 1),
    }


def main():
    base = get_config("llama3-e8t2")
    rows = []

    def with_moe(**kw):
        return base.replace(moe=dataclasses.replace(base.moe, **kw))

    # production 2-D mesh: experts fall back to expert-TP (ETP16)
    mesh2d = make_production_mesh()
    rows.append(lower_config(with_moe(dispatcher="allgather"), mesh2d,
                             "2D 16x16 ETP16 allgather CF4"))
    # study 3-D meshes: true EP8 (the paper's TP1EP8-style folding)
    mesh_ep = make_study_mesh(32, 8, 1)
    rows.append(lower_config(with_moe(dispatcher="allgather"), mesh_ep,
                             "3D 32x8x1 EP8 allgather CF4"))
    rows.append(lower_config(with_moe(dispatcher="alltoall"), mesh_ep,
                             "3D 32x8x1 EP8 alltoall CF4"))
    mesh_ep_tp = make_study_mesh(16, 8, 2)
    rows.append(lower_config(with_moe(dispatcher="alltoall"), mesh_ep_tp,
                             "3D 16x8x2 EP8xTP2 alltoall CF4"))
    # CF sweep on the best mesh (paper rows: CF1 best MFU, dropless worst)
    for cf in (1.0, 2.0, None):
        rows.append(lower_config(with_moe(dispatcher="alltoall", capacity_factor=cf),
                                 mesh_ep, f"3D 32x8x1 EP8 alltoall CF{cf}"))
    emit("table2_parallel", rows, list(rows[0]))


if __name__ == "__main__":
    main()
