"""Paper Table 4 / Figure 2: capacity-factor ablation.

Upcycle the same trained dense checkpoint with CF in {1, 2, 4, dropless},
train each for the same budget, report: held-out CE (quality), measured
step time and capacity-buffer tokens per expert (throughput proxies for the
paper's MFU column), and the realized token-drop fraction. Paper findings
checked: CF1 has the smallest dispatch buffer (best MFU) but drops tokens;
dropless has the largest buffer and no quality edge over CF2/CF4."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from benchmarks.pretrain_cache import CT_STEPS, base_cfg, data, get_pretrained, tcfg
from repro.config import MoEConfig
from repro.core.moe import _dispatch_tables, capacity
from repro.core.upcycle import upcycle_config, upcycle_params
from repro.train.trainer import Trainer


def drop_fraction(moe_cfg, params, batch):
    from repro.core.router import route
    from repro.models.layers import embed_apply

    x = embed_apply(params["embed"], batch["tokens"], jnp.float32)
    r = params["stack"]["slot0"]["ffn"]["router"]
    moe = moe_cfg.moe
    gates, idx, _ = route(moe, jax.tree.map(lambda v: v[0], r), x.reshape(-1, x.shape[-1]))
    T = gates.shape[0]
    C = capacity(moe, T)
    _, slot_gate = _dispatch_tables(idx, gates, moe.num_experts, C)
    kept = float((np.asarray(slot_gate) > 0).sum())
    return 1.0 - kept / (T * moe.top_k)


def main():
    cfg, params = get_pretrained()
    rows = []

    ct = Trainer(cfg, tcfg(CT_STEPS), params=params, data_iter=data(200))
    t0 = time.perf_counter()
    ct.run(CT_STEPS, log=lambda *_: None)
    rows.append({"strategy": "Base Model CT", "heldout_ce": round(ct.eval_loss(6), 4),
                 "ms_per_step": round((time.perf_counter() - t0) / CT_STEPS * 1e3, 1),
                 "capacity_per_expert": "", "drop_frac": ""})

    T = tcfg(1).global_batch * tcfg(1).seq_len
    for cf, label in ((None, "Dropless"), (4.0, "CF 4"), (2.0, "CF 2"), (1.0, "CF 1")):
        moe_cfg = upcycle_config(
            cfg, MoEConfig(num_experts=4, top_k=2, capacity_factor=cf),
            name=f"e4t2-cf{cf}",
        )
        mp = upcycle_params(cfg, moe_cfg, params, jax.random.PRNGKey(5))
        tr = Trainer(moe_cfg, tcfg(CT_STEPS), params=mp, data_iter=data(200))
        t0 = time.perf_counter()
        tr.run(CT_STEPS, log=lambda *_: None)
        dt = (time.perf_counter() - t0) / CT_STEPS * 1e3
        batch = {k: jnp.asarray(v) for k, v in next(data(300)).items()}
        df = drop_fraction(moe_cfg, tr.params, batch)
        rows.append({"strategy": label, "heldout_ce": round(tr.eval_loss(6), 4),
                     "ms_per_step": round(dt, 1),
                     "capacity_per_expert": capacity(moe_cfg.moe, T),
                     "drop_frac": round(df, 4)})
    emit("table4_cf", rows, list(rows[0]))
    caps = [r["capacity_per_expert"] for r in rows[1:]]
    assert caps[0] == max(caps) and caps[-1] == min(caps)  # dropless max, CF1 min


if __name__ == "__main__":
    main()
