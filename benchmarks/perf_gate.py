"""Perf gate: diff freshly generated ``BENCH_*.json`` artifacts against the
committed baselines in ``benchmarks/baselines/`` with per-metric tolerance
bands, failing CI on regressions.

Metric classes (by key name / leaf type):

* **timing** (``us_*``, ``*_ms``, ``*_per_s`` ...) — CPU wall times on CI
  runners are very noisy, so the band is generous: fail only when worse
  than ``TIME_BAND`` x baseline (direction-aware: ``*_per_s`` is
  higher-is-better, the rest lower-is-better). Improvements always pass
  and are reported so baselines can be re-pinned.
* **numerical error** (``*err*``) — fail above ``ERR_BAND`` x baseline
  (+ eps): kernel accuracy must not quietly degrade.
* **bytes** (``*bytes*`` ints) — 2% relative band (checkpoint manifests
  carry a few variable-length fields); all other ints and bools/strings
  are exact — parity flags, page counts, trace counts and row identities
  are deterministic claims, not measurements.
* **other floats** — 25% relative band (utilization ratios, fractions).
* **informational** (resilience counters: paths containing ``anomaly``,
  ``shed``, ``evict``, ``skipped``, ``rollback``, ``fallback``, or
  ``intervention``) — tracked, never gated: a drift in how many updates
  the anomaly supervisor skipped or how many requests the engine shed is
  reported as a note, not a failure (the chaos suite asserts the recovery
  *behavior*; the bench just surfaces the counts).

A key present in the baseline but missing from the fresh artifact is a
coverage regression and fails; new keys in the fresh artifact pass (they
are picked up on the next ``--update``). Rows in ``rows``/``engines``
containers are matched by their ``name``/``mode`` identity when present.

Usage::

    python benchmarks/perf_gate.py            # gate (exit 1 on regression)
    python benchmarks/perf_gate.py --update   # pin current artifacts
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Any, List, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(ROOT, "benchmarks", "baselines")
ARTIFACTS = ("BENCH_kernels.json", "BENCH_serving.json", "BENCH_train.json")

TIME_BAND = 5.0  # fail when a wall-time metric is > 5x worse than baseline
ERR_BAND = 4.0  # fail when a kernel-error metric is > 4x worse
BYTES_TOL = 0.02
FLOAT_TOL = 0.25

_TIME_MARKERS = ("us_", "_ms", "ms_", "per_s", "_blocked", "restore_ms")
_HIGHER_BETTER = ("per_s",)
_INFO_MARKERS = ("anomaly", "shed", "evict", "skipped", "rollback",
                 "fallback", "intervention")

# Sections that must exist in the FRESH artifact even when the committed
# baseline predates them — a bench edit that silently drops a coverage
# section must fail here, not ride through as "new keys pass".
REQUIRED_SECTIONS = {
    "BENCH_serving.json": ("prefix_reuse", "speculation", "quant"),
    "BENCH_kernels.json": ("fused_dispatch", "autotune"),
}


def _is_timing(key: str) -> bool:
    return any(m in key for m in _TIME_MARKERS)


def _is_informational(path: str) -> bool:
    return any(m in path for m in _INFO_MARKERS)


def _rel_worse(key: str, base: float, fresh: float) -> float:
    """How many x worse ``fresh`` is than ``base`` (1.0 = equal, <1 =
    improved), respecting the metric's direction."""
    if base <= 0 or fresh <= 0:
        return 1.0 if fresh == base else float("inf")
    if any(m in key for m in _HIGHER_BETTER):
        return base / fresh
    return fresh / base


def _match_rows(base_rows: list, fresh_rows: list) -> List[Tuple[str, Any, Any]]:
    """Pair rows by 'name'/'mode' identity when available, else by index.
    Baseline rows with no fresh counterpart pair with None (a failure)."""
    def ident(r, i):
        if isinstance(r, dict):
            for k in ("name", "mode"):
                if k in r:
                    return str(r[k])
        return f"[{i}]"

    fresh_by_id = {ident(r, i): r for i, r in enumerate(fresh_rows)}
    return [
        (ident(r, i), r, fresh_by_id.get(ident(r, i)))
        for i, r in enumerate(base_rows)
    ]


def compare(base: Any, fresh: Any, path: str, failures: List[str],
            notes: List[str]) -> None:
    if isinstance(base, dict):
        if not isinstance(fresh, dict):
            failures.append(f"{path}: baseline is a mapping, fresh is "
                            f"{type(fresh).__name__}")
            return
        for k, bv in base.items():
            sub = f"{path}.{k}" if path else k
            if k not in fresh:
                failures.append(f"{sub}: metric disappeared from artifact")
                continue
            compare(bv, fresh[k], sub, failures, notes)
        for k in fresh.keys() - base.keys():
            notes.append(f"{path}.{k}: new metric (pass; pin via --update)")
        return
    if isinstance(base, list):
        if not isinstance(fresh, list):
            failures.append(f"{path}: baseline is a list, fresh is "
                            f"{type(fresh).__name__}")
            return
        for rid, brow, frow in _match_rows(base, fresh):
            sub = f"{path}[{rid}]"
            if frow is None:
                failures.append(f"{sub}: row disappeared from artifact")
                continue
            compare(brow, frow, sub, failures, notes)
        return
    key = path.rsplit(".", 1)[-1]
    if _is_informational(path):
        if fresh != base:
            notes.append(f"{path}: {base!r} -> {fresh!r} (informational "
                         f"resilience counter; not gated)")
        return
    if isinstance(base, bool) or isinstance(base, str) or base is None:
        if fresh != base:
            failures.append(f"{path}: {base!r} -> {fresh!r} (exact metric)")
        return
    if not isinstance(base, (int, float)) or not isinstance(fresh, (int, float)):
        failures.append(f"{path}: type changed {type(base).__name__} -> "
                        f"{type(fresh).__name__}")
        return
    if _is_timing(key):
        worse = _rel_worse(key, float(base), float(fresh))
        if worse > TIME_BAND:
            failures.append(
                f"{path}: {base} -> {fresh} ({worse:.1f}x worse, band "
                f"{TIME_BAND}x)"
            )
        elif worse < 1 / 1.5:
            notes.append(f"{path}: improved {1 / worse:.1f}x "
                         f"({base} -> {fresh}); consider --update")
        return
    if "err" in key:
        if float(fresh) > float(base) * ERR_BAND + 1e-9:
            failures.append(f"{path}: error {base} -> {fresh} "
                            f"(band {ERR_BAND}x)")
        return
    if isinstance(base, int) and not isinstance(base, bool):
        if "bytes" in key:
            if abs(fresh - base) > abs(base) * BYTES_TOL:
                failures.append(f"{path}: {base} -> {fresh} bytes "
                                f"(band {BYTES_TOL:.0%})")
        elif fresh != base:
            failures.append(f"{path}: {base} -> {fresh} (exact count)")
        return
    if abs(float(fresh) - float(base)) > abs(float(base)) * FLOAT_TOL + 1e-9:
        failures.append(f"{path}: {base} -> {fresh} (band {FLOAT_TOL:.0%})")


def gate(artifacts=ARTIFACTS, baseline_dir=BASELINE_DIR, root=ROOT,
         verbose=True) -> List[str]:
    failures: List[str] = []
    notes: List[str] = []
    for name in artifacts:
        base_path = os.path.join(baseline_dir, name)
        fresh_path = os.path.join(root, name)
        if not os.path.exists(base_path):
            failures.append(f"{name}: no committed baseline "
                            f"(run perf_gate.py --update and commit)")
            continue
        if not os.path.exists(fresh_path):
            failures.append(f"{name}: artifact was not generated")
            continue
        with open(base_path) as f:
            base = json.load(f)
        with open(fresh_path) as f:
            fresh = json.load(f)
        for sec in REQUIRED_SECTIONS.get(name, ()):
            if sec not in fresh:
                failures.append(
                    f"{name}: required section '{sec}' missing from artifact"
                )
        compare(base, fresh, name, failures, notes)
    if verbose:
        for n in notes:
            print(f"  note: {n}")
        for fmsg in failures:
            print(f"  FAIL: {fmsg}")
    return failures


def update(artifacts=ARTIFACTS, baseline_dir=BASELINE_DIR, root=ROOT) -> None:
    os.makedirs(baseline_dir, exist_ok=True)
    for name in artifacts:
        src = os.path.join(root, name)
        if not os.path.exists(src):
            print(f"  skip {name}: not generated")
            continue
        shutil.copyfile(src, os.path.join(baseline_dir, name))
        print(f"  pinned {name}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--update", action="store_true",
                    help="pin the current BENCH_*.json as the new baselines")
    args = ap.parse_args(argv)
    if args.update:
        update()
        return 0
    failures = gate()
    if failures:
        print(f"perf gate: {len(failures)} regression(s) vs committed "
              f"baselines (benchmarks/baselines/)")
        return 1
    print("perf gate: all artifacts within tolerance of baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
