"""Distributed correctness on fake multi-device meshes. Each case runs in a
subprocess with its own XLA_FLAGS device count (jax locks the count on first
init, so these cannot share the main test process)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # fake-device meshes live on the host (CPU) platform; pin it so the
    # child never probes a real accelerator plugin (libtpu init can hang
    # when the machine has the plugin but no device)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=timeout,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


PREAMBLE = """
import jax, jax.numpy as jnp, numpy as np, json
from repro.config import get_config, smoke_config, TrainConfig, MoEConfig
from repro.models.model import model_decl, forward, loss_fn
from repro.sharding.rules import FoldingPlan, init_from_decls, shardings_from_decls
from repro.train.trainer import make_train_step
from repro.optim.adamw import adamw_init, opt_state_shardings
"""


def test_sharded_loss_matches_single_device():
    """Same params + batch: loss on a 2x4 mesh == loss on 1 device."""
    out = run_sub(PREAMBLE + """
import dataclasses
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = smoke_config(get_config("qwen3-moe-30b-a3b")).replace(dtype="float32")
# dropless: capacity (and thus token drops) is per-dispatch-group, so a
# finite CF legitimately differs between 1-device and 2x4 layouts
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=None))
decls = model_decl(cfg)
params = init_from_decls(decls, jax.random.PRNGKey(0))
params = jax.tree.map(lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, params)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)}
l1, _ = jax.jit(lambda p, b: loss_fn(cfg, None, p, b))(params, batch)
plan = FoldingPlan.make(cfg, mesh)
with mesh:
    l2, _ = jax.jit(lambda p, b: loss_fn(cfg, plan, p, b))(params, batch)
print(json.dumps({"single": float(l1), "sharded": float(l2)}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert abs(r["single"] - r["sharded"]) < 1e-4, r


def test_all_three_dispatchers_agree():
    """Fixed routing on a 2x4 EP mesh: allgather == alltoall == sorted
    (the two Megatron padded dispatchers and the dropless sorted path)."""
    out = run_sub(PREAMBLE + """
import dataclasses
from repro.core.moe import moe_apply, moe_decl
mesh = jax.make_mesh((2, 4), ("data", "model"))
from repro.config import ModelConfig
moe = MoEConfig(num_experts=4, top_k=2, capacity_factor=None, dispatcher="allgather")
cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=64, num_heads=4,
                  num_kv_heads=4, d_ff=128, vocab_size=256, vocab_divisor=64,
                  dtype="float32", moe=moe)
from repro.sharding.rules import init_from_decls
params = init_from_decls(moe_decl(cfg, moe), jax.random.PRNGKey(0))
params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64)) * 0.3
plan = FoldingPlan.make(cfg, mesh)
ys = {}
with mesh:
    for name in ("allgather", "alltoall", "sorted"):
        moe_n = dataclasses.replace(moe, dispatcher=name)
        ys[name], _ = jax.jit(
            lambda p, x, m=moe_n: moe_apply(cfg, m, plan, p, x))(params, x)
errs = {n: float(jnp.max(jnp.abs(ys["allgather"] - ys[n]))) for n in ys}
print(json.dumps(errs))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert all(v < 1e-4 for v in r.values()), r


def test_online_upcycle_is_collective_free():
    """Paper §3.1: sharded upcycling must not gather expert weights — the
    compiled HLO contains no all-gather/all-reduce on the expansion path."""
    out = run_sub(PREAMBLE + """
from repro.core.upcycle import upcycle_config, upcycle_params, dense_input_shardings
from repro.config import ModelConfig
cfg = ModelConfig(name="d", family="dense", num_layers=4, d_model=64, num_heads=4,
                  num_kv_heads=4, d_ff=128, vocab_size=256, vocab_divisor=64)
mesh = jax.make_mesh((2, 4), ("data", "model"))
moe_cfg = upcycle_config(cfg, MoEConfig(num_experts=8, top_k=2))
plan_d = FoldingPlan.make(cfg, mesh)
plan_m = FoldingPlan.make(moe_cfg, mesh)
decls_d, decls_m = model_decl(cfg), model_decl(moe_cfg)
# paper §3.1: the dense checkpoint is sharded per the MoE parallel config
in_sh = dense_input_shardings(cfg, moe_cfg, plan_d)
params = jax.jit(lambda k: init_from_decls(decls_d, k),
                 out_shardings=in_sh)(jax.random.PRNGKey(0))
fn = jax.jit(lambda dp: upcycle_params(cfg, moe_cfg, dp, jax.random.PRNGKey(1)),
             out_shardings=shardings_from_decls(decls_m, plan_m))
with mesh:
    hlo = fn.lower(params).compile().as_text()
bad = [op for op in ("all-gather", "all-to-all", "collective-permute") if op in hlo]
print(json.dumps({"bad": bad, "has_all_reduce": "all-reduce(" in hlo}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["bad"] == [], r


def test_zero1_opt_state_is_data_sharded():
    out = run_sub(PREAMBLE + """
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = smoke_config(get_config("llama3.2-3b"))
plan = FoldingPlan.make(cfg, mesh)
sh = opt_state_shardings(model_decl(cfg), plan, zero1=True)
specs = [s.spec for s in jax.tree.leaves(sh.m)]
frac = sum(1 for s in specs if any("data" in (p if isinstance(p, tuple) else (p,))
           for p in s if p)) / len(specs)
print(json.dumps({"data_sharded_fraction": frac}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["data_sharded_fraction"] > 0.8, r


def test_multipod_mesh_small_analog():
    """3-axis ('pod','data','model') mesh lowers a train step (the 2-pod
    production dry-run analog at 2x2x2)."""
    out = run_sub(PREAMBLE + """
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = smoke_config(get_config("llama3-e8t2"))
plan = FoldingPlan.make(cfg, mesh)
decls = model_decl(cfg)
params = jax.jit(lambda k: init_from_decls(decls, k),
                 out_shardings=shardings_from_decls(decls, plan))(jax.random.PRNGKey(0))
tcfg = TrainConfig(global_batch=8, seq_len=32)
opt = jax.jit(adamw_init, out_shardings=opt_state_shardings(decls, plan, True))(params)
step = jax.jit(make_train_step(cfg, tcfg, plan), donate_argnums=(0, 1))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}
with mesh:
    hlo = step.lower(params, opt, batch, jax.random.PRNGKey(1)).compile()
    p2, o2, m = step(params, opt, batch, jax.random.PRNGKey(1))
print(json.dumps({"loss": float(m["loss"])}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["loss"] > 0 and r["loss"] < 20


def test_folding_study_mesh_ep8():
    """Paper-study 3-D mesh: E8T2 experts shard the dedicated 'expert' axis
    (true EP8) while attention folds it into the batch group."""
    out = run_sub(PREAMBLE + """
from repro.launch.mesh import make_study_mesh
mesh = make_study_mesh(1, 8, 1)
cfg = smoke_config(get_config("llama3-e8t2"))
import dataclasses
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, num_experts=8))
plan = FoldingPlan.make(cfg, mesh)
from repro.sharding.rules import specs_from_decls
specs = specs_from_decls(model_decl(cfg), plan)
wg_spec = specs["stack"]["slot0"]["ffn"]["experts"]["w_gate"]
print(json.dumps({"moe_mode": plan.moe_mode, "ep_axis": plan.ep_axis,
                  "wg_spec": str(wg_spec)}))
""")
    r = json.loads(out.strip().splitlines()[-1])
    assert r["moe_mode"] == "ep" and r["ep_axis"] == "expert", r
    assert "expert" in r["wg_spec"], r
