"""Autotuner + fused-dispatch suite.

Covers the PR-10 tentpole from both ends:

* ``kernels/autotune.py``: opt-in gating (default off == static ``_pick``
  heuristics), cache determinism across processes, cache-version
  invalidation, lane-misaligned (poisoned) cache-entry rejection, VMEM
  filtering, and modeled-score sanity.
* fused dispatch (``grouped_gemm_fused``/``_q8``): token-for-token parity
  against the unfused scatter -> grouped GEMM -> gather/combine composition
  swept over E/k/D/F, bf16 and f32, int8 weights, and the custom_vjp
  backward (gradients for x, all three expert weights, and the gates).

Kernel-level sweeps run at ``row_block=8`` to keep interpret-mode grids
small; the dispatcher-level test at the production ``KERNEL_ROW_BLOCK=128``
lives in tests/test_dispatch.py.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune
from repro.kernels import expert_gemm as eg

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Each test gets a private cache file and a clean memo; autotuning is
    left OFF unless the test enables it."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "cache.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    monkeypatch.delenv("REPRO_HW_PROFILE", raising=False)
    autotune.reset()
    yield
    autotune.reset()


# ---------------------------------------------------------------------------
# Autotuner unit tests
# ---------------------------------------------------------------------------


def _simple_cost(blocks):
    bf, bd = blocks
    # strictly prefers larger tiles (fewer steps), fits any VMEM
    return {"flops": 1e9, "bytes": 1e6, "steps": (512 // bf) * (512 // bd),
            "vmem_bytes": bf * bd}


def _resolve(key="k1", fallback=(128, 128)):
    return autotune.get_blocks(
        "unit", key, fallback, dims=(512, 512), aligns=(128, 128),
        cost=_simple_cost,
    )


def test_disabled_returns_fallback_untouched():
    assert not autotune.enabled()
    assert _resolve(fallback=(128, 256)) == (128, 256)
    assert autotune.stats() == {"hits": 0, "misses": 0}
    assert not os.path.exists(autotune.cache_path())


def test_enabled_tunes_persists_and_hits(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    win = _resolve()
    assert win == (512, 512)  # fewest steps wins under _simple_cost
    assert autotune.stats() == {"hits": 0, "misses": 1}
    assert _resolve() == win
    assert autotune.stats() == {"hits": 1, "misses": 1}
    data = json.load(open(autotune.cache_path()))
    assert data["version"] == autotune.CACHE_VERSION
    entry = data["profiles"]["v5e"]["k1"]
    assert entry["blocks"] == [512, 512]
    assert entry["source"] == "modeled"


def test_cache_determinism_across_processes(tmp_path, monkeypatch):
    """Same key -> same winner from a cold process reading the same cache
    file (the cross-process contract the persistent cache exists for)."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    win = _resolve()
    prog = (
        "import json, os\n"
        "from repro.kernels import autotune\n"
        "def cost(blocks):\n"
        "    bf, bd = blocks\n"
        "    return {'flops': 1e9, 'bytes': 1e6,"
        " 'steps': (512 // bf) * (512 // bd), 'vmem_bytes': bf * bd}\n"
        "w = autotune.get_blocks('unit', 'k1', (128, 128), dims=(512, 512),"
        " aligns=(128, 128), cost=cost)\n"
        "print(json.dumps({'win': list(w), 'stats': autotune.stats()}))\n"
    )
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(sys.path))
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, check=True)
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert tuple(got["win"]) == win
    assert got["stats"] == {"hits": 1, "misses": 0}  # served from disk


def test_cache_version_invalidation(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    path = autotune.cache_path()
    with open(path, "w") as f:
        json.dump({"version": autotune.CACHE_VERSION - 1,
                   "profiles": {"v5e": {"k1": {"blocks": [128, 128]}}}}, f)
    autotune.reset()
    assert _resolve() == (512, 512)  # stale version ignored, re-tuned
    assert autotune.stats()["misses"] == 1
    assert json.load(open(path))["version"] == autotune.CACHE_VERSION


def test_poisoned_misaligned_cache_entry_rejected(monkeypatch):
    """A cached winner that fails the lane-alignment validation (e.g. a
    hand-edited or corrupted entry) must be dropped and re-tuned, never
    handed to a kernel."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    path = autotune.cache_path()
    with open(path, "w") as f:
        json.dump({"version": autotune.CACHE_VERSION, "profiles": {"v5e": {
            "k1": {"v": 1, "blocks": [96, 512]},     # 96 is lane-misaligned
            "k2": {"v": 1, "blocks": [512, 768]},    # 768 doesn't divide 512
            "k3": {"v": 1, "blocks": [512]},         # wrong arity
        }}}, f)
    autotune.reset()
    for key in ("k1", "k2", "k3"):
        assert _resolve(key=key) == (512, 512)
    assert autotune.stats() == {"hits": 0, "misses": 3}


def test_vmem_filter_and_whole_dim_fallback(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setenv("REPRO_HW_PROFILE", "cpu")  # 8 MB budget

    def cost(blocks):
        (b,) = blocks
        return {"flops": 1.0, "bytes": 1.0, "steps": 1024 // b,
                "vmem_bytes": b * 64 * 1024}  # 512-tile = 32 MB: over budget

    win = autotune.get_blocks("unit", "kv", (64,), dims=(1024,), aligns=(8,),
                              cost=cost)
    assert win[0] * 64 * 1024 <= 0.7 * 8e6
    # a dim with no aligned pool divisor still yields the whole-dim tile
    assert list(autotune.candidates((282,), (8,))) == [(282,)]


def test_validate_blocks_contract():
    ok = autotune.validate_blocks
    assert ok((512, 256), (512, 512), (128, 128))
    assert ok((282,), (282,), (8,))          # whole sublane dim, any size
    assert ok((96,), (96,), (128,))          # whole lane dim: compiler pads
    assert not ok((96,), (192,), (128,))     # misaligned lane split
    assert not ok((48,), (96,), (128,))
    assert ok((3,), (9,), (8,))              # sublane divisor: legal
    assert not ok((100,), (512,), (128,))    # non-divisor
    assert not ok((512,), (512, 512), (128, 128))  # arity


def test_hw_profile_selection(monkeypatch):
    from repro.roofline.analysis import HW_PROFILES, hw_profile

    assert hw_profile() == HW_PROFILES["v5e"]
    monkeypatch.setenv("REPRO_HW_PROFILE", "v5p")
    assert hw_profile() == HW_PROFILES["v5p"]
    assert hw_profile("cpu") == HW_PROFILES["cpu"]
    with pytest.raises(ValueError, match="unknown hardware profile"):
        hw_profile("v9000")
    for prof in HW_PROFILES.values():
        assert {"peak_flops", "hbm_bw", "ici_bw", "vmem_bytes"} <= set(prof)


def test_modeled_score_monotone_in_hw(monkeypatch):
    """The same candidate costs less on the faster chip — the autotuner's
    cost model actually consumes the selected hardware profile."""
    from repro.roofline.analysis import hw_profile

    s_v5e = autotune.modeled_seconds(1e12, 1e9, 0, hw_profile("v5e"))
    s_v5p = autotune.modeled_seconds(1e12, 1e9, 0, hw_profile("v5p"))
    assert s_v5p < s_v5e


# ---------------------------------------------------------------------------
# Fused dispatch parity
# ---------------------------------------------------------------------------


def _routing(rng, E, k, T, bc):
    """Sorted-dispatcher index vectors for random top-k routing, mirroring
    SortedDispatcher._indices at row_block=bc."""
    N = T * k
    # distinct experts per token (a (token, slot) pair is unique by
    # construction; distinct experts also make gates meaningful)
    idx = np.stack([rng.permutation(E)[:k] for _ in range(T)])
    flat_e = jnp.asarray(idx.reshape(N).astype(np.int32))
    gates = jnp.asarray(rng.uniform(0.2, 1.0, size=(N,)).astype(np.float32))
    order = jnp.argsort(flat_e, stable=True)
    token = (order // k).astype(jnp.int32)
    slot = (order % k).astype(jnp.int32)
    sorted_e = flat_e[order]
    gs = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    padded = ((gs + bc - 1) // bc) * bc
    starts_pad = jnp.cumsum(padded) - padded
    starts = jnp.cumsum(gs) - gs
    pos = jnp.arange(N, dtype=jnp.int32) - starts[sorted_e]
    dest = (starts_pad[sorted_e] + pos).astype(jnp.int32)
    return token, slot, dest, gates[order], gs


def _weights(rng, E, D, F, dtype):
    mk = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32) * 0.1).astype(dtype)
    return mk(E, D, F), mk(E, D, F), mk(E, F, D)


FUSED_CASES = [
    # (E, k, T, D, F)
    (2, 1, 16, 128, 128),
    (4, 2, 16, 128, 256),
    (4, 2, 24, 256, 128),
    (8, 2, 16, 256, 256),
]


@pytest.mark.parametrize("E,k,T,D,F", FUSED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_forward_matches_unfused(E, k, T, D, F, dtype):
    rng = np.random.default_rng(hash((E, k, T, D, F)) % 2**31)
    bc = 8
    blocks = (bc, 256, 256)
    x = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32)).astype(dtype)
    wg, wu, wd = _weights(rng, E, D, F, dtype)
    token, slot, dest, gate_sorted, gs = _routing(rng, E, k, T, bc)

    y_ref = eg._fused_unfused_ref(
        x, wg, wu, wd, gs, token, dest, slot, gate_sorted, blocks, True
    )
    y = eg.grouped_gemm_fused(
        x, wg, wu, wd, gs, token, dest, slot, gate_sorted,
        blocks=blocks, interpret=True,
    )
    assert y.dtype == x.dtype and y.shape == (T, D)
    # bf16: the fused path rounds slot partials to bf16 before the f32
    # k-way sum while the ref rounds after the gather — accumulation-order
    # noise of a few ulps, so the bf16 budget needs a relative term
    atol, rtol = (1e-5, 0.0) if dtype == jnp.float32 else (3e-2, 2e-2)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        atol=atol, rtol=rtol,
    )


@pytest.mark.parametrize("E,k,T,D,F", FUSED_CASES[:2])
def test_fused_backward_matches_unfused(E, k, T, D, F):
    """custom_vjp gradients (x, all expert weights, gates) match jax.grad
    through the unfused composition — the fused path must be a drop-in for
    training, not just decode."""
    rng = np.random.default_rng(7)
    bc = 8
    blocks = (bc, 256, 256)
    x = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
    wg, wu, wd = _weights(rng, E, D, F, jnp.float32)
    token, slot, dest, gate_sorted, gs = _routing(rng, E, k, T, bc)

    def loss_fused(x, wg, wu, wd, g):
        y = eg.grouped_gemm_fused(x, wg, wu, wd, gs, token, dest, slot, g,
                                  blocks=blocks, interpret=True)
        return jnp.sum(jnp.square(y))

    def loss_ref(x, wg, wu, wd, g):
        y = eg._fused_unfused_ref(x, wg, wu, wd, gs, token, dest, slot, g,
                                  blocks, True)
        return jnp.sum(jnp.square(y))

    gf = jax.grad(loss_fused, argnums=(0, 1, 2, 3, 4))(x, wg, wu, wd, gate_sorted)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3, 4))(x, wg, wu, wd, gate_sorted)
    for name, a, b in zip(("x", "wg", "wu", "wd", "gates"), gf, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-4, err_msg=name
        )
        assert float(jnp.sum(jnp.abs(a))) > 0, name


def test_fused_q8_matches_unfused_q8():
    rng = np.random.default_rng(11)
    E, k, T, D, F = 4, 2, 16, 256, 256
    bc = 8
    blocks = (bc, 256, 256)
    x = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
    wg, wu, wd = _weights(rng, E, D, F, jnp.float32)

    def q8(w, axis):
        s = jnp.max(jnp.abs(w), axis=axis, keepdims=True) / 127.0
        return jnp.round(w / s).astype(jnp.int8), jnp.squeeze(s, axis)

    wg_q, sg = q8(wg, 1)
    wu_q, su = q8(wu, 1)
    wd_q, sd = q8(wd, 1)
    token, slot, dest, gate_sorted, gs = _routing(rng, E, k, T, bc)

    N = T * k
    N_pad = eg._aligned_rows(N, E, bc)
    xs = jnp.zeros((N_pad, D), x.dtype).at[dest].set(x[token])
    ys = eg.grouped_gemm_q8(xs, wg_q, wu_q, wd_q, sg, su, sd, gs,
                            blocks=blocks, interpret=True)
    yv = ys[dest].astype(jnp.float32) * gate_sorted[:, None]
    y_ref = jnp.zeros((T, D), jnp.float32).at[token].add(yv)

    y = eg.grouped_gemm_fused_q8(
        x, wg_q, wu_q, wd_q, sg, su, sd, gs, token, dest, slot, gate_sorted,
        blocks=blocks, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


def test_fused_residuals_are_inputs_only():
    """The fused VJP saves token-major inputs and O(N) index vectors only:
    no (N_pad, D) dispatch buffer, no (N_pad, F) intermediate."""
    rng = np.random.default_rng(3)
    E, k, T, D, F = 4, 2, 16, 128, 256
    bc = 8
    x = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
    wg, wu, wd = _weights(rng, E, D, F, jnp.float32)
    token, slot, dest, gate_sorted, gs = _routing(rng, E, k, T, bc)
    leaves = eg.fused_moe_residuals(x, wg, wu, wd, gs, token, dest, slot,
                                    gate_sorted, blocks=(bc, 256, 256))
    N_pad = eg._aligned_rows(T * k, E, bc)
    shapes = {tuple(l.shape) for l in leaves}
    assert (T, D) in shapes
    assert (N_pad, D) not in shapes and (N_pad, F) not in shapes
    big = [s for s in shapes if len(s) == 2 and s[0] > T]
    assert not big, big


def test_fused_ops_wrapper_roundtrip():
    """The ops-level wrapper (autotune hook + interpret selection) matches
    the kernel called directly."""
    from repro.kernels import ops

    rng = np.random.default_rng(5)
    E, k, T, D, F = 4, 2, 64, 128, 256
    bc = 128  # production row block through the public wrapper
    x = jnp.asarray(rng.normal(size=(T, D)).astype(np.float32))
    wg, wu, wd = _weights(rng, E, D, F, jnp.float32)
    token, slot, dest, gate_sorted, gs = _routing(rng, E, k, T, bc)
    y_ops = ops.grouped_gemm_fused(x, wg, wu, wd, gs, token, dest, slot,
                                   gate_sorted, row_block=bc)
    y_eg = eg.grouped_gemm_fused(x, wg, wu, wd, gs, token, dest, slot,
                                 gate_sorted, blocks=(bc, 512, 512),
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(y_ops), np.asarray(y_eg), atol=1e-6)
