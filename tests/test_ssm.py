"""Mamba-2 SSD: chunked algorithm vs naive recurrence, decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, SSMConfig
from repro.models.ssm import ssd_chunked, ssm_apply, ssm_cache_decl, ssm_decl
from repro.sharding.rules import ParamDecl, init_from_decls


def naive_ssd(x, dt, A, Bm, Cm):
    """Literal per-step recurrence oracle."""
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    Bh = np.repeat(np.asarray(Bm, np.float64), rep, axis=2)  # (b,l,h,n)
    Ch = np.repeat(np.asarray(Cm, np.float64), rep, axis=2)
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Af = np.asarray(A, np.float64)
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, l, h, p))
    for t in range(l):
        dA = np.exp(dtf[:, t] * Af)  # (b,h)
        inp = (xf[:, t] * dtf[:, t][..., None])[..., None] * Bh[:, t][:, :, None, :]
        state = state * dA[..., None, None] + inp
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch[:, t])
    return ys, state


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_naive(rng, chunk):
    b, l, h, p, g, n = 2, 16, 4, 8, 2, 16
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, l, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    y, st = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y_ref, st_ref = naive_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), st_ref, atol=1e-4)


def test_ssd_init_state_continuation(rng):
    """Processing [a;b] == processing a then b with the carried state."""
    b, l, h, p, g, n = 1, 16, 2, 4, 1, 8
    mk = lambda shape: jnp.asarray(rng.standard_normal(shape), jnp.float32)
    x, Bm, Cm = mk((b, l, h, p)), mk((b, l, g, n)), mk((b, l, g, n))
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (b, l, h)), jnp.float32)
    A = -jnp.ones((h,), jnp.float32)
    y_full, st_full = ssd_chunked(x, dt, A, Bm, Cm, 4)
    y1, st1 = ssd_chunked(x[:, :8], dt[:, :8], A, Bm[:, :8], Cm[:, :8], 4)
    y2, st2 = ssd_chunked(x[:, 8:], dt[:, 8:], A, Bm[:, 8:], Cm[:, 8:], 4, init_state=st1)
    np.testing.assert_allclose(np.asarray(y_full[:, :8]), np.asarray(y1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_full[:, 8:]), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2), atol=1e-5)


def _cfg():
    return ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=64, num_heads=0,
        num_kv_heads=0, d_ff=0, vocab_size=128, vocab_divisor=64, dtype="float32",
        ssm=SSMConfig(d_state=16, headdim=16, ngroups=2, chunk_size=8),
    )


def test_ssm_block_decode_matches_train(rng):
    cfg = _cfg()
    params = init_from_decls(ssm_decl(cfg), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    x = jnp.asarray(rng.standard_normal((2, 16, 64)), jnp.float32) * 0.5
    y_train, cache_out = ssm_apply(cfg, None, params, x, return_state=True)
    cd = ssm_cache_decl(cfg, 2)
    cache = jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype), cd, is_leaf=lambda d: isinstance(d, ParamDecl)
    )
    ys = []
    for t in range(16):
        yt, cache = ssm_apply(cfg, None, params, x[:, t : t + 1], cache=cache)
        ys.append(yt)
    y_dec = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(cache_out["state"]), np.asarray(cache["state"]), atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(cache_out["conv"]), np.asarray(cache["conv"]), atol=1e-5
    )
