"""Property tests for the page pool and chunked-prefill scheduler: random
arrival / prompt-length / eos streams never leak pages (freed == allocated
at drain), never double-assign a page, respect the free-page admission
budget, and every submitted request terminates.

The simulation core runs model-free (the scheduler is pure policy). A
seeded sweep always runs; when hypothesis is installed the same core is
driven by generated streams as well (CI installs it)."""
import numpy as np
import pytest

from repro.serving.kv_cache import PagePool
from repro.serving.scheduler import ChunkedScheduler, SchedulerConfig

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _check_tables(sched: ChunkedScheduler) -> None:
    """Every block-table entry maps to a page the slot's request holds
    (private, or shared-referenced via the prefix cache), a *private* page
    never appears in two tables (no double-assign; shared pages appear in
    as many tables as their refcount), and under ``dp_shards > 1`` each
    resident request is pinned to its slot's shard."""
    seen = {}
    shared = set(sched.pool._shared)
    for slot, req in sched.running.items():
        pinned = sched.pool.shard_of(req.rid)
        assert pinned in (None, sched.shard_of_slot(slot)), (
            f"slot {slot} (shard {sched.shard_of_slot(slot)}) holds request "
            f"{req.rid} pinned to shard {pinned}"
        )
        held = set(sched.pool.owned(req.rid)) | set(sched.pool.refs(req.rid))
        row = sched.tables[slot]
        live = row[row >= 0]
        assert len(set(live)) == len(live), f"slot {slot} repeats a page"
        for p in live:
            assert int(p) in held, f"slot {slot} maps unheld page {p}"
            if int(p) in shared:
                continue  # sharing across slots is exactly the point
            assert p not in seen, f"page {p} in slots {seen[p]} and {slot}"
            seen[p] = slot
    # idle slots are fully cleared
    for slot in range(sched.cfg.max_batch):
        if slot not in sched.running:
            assert (sched.tables[slot] == -1).all()


def simulate(seed, num_pages=12, ps=4, max_batch=3, chunk=8, window=None,
             n_req=8, watermark=1, eos_p=0.05, defrag_every=0, max_steps=3000,
             dp_shards=1, prefix=False):
    """Drive the scheduler with a random stream; returns summary stats.
    Token values are irrelevant to the policy layer, so 'decode' here is
    just the bookkeeping calls the engine would make. With ``prefix=True``
    requests carry token arrays drawn from a tiny set of shared prefixes,
    the prefix cache is enabled, and prefill completion is reported via
    ``note_prefilled`` (as the engine would)."""
    rng = np.random.default_rng(seed)
    pool = PagePool(num_pages, ps, num_shards=dp_shards)
    if prefix:
        pool.enable_prefix_cache()
    maxP = 16
    sched = ChunkedScheduler(
        SchedulerConfig(max_batch, ps, chunk, max_pages_per_seq=maxP,
                        watermark=watermark, window=window,
                        dp_shards=dp_shards),
        pool,
    )
    # a few shared prefixes so concurrent requests actually collide
    stems = [rng.integers(0, 50, size=int(rng.integers(1, 3 * ps)))
             for _ in range(3)]
    pending = []
    for rid in range(n_req):
        p, m = int(rng.integers(1, 20)), int(rng.integers(1, 10))
        if (pool.pages_for(p + m) <= maxP
                and sched._live_bound(p + m) <= pool.pages_per_shard):
            toks = None
            if prefix:
                stem = stems[int(rng.integers(0, len(stems)))][:p]
                tail = rng.integers(0, 50, size=p - len(stem))
                toks = np.concatenate([stem, tail]).astype(np.int32)
            pending.append((rid, p, m, toks))
    submitted, finished = set(), set()
    steps = preemptions = 0
    while (pending or sched.has_work) and steps < max_steps:
        steps += 1
        while pending and rng.random() < 0.5:
            rid, p, m, toks = pending.pop(0)
            sched.submit(rid, p, m, tokens=toks)
            submitted.add(rid)
        plan = sched.plan()
        preemptions += len(plan.preempted)
        # COW clones target a page the destination request privately owns
        for src, dst in plan.cow_copies:
            assert src in pool._shared
            assert any(dst in pool.owned(r.rid)
                       for r in sched.running.values())
        pool.check_invariants()
        _check_tables(sched)
        for c in plan.prefills:
            if prefix:
                sched.note_prefilled(c.rid, c.start + c.length)
            if c.final:
                req = sched.running[c.slot]
                done = req.generated + 1 >= req.max_new_tokens or rng.random() < eos_p
                sched.on_token(c.slot, done)
                if done:
                    finished.add(c.rid)
        for slot in plan.decode_slots:
            req = sched.running[slot]
            done = req.generated + 1 >= req.max_new_tokens or rng.random() < eos_p
            sched.on_token(slot, done)
            if done:
                finished.add(req.rid)
        if defrag_every and steps % defrag_every == 0:
            mapping = pool.defrag()
            if mapping:
                sched.apply_defrag(mapping)
            pool.check_invariants()
            _check_tables(sched)
    # termination: every submitted request finishes within the step bound
    assert not sched.has_work and not pending, f"live work after {steps} steps"
    assert finished == submitted
    # no leak: freed == allocated at drain — in every shard's sub-pool
    assert not pool._owned
    if prefix:
        # drained: nothing referenced, every cached page at refcount zero
        assert not pool._refs
        assert all(r == 0 for r in pool._shared.values())
        assert pool.free_pages == num_pages - pool.shared_pages
        pool.drop_prefix_cache()
        assert not pool._shared and not pool._evictable
        pool.check_invariants()
    assert pool.free_pages == num_pages
    for s in range(pool.num_shards):
        assert pool.free_pages_in(s) == pool.pages_per_shard, f"shard {s} leaked"
    return {"steps": steps, "preemptions": preemptions,
            "prefix_hits": pool.prefix.hits if prefix else 0}


@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("window", [None, 6])
def test_random_streams_keep_invariants(seed, window):
    simulate(seed, window=window)


@pytest.mark.parametrize("seed", range(6))
def test_tight_pool_preempts_but_terminates(seed):
    stats = simulate(seed, num_pages=7, max_batch=3, n_req=10)
    assert stats["steps"] < 3000


def test_defrag_mid_stream_keeps_invariants():
    for seed in range(6):
        simulate(seed, defrag_every=3)


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("dp_shards", [2, 4])
def test_sharded_streams_keep_invariants(seed, dp_shards):
    """EP x DP pool partition: random streams through per-shard sub-pools
    keep every invariant (per-shard used/free sums to the aggregate, pages
    never cross a request's pinned shard) and drain every shard clean."""
    simulate(seed, num_pages=16, max_batch=2 * dp_shards, dp_shards=dp_shards,
             n_req=10)


@pytest.mark.parametrize("seed", range(4))
def test_tight_sharded_pool_preempts_but_terminates(seed):
    """Page pressure inside one shard evicts same-shard victims only; the
    per-shard oldest request always progresses, so the stream terminates."""
    stats = simulate(seed, num_pages=12, ps=2, max_batch=4, dp_shards=2,
                     n_req=10)
    assert stats["steps"] < 3000


def test_sharded_defrag_and_window_streams():
    for seed in range(4):
        simulate(seed, num_pages=16, max_batch=4, dp_shards=2, defrag_every=3)
        simulate(seed, num_pages=16, max_batch=4, dp_shards=2, window=6)


def test_per_shard_bytes_accounting_sums_to_aggregate():
    """kv_bytes_resident_per_shard partitions kv_bytes_resident exactly, at
    every allocation state."""
    from conftest import tiny_dense
    from repro.serving.kv_cache import (
        kv_bytes_resident,
        kv_bytes_resident_per_shard,
    )

    cfg = tiny_dense()
    pool = PagePool(12, 4, num_shards=3)
    pool.alloc(0, 3, shard=0)
    pool.alloc(1, 2, shard=2)
    for state in range(3):
        per = kv_bytes_resident_per_shard(cfg, pool)
        assert len(per) == 3
        assert sum(per) == kv_bytes_resident(cfg, pool)
        if state == 0:
            assert per[1] == 0 and per[0] > per[2] > 0
            pool.alloc(2, 4, shard=1)
        elif state == 1:
            pool.free_request(0)
    assert kv_bytes_resident_per_shard(cfg, pool)[0] == 0


def test_admission_respects_free_page_budget():
    """watermark + committed-prefill reservation: a second large prompt is
    NOT admitted into pages the first one still needs."""
    pool = PagePool(10, 4)
    sched = ChunkedScheduler(
        SchedulerConfig(max_batch=4, page_size=4, prefill_chunk=8,
                        max_pages_per_seq=8, watermark=2),
        pool,
    )
    sched.submit(0, 24, 2)  # needs 6 pages; 10 - 2 >= 6 -> admitted
    sched.submit(1, 24, 2)  # 6 committed to rid 0 -> 10 - 2 - 6 < 6 -> queued
    plan = sched.plan()
    assert {r.rid for r in sched.running.values()} == {0}
    assert [c.rid for c in plan.prefills] == [0]
    # free pages never dip below the watermark through rid 0's whole life
    while sched.has_work:
        plan = sched.plan()
        for c in plan.prefills:
            if c.final:
                sched.on_token(c.slot, sched.running[c.slot].generated + 1 >= 2)
        for slot in plan.decode_slots:
            sched.on_token(slot, sched.running[slot].generated + 1 >= 2)
        running = {r.rid for r in sched.running.values()}
        if 1 in running:
            break
        if 0 in running:
            assert pool.free_pages >= 2, "admission watermark violated"
    pool.check_invariants()


def test_pool_rejects_oversized_request():
    pool = PagePool(4, 4)
    sched = ChunkedScheduler(
        SchedulerConfig(max_batch=2, page_size=4, prefill_chunk=8,
                        max_pages_per_seq=32, watermark=0),
        pool,
    )
    with pytest.raises(ValueError):
        sched.submit(0, 40, 8)  # 12 pages > pool of 4
    # ... but the same span fits a window pool holding window + chunk live
    # tokens (dead pages recycle as decode advances)
    sched_w = ChunkedScheduler(
        SchedulerConfig(max_batch=2, page_size=4, prefill_chunk=8,
                        max_pages_per_seq=32, watermark=0, window=8),
        PagePool(5, 4),
    )
    sched_w.submit(0, 40, 8)


def test_pagepool_alloc_free_defrag_unit():
    pool = PagePool(8, 4)
    a = pool.alloc(1, 3)
    b = pool.alloc(2, 2)
    assert a is not None and b is not None and not set(a) & set(b)
    assert pool.alloc(3, 4) is None and pool.free_pages == 3  # no partial
    pool.release(1, [a[1]])
    pool.check_invariants()
    pool.free_request(2)
    mapping = pool.defrag()
    pool.check_invariants()
    assert pool.used_pages == 2
    owned = pool.owned(1)
    assert sorted(owned) == [0, 1]
    if mapping:
        assert all(new < 2 for new in mapping.values())


def test_zero_alloc_is_pure_noop():
    """alloc(rid, 0) returns [] without touching ANY pool state — no owner
    record, no shard pin, no free-list movement; negative n is a caller bug."""
    pool = PagePool(8, 4, num_shards=2)
    before = (pool.free_pages, dict(pool._shard_of), dict(pool._owned))
    assert pool.alloc(7, 0) == []
    assert pool.alloc(7, 0, shard=1) == []
    assert (pool.free_pages, dict(pool._shard_of), dict(pool._owned)) == before
    assert pool.shard_of(7) is None  # no pin from the empty alloc
    pool.check_invariants()
    with pytest.raises(AssertionError):
        pool.alloc(7, -1)


def test_release_to_zero_keeps_shard_pin():
    """A live request that transiently drops to zero pages stays pinned to
    its shard: the next alloc must come from the same sub-pool. Only
    free_request drops the pin."""
    pool = PagePool(8, 2, num_shards=2)
    pages = pool.alloc(3, 2, shard=1)
    pool.release(3, pages)
    assert pool.owned(3) == [] and pool.free_pages == 8
    assert pool.shard_of(3) == 1, "pin dropped on transient zero pages"
    with pytest.raises(AssertionError):
        pool.alloc(3, 1, shard=0)  # wrong shard: the pin still guards
    again = pool.alloc(3, 1, shard=pool.shard_of(3))
    assert again and all(pool.shard_of_page(p) == 1 for p in again)
    pool.check_invariants()
    pool.free_request(3)
    assert pool.shard_of(3) is None
    pool.check_invariants()


def test_shared_page_refcounts():
    """Refcounted sharing: a page with refcount > 0 is never freed or
    reclaimed, COW detaches the reader instead of mutating the shared page,
    and a full drain leaves every cached page at refcount zero."""
    pool = PagePool(6, 2)
    cache = pool.enable_prefix_cache()
    toks = np.arange(4, dtype=np.int32)  # two full pages
    a = pool.alloc(0, 2)
    cache.insert(0, toks, 2, np.array(a, np.int32))  # promote both pages
    assert pool.owned(0) == [] and pool.refs(0) == a
    assert pool.refcount(a[0]) == pool.refcount(a[1]) == 1
    hit = cache.acquire(1, toks, 0)  # rid 1 shares the whole prefix
    assert hit == a and pool.refcount(a[0]) == 2
    pool.check_invariants()
    # referenced pages are NOT reclaimable: a too-big alloc must fail
    # rather than steal a shared page (4 free + 0 evictable < 5)
    assert pool.alloc(2, 5) is None
    assert pool.refcount(a[0]) == 2
    # COW: rid 1 diverges at the last page — fresh private page, shared
    # page keeps serving rid 0
    fresh = pool.cow(1, a[1])
    assert fresh is not None and fresh != a[1]
    assert pool.refcount(a[1]) == 1 and fresh in pool.owned(1)
    assert pool.cow_clones == 1
    pool.check_invariants()
    # drain: refcounts fall to zero, pages become evictable (cached), and
    # only then can allocation pressure reclaim them (leaf-first)
    pool.free_request(0)
    pool.free_request(1)
    assert pool.refcount(a[0]) == 0 and not pool._refs
    assert pool.evictable_pages == 2
    pool.check_invariants()
    big = pool.alloc(3, 6)  # needs every page -> evicts both cached ones
    assert big is not None and len(big) == 6
    assert pool.shared_pages == 0
    pool.check_invariants()


@pytest.mark.parametrize("seed", range(8))
def test_prefix_streams_keep_invariants(seed):
    """Shared-prefix traffic through the radix cache: refcount/table
    invariants hold at every step and the drain leaves only refcount-zero
    cached pages behind."""
    simulate(seed, prefix=True, n_req=10)


def test_prefix_streams_actually_hit():
    hits = sum(simulate(s, prefix=True, n_req=10, ps=2)["prefix_hits"]
               for s in range(8))
    assert hits > 0, "prefix traffic never hit the cache across 8 seeds"


@pytest.mark.parametrize("seed", range(4))
def test_tight_prefix_pool_reclaims_and_terminates(seed):
    """Under page pressure the allocator reclaims cached (refcount-zero)
    pages leaf-first instead of stalling admission."""
    simulate(seed, num_pages=7, max_batch=3, n_req=10, prefix=True)


def test_prefix_defrag_and_sharded_streams():
    for seed in range(4):
        simulate(seed, prefix=True, defrag_every=3)
        simulate(seed, num_pages=16, max_batch=4, dp_shards=2, prefix=True,
                 n_req=10)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        num_pages=st.integers(4, 24),
        ps=st.sampled_from([1, 2, 4, 8]),
        max_batch=st.integers(1, 4),
        chunk=st.sampled_from([1, 4, 8, 16]),
        window=st.one_of(st.none(), st.integers(2, 12)),
        prefix=st.booleans(),
    )
    def test_hypothesis_streams(seed, num_pages, ps, max_batch, chunk, window,
                                prefix):
        if prefix:
            window = None  # prefix cache requires full attention
        simulate(seed, num_pages=num_pages, ps=ps, max_batch=max_batch,
                 chunk=chunk, window=window, n_req=6, prefix=prefix)
