"""Beyond-paper extensions: Expert-Choice routing (Zhou et al., cited by
the paper's §2), expert-noise upcycling (He et al. [10]), the serving
engine, and the roofline analyzer on a known program."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import init_model, tiny_dense
from repro.config import ModelConfig, MoEConfig
from repro.core.moe import capacity, expert_choice_tables, moe_apply, moe_decl
from repro.sharding.rules import init_from_decls


def _ec_cfg(E=4, C_factor=2.0):
    moe = MoEConfig(num_experts=E, top_k=2, capacity_factor=C_factor,
                    router_type="expert_choice")
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=128,
                      vocab_divisor=64, dtype="float32", moe=moe)
    return cfg, moe


def test_expert_choice_perfect_balance():
    """Every expert processes exactly C tokens — balanced by construction."""
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (64, 4)), -1)
    sel, gate = expert_choice_tables(probs, E=4, C=16)
    assert sel.shape == (4, 16) and gate.shape == (4, 16)
    assert bool(jnp.all(gate > 0))  # every slot filled
    # selected gates are each expert's top scores
    for e in range(4):
        thresh = float(jnp.min(gate[e]))
        assert int(jnp.sum(probs[:, e] > thresh)) <= 16


def test_expert_choice_moe_runs_and_trains():
    cfg, moe = _ec_cfg()
    params = init_from_decls(moe_decl(cfg, moe), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.3

    def loss(p):
        y, aux = moe_apply(cfg, moe, None, p, x)
        return jnp.sum(jnp.square(y)) + sum(aux.values())

    l, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l))
    gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    # router receives gradient (EC is differentiable through the gates)
    assert float(jnp.sum(jnp.abs(g["router"]["w_g"]))) > 0


def test_expert_noise_breaks_symmetry_but_stays_close():
    from repro.core.upcycle import upcycle_config, upcycle_params
    from repro.models.model import forward

    cfg = tiny_dense(num_layers=2, dtype="float32")
    dp = init_model(cfg, fp32=True)
    moe_c = upcycle_config(cfg, MoEConfig(num_experts=4, top_k=2, capacity_factor=None))
    mp = upcycle_params(cfg, moe_c, dp, jax.random.PRNGKey(1), expert_noise=0.01)
    wg = np.asarray(mp["stack"]["slot0"]["ffn"]["experts"]["w_gate"], np.float32)
    # experts now differ...
    assert not np.array_equal(wg[:, 0], wg[:, 1])
    # ...but the function stays near the dense one (small perturbation)
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16)}
    ld, _ = forward(cfg, None, dp, batch)
    lm, _ = forward(moe_c, None, mp, batch)
    rel = float(jnp.max(jnp.abs(ld - lm)) / (jnp.max(jnp.abs(ld)) + 1e-9))
    assert 0 < rel < 0.05, rel


def test_serving_engine_end_to_end():
    from repro.serving.engine import Request, ServingEngine

    cfg = tiny_dense(num_layers=2, dtype="float32")
    params = init_model(cfg, fp32=True)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                max_new_tokens=5 + i)
        for i in range(5)  # 5 requests through 2 slots -> refill path
    ]
    out = eng.run(reqs)
    assert set(out) == {0, 1, 2, 3, 4}
    for i, toks in out.items():
        assert len(toks) == 5 + i
        assert all(0 <= t < cfg.vocab_size for t in toks)
    # greedy + deterministic: resubmitting the same prompt reproduces output
    eng2 = ServingEngine(cfg, params, max_batch=2, max_seq=48)
    out2 = eng2.run([Request(rid=0, prompt=reqs[0].prompt, max_new_tokens=5)])
    assert out2[0] == out[0][:5]


def test_roofline_analyzer_known_program():
    """The trip-count-aware analyzer gets scan FLOPs exactly right where
    XLA's builtin is wrong by the trip count."""
    from repro.roofline.hlo_analysis import analyze

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    compiled = jax.jit(scanned).lower(x, ws).compile()
    got = analyze(compiled.as_text()).flops
    assert got == 6 * 2 * 64**3, got
    ca = compiled.cost_analysis()  # list of per-device dicts on older jax
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    builtin = float(ca.get("flops", 0))
    assert builtin < got  # documents the builtin undercount
