"""Config system: all assigned architectures load, counts match the
published models, smoke reductions stay in the same family."""
import pytest

from repro.config import ARCH_IDS, SHAPES, get_config, smoke_config

# published (total, active) in billions; tolerance is loose because we count
# exactly what we implement (biases, norms, routers included).
PUBLISHED = {
    "mamba2-2.7b": (2.7, 2.7),
    "minicpm3-4b": (4.0, 4.0),
    "llama3.2-3b": (3.2, 3.2),
    "stablelm-1.6b": (1.6, 1.6),
    "jamba-1.5-large-398b": (398.0, 94.0),
    "qwen3-moe-30b-a3b": (30.5, 3.3),
    "llava-next-34b": (34.4, 34.4),
    "qwen2.5-14b": (14.7, 14.7),
    "arctic-480b": (480.0, 17.0),
    "llama3-8b": (8.0, 8.0),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    t, a = cfg.param_counts()
    assert t >= a > 0
    assert cfg.padded_vocab % cfg.vocab_divisor == 0
    assert cfg.padded_vocab >= cfg.vocab_size


@pytest.mark.parametrize("arch", sorted(PUBLISHED))
def test_param_counts_match_published(arch):
    cfg = get_config(arch)
    t, a = cfg.param_counts()
    pt, pa = PUBLISHED[arch]
    assert abs(t / 1e9 - pt) / pt < 0.20, (arch, t / 1e9, pt)
    assert abs(a / 1e9 - pa) / pa < 0.20, (arch, a / 1e9, pa)


def test_e8t2_flops_ratio_table1():
    """Paper Table 1: E8T2 uses ~1.6x the dense FLOPs despite ~4-6x params."""
    dense = get_config("llama3-8b")
    moe = get_config("llama3-e8t2")
    r_flops = moe.flops_per_token(8192) / dense.flops_per_token(8192)
    r_params = moe.param_counts()[0] / dense.param_counts()[0]
    assert 1.4 < r_flops < 1.9, r_flops
    assert 4.0 < r_params < 6.5, r_params


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_reduced(arch):
    cfg = smoke_config(get_config(arch))
    assert cfg.family == get_config(arch).family
    assert cfg.d_model <= 512
    assert cfg.num_layers <= 8
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    t, _ = cfg.param_counts()
    assert t < 50e6


def test_shapes_registry():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["train_4k"].global_batch == 256


def test_long_context_policy():
    assert get_config("mamba2-2.7b").supports_long_context
    assert get_config("jamba-1.5-large-398b").supports_long_context
    assert get_config("minicpm3-4b").supports_long_context  # MLA latent cache
    assert not get_config("seamless-m4t-medium").supports_long_context
    assert not get_config("llama3.2-3b").supports_long_context  # until SWA variant
    assert get_config("llama3.2-3b").replace(sliding_window=8192).supports_long_context
