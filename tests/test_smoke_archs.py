"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward/train step and one decode step on CPU
with finite outputs and correct shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.config import ARCH_IDS, TrainConfig, get_config, smoke_config
from repro.models.model import cache_decl, decode_step, loss_fn, model_decl
from repro.optim.adamw import adamw_init
from repro.sharding.rules import ParamDecl, init_from_decls
from repro.train.trainer import make_train_step

ARCHS = [a for a in ARCH_IDS]


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


def _params(cfg):
    return init_from_decls(model_decl(cfg), jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, rng):
    cfg = smoke_config(get_config(arch))
    params = _params(cfg)
    B, S = 2, 32
    tcfg = TrainConfig(global_batch=B, seq_len=S)
    step = jax.jit(make_train_step(cfg, tcfg, None))
    batch = make_batch(cfg, B, S, rng, enc_len=S)
    opt = adamw_init(params)
    p2, o2, m = step(params, opt, batch, jax.random.PRNGKey(1))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(l0, np.float32), np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, rng):
    cfg = smoke_config(get_config(arch))
    params = _params(cfg)
    B, W = 2, 16
    decls = cache_decl(cfg, B, W, enc_len=8)
    cache = jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype), decls,
        is_leaf=lambda d: isinstance(d, ParamDecl),
    )
    fn = jax.jit(lambda p, c, t: decode_step(cfg, None, p, c, t))
    logits, cache = fn(params, cache, jnp.array([1, 2], jnp.int32))
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"][0]) == 1
    logits, cache = fn(params, cache, jnp.array([3, 4], jnp.int32))
    assert int(cache["pos"][0]) == 2
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "qwen2.5-14b", "arctic-480b"])
def test_sliding_window_variant(arch, rng):
    """The SWA variant that long_500k uses for dense/moe archs."""
    cfg = smoke_config(get_config(arch)).replace(sliding_window=8)
    params = _params(cfg)
    B, S = 2, 32
    tcfg = TrainConfig(global_batch=B, seq_len=S)
    step = jax.jit(make_train_step(cfg, tcfg, None))
    batch = make_batch(cfg, B, S, rng)
    _, _, m = step(params, adamw_init(params), batch, jax.random.PRNGKey(1))
    assert np.isfinite(float(m["loss"]))
    # ring-buffer decode with W < total decoded tokens
    decls = cache_decl(cfg, B, 8)
    cache = jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype), decls,
        is_leaf=lambda d: isinstance(d, ParamDecl),
    )
    fn = jax.jit(lambda p, c, t: decode_step(cfg, None, p, c, t))
    for t in range(12):  # wraps the ring twice
        logits, cache = fn(params, cache, jnp.full((B,), t % 7, jnp.int32))
    assert bool(jnp.all(jnp.isfinite(logits)))
