"""Chaos suite: every fault class the harness can inject is driven through
its injection site and must be either AUTO-RECOVERED (with bitwise-correct
continuation where the contract promises one) or rejected with a typed,
actionable error. Each test asserts the injector's audit log too — a
recovery test whose fault never fired proves nothing.

Fault classes -> recovery contract (the matrix in README.md):

* shard write failure   -> bounded retry + backoff; loud after exhaustion
* torn / truncated shard-> structural verify catches; restore falls back to
                           the newest VERIFIED checkpoint
* bit-flip corruption   -> deep (CRC) verify catches what structure misses
* NaN/Inf gradients     -> in-jit guard skips the update, bitwise clean
* loss spike            -> guard skip -> strikes -> rollback -> parity
* corrupt data batch    -> skip-and-log under a bounded budget, then raise
* page-pool exhaustion  -> typed ShedError at admission; no deadlock
* deadline overrun      -> on-time eviction, pages reclaimed, status set
* hung step             -> watchdog HangError (train and serve)
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, init_model
from repro.checkpoint import (
    CheckpointManager,
    latest_verified_step,
    list_steps,
    restore_tree,
    verified_steps,
    verify_checkpoint,
)
from repro.config import TrainConfig
from repro.data.pipeline import make_train_iter
from repro.resilience import (
    CheckpointCorruptionError,
    DataCorruptionError,
    FaultSpec,
    HangError,
    InjectedFault,
    ShardCorruptionError,
    ShedError,
    faults,
    retry_io,
)
from repro.serving.engine import Request, ServingEngine
from repro.train.callbacks import AnomalySupervisor, CheckpointCallback
from repro.train.state import state_to_tree
from repro.train.trainer import Trainer


def _tcfg(steps=30, B=4, S=16, **kw):
    return TrainConfig(global_batch=B, seq_len=S, lr=3e-3, lr_min=3e-4,
                       warmup_steps=5, total_steps=steps, log_every=1, seed=3,
                       **kw)


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(r.standard_normal((6, 4)), jnp.float32),
        "b": {"c": jnp.asarray(r.standard_normal(8), jnp.float32).astype(jnp.bfloat16),
              "step": jnp.int32(seed)},
    }


def _leaves_equal(t1, t2) -> bool:
    l1, l2 = jax.tree.leaves(t1), jax.tree.leaves(t2)
    return len(l1) == len(l2) and all(
        np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(l1, l2)
    )


def _a_shard_file(ckpt_dir, step):
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    files = sorted(f for f in os.listdir(d) if f.endswith(".npy"))
    return os.path.join(d, files[0])


# -- the harness itself ------------------------------------------------------


def test_injector_is_deterministic_and_scoped():
    spec = FaultSpec("site.x", "boom", at=1, count=2)
    periodic = FaultSpec("site.y", "tick", at=1, every=3)
    with faults.inject(spec, periodic, seed=7) as inj:
        hits = [bool(faults.fire("site.x")) for _ in range(5)]
        assert hits == [False, True, True, False, False]
        ticks = [bool(faults.fire("site.y")) for _ in range(7)]
        assert ticks == [False, True, False, False, True, False, False]
        assert inj.fired == [
            ("site.x", "boom", 1), ("site.x", "boom", 2),
            ("site.y", "tick", 1), ("site.y", "tick", 4),
        ]
        assert inj.events("site.x") == 5
        # nesting restores the outer injector on exit
        with faults.inject(FaultSpec("site.x", "inner", at=0)) as inner:
            assert faults.fire("site.x")[0].kind == "inner"
            assert inner is faults.active()
        assert faults.active() is inj
    assert faults.active() is None
    assert faults.fire("site.x") == []  # no injector -> no-op


def test_retry_io_backoff_and_exhaustion():
    sleeps = []
    calls = {"n": 0}

    def flaky(fail_times):
        calls["n"] += 1
        if calls["n"] <= fail_times:
            raise OSError("transient")
        return "ok"

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert retry_io(flaky, 2, attempts=3, sleep=sleeps.append) == "ok"
    assert calls["n"] == 3 and sleeps == [0.01, 0.02]  # exponential backoff
    calls["n"] = 0
    with pytest.raises(OSError), warnings.catch_warnings():
        warnings.simplefilter("ignore")
        retry_io(flaky, 99, attempts=3, sleep=sleeps.append)


# -- checkpoint integrity ----------------------------------------------------


def test_transient_write_fault_recovered_by_retry(tmp_path):
    d = str(tmp_path / "ck")
    m = CheckpointManager(d, async_save=False)
    with faults.inject(FaultSpec("ckpt.shard_write", "write_fail", at=1)) as inj:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            m.save(_tree(1), 1)
        assert inj.fired == [("ckpt.shard_write", "write_fail", 1)]
        assert any("retrying" in str(x.message) for x in w)
    verify_checkpoint(os.path.join(d, "step_00000001"), deep=True)
    assert _leaves_equal(restore_tree(d)[0], _tree(1))


def test_persistent_write_fault_is_loud_and_preserves_last_good(tmp_path):
    d = str(tmp_path / "ck")
    m = CheckpointManager(d, async_save=False)
    m.save(_tree(1), 1)
    with faults.inject(
        FaultSpec("ckpt.shard_write", "write_fail", at=0, count=10_000)
    ), warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(InjectedFault):
            m.save(_tree(2), 2)
    assert list_steps(d) == [1]  # tmp dir never promoted
    assert _leaves_equal(restore_tree(d)[0], _tree(1))


@pytest.mark.parametrize("kind", ["torn", "bitflip"])
def test_corrupt_write_falls_back_to_newest_verified(tmp_path, kind):
    """Corruption injected at write time (every shard of step 2): restore
    must land on step 1 and warn — never silently return garbage."""
    d = str(tmp_path / "ck")
    m = CheckpointManager(d, async_save=False)
    m.save(_tree(1), 1)
    with faults.inject(
        FaultSpec("ckpt.shard_write", kind, at=0, count=10_000), seed=5
    ) as inj:
        m.save(_tree(2), 2)
        assert inj.fired, "corruption fault never fired"
    assert list_steps(d) == [1, 2]  # step 2 committed, but rotten
    assert latest_verified_step(d) == 1
    if kind == "bitflip":
        # the structural pass cannot see a flipped bit; the CRC must
        verify_checkpoint(os.path.join(d, "step_00000002"), deep=False)
    with pytest.raises(ShardCorruptionError):
        verify_checkpoint(os.path.join(d, "step_00000002"), deep=True)
    with pytest.warns(UserWarning, match="skipping"):
        tree, manifest = m.restore()
    assert manifest["step"] == 1 and _leaves_equal(tree, _tree(1))
    assert m.restore_fallbacks == 1


def test_posthoc_truncation_detected_structurally(tmp_path):
    """A shard truncated after commit (torn replica, disk rot) fails even
    the cheap structural verify once the file drops below its recorded
    payload size (the structural bound excludes the npy header, so cut
    deep); any truncation at all fails the deep pass."""
    d = str(tmp_path / "ck")
    m = CheckpointManager(d, async_save=False)
    m.save(_tree(1), 1)
    faults.truncate_file(_a_shard_file(d, 1), keep_fraction=0.2)
    with pytest.raises(ShardCorruptionError, match="torn write"):
        verify_checkpoint(os.path.join(d, "step_00000001"), deep=False)
    with pytest.raises(ShardCorruptionError):
        verify_checkpoint(os.path.join(d, "step_00000001"), deep=True)


def test_pinned_restore_never_falls_back(tmp_path):
    d = str(tmp_path / "ck")
    m = CheckpointManager(d, async_save=False)
    m.save(_tree(1), 1)
    m.save(_tree(2), 2)
    faults.flip_bit(_a_shard_file(d, 2))
    with pytest.raises(CheckpointCorruptionError, match="step 2"):
        restore_tree(d, step=2)


def test_all_corrupt_raises_listing_every_step(tmp_path):
    d = str(tmp_path / "ck")
    m = CheckpointManager(d, async_save=False)
    m.save(_tree(1), 1)
    m.save(_tree(2), 2)
    for s in (1, 2):
        faults.flip_bit(_a_shard_file(d, s))
    with pytest.raises(CheckpointCorruptionError) as ei:
        restore_tree(d)
    assert "step 1" in str(ei.value) and "step 2" in str(ei.value)


def test_retention_counts_only_verified(tmp_path):
    """keep_last=1 with a corrupt latest: pruning must NOT evict the last
    good checkpoint, and the corrupt dir is reclaimed only once a newer
    verified step supersedes it."""
    d = str(tmp_path / "ck")
    m = CheckpointManager(d, keep_last=1, async_save=False)
    m.save(_tree(1), 1)
    with faults.inject(FaultSpec("ckpt.shard_write", "torn", at=0, count=10_000)):
        m.save(_tree(2), 2)  # committed but every shard torn
    # prune at step 2's commit saw verified=[1]: step 1 survives
    assert list_steps(d) == [1, 2]
    assert verified_steps(d, deep=True) == [1]
    with pytest.warns(UserWarning, match="skipping"):
        tree, manifest = m.restore()
    assert manifest["step"] == 1
    m.save(_tree(3), 3)  # a new verified step supersedes both
    assert list_steps(d) == [3]
    assert _leaves_equal(restore_tree(d)[0], _tree(3))


def test_transient_read_fault_recovered_by_retry(tmp_path):
    d = str(tmp_path / "ck")
    m = CheckpointManager(d, async_save=False)
    m.save(_tree(1), 1)
    with faults.inject(FaultSpec("ckpt.shard_read", "read_fail", at=0)) as inj, \
            warnings.catch_warnings():
        warnings.simplefilter("ignore")
        tree, _ = restore_tree(d, verify=False)
    assert inj.fired and _leaves_equal(tree, _tree(1))


# -- training anomaly supervision -------------------------------------------


def _trainer(cfg, tcfg, **kw):
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                         tcfg.blend_ratio, tcfg.seed)
    return Trainer(cfg, tcfg, data_iter=it, **kw)


def test_nan_step_skipped_bitwise_clean():
    """An injected NaN-gradient step must leave params AND optimizer state
    bitwise untouched (no partially-applied update), keep the optimizer
    clock still, and still advance the batch/RNG stream."""
    cfg = tiny_dense(num_layers=1, vocab_size=256)
    tr = _trainer(cfg, _tcfg())
    sup = AnomalySupervisor(rollback_after=100)  # observe only, no rollback
    tr.run(2, log=lambda *_: None, callbacks=[sup])
    before = jax.device_get(state_to_tree(tr.state))
    with faults.inject(FaultSpec("train.step", "nan_grads", at=0)) as inj:
        tr.run(1, log=lambda *_: None, callbacks=[sup])
        assert inj.fired == [("train.step", "nan_grads", 0)]
    after = jax.device_get(state_to_tree(tr.state))
    assert _leaves_equal(after["params"], before["params"])
    assert _leaves_equal(after["opt"]["master"], before["opt"]["master"])
    assert _leaves_equal(after["opt"]["m"], before["opt"]["m"])
    assert int(after["opt"]["step"]) == int(before["opt"]["step"])
    assert int(after["step"]) == int(before["step"]) + 1  # batch consumed
    assert not np.array_equal(after["rng"], before["rng"])
    assert sup.skips == 1 and sup.rollbacks == 0
    # and the run self-heals: the next (clean) step trains normally
    tr.run(1, log=lambda *_: None, callbacks=[sup])
    assert not _leaves_equal(
        jax.device_get(tr.state.params), after["params"]
    )


def test_spike_rollback_recovers_to_bitwise_parity(tmp_path):
    """Loss spikes past the strike limit force a rollback; after recovery
    the run must continue to the SAME TrainState, bitwise, as an
    uninterrupted run — the acceptance bar for supervised recovery."""
    cfg = tiny_dense(num_layers=1, vocab_size=256)
    tcfg = _tcfg()
    target = 10
    straight = _trainer(cfg, tcfg)
    straight.run(target, log=lambda *_: None)
    ref = jax.device_get(state_to_tree(straight.state))

    tr = _trainer(cfg, tcfg)
    ck = CheckpointCallback(str(tmp_path / "ck"), every=2, async_save=True)
    sup = AnomalySupervisor(ckpt=ck, rollback_after=2, warmup_steps=3)
    cbs = [ck, sup]
    # 10 loop iterations: 5 clean, 2 spiked-and-skipped (strikes 1, 2 ->
    # rollback to checkpoint step 4), 3 replayed -> state.step lands at 7
    with faults.inject(
        FaultSpec("train.step", "loss_spike", at=5, count=2,
                  args={"shift": 1e5})
    ) as inj:
        tr.run(target, log=lambda *_: None, callbacks=cbs)
        assert len(inj.fired) == 2
    assert sup.rollbacks == 1 and sup.skips == 2
    done = int(jax.device_get(tr.state.step))
    assert done < target  # the rollback rewound the global step
    tr.run(target - done, log=lambda *_: None, callbacks=cbs)
    got = jax.device_get(state_to_tree(tr.state))
    assert _leaves_equal(got, ref), "recovered run diverged from clean run"


def test_supervisor_diverged_without_checkpoint():
    from repro.resilience import TrainingDivergedError

    cfg = tiny_dense(num_layers=1, vocab_size=256)
    tr = _trainer(cfg, _tcfg())
    sup = AnomalySupervisor(ckpt=None, rollback_after=2)
    with faults.inject(
        FaultSpec("train.step", "nan_grads", at=0, count=10)
    ), pytest.raises(TrainingDivergedError):
        tr.run(4, log=lambda *_: None, callbacks=[sup])


def test_train_hang_watchdog():
    cfg = tiny_dense(num_layers=1, vocab_size=256)
    tr = _trainer(cfg, _tcfg())
    tr.run(1, log=lambda *_: None)  # pay compile outside the watchdog
    tr.step_timeout_s = 30.0
    tr.run(1, log=lambda *_: None)  # sane budget passes
    tr.step_timeout_s = 0.05
    with faults.inject(
        FaultSpec("train.step", "hang", at=0, args={"seconds": 0.2})
    ), pytest.raises(HangError, match="wall"):
        tr.run(1, log=lambda *_: None)


# -- data pipeline -----------------------------------------------------------


def test_corrupt_batch_skipped_with_stream_parity():
    clean = make_train_iter(256, 16, 4, seed=11)
    ref = [next(clean) for _ in range(4)]
    it = make_train_iter(256, 16, 4, seed=11)
    with faults.inject(
        FaultSpec("data.batch", "corrupt_batch", at=1)
    ) as inj, pytest.warns(UserWarning, match="corrupt"):
        got = [next(it) for _ in range(3)]
        assert inj.fired == [("data.batch", "corrupt_batch", 1)]
    # batch 1 was dropped: the faulted stream is the clean one minus it
    np.testing.assert_array_equal(got[0]["tokens"], ref[0]["tokens"])
    np.testing.assert_array_equal(got[1]["tokens"], ref[2]["tokens"])
    np.testing.assert_array_equal(got[2]["tokens"], ref[3]["tokens"])
    assert it.state()["skipped"] == [1]
    # the snapshot restores the skip bookkeeping too
    it2 = make_train_iter(256, 16, 4, seed=11).restore(it.state())
    assert it2.state()["skipped"] == [1]
    np.testing.assert_array_equal(next(it2)["tokens"], next(clean)["tokens"])


def test_corrupt_batch_budget_exhaustion_raises():
    it = make_train_iter(256, 16, 4, seed=11, skip_budget=2)
    with faults.inject(
        FaultSpec("data.batch", "corrupt_batch", at=0, count=100)
    ), warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(DataCorruptionError, match="budget"):
            next(it)


def test_genuinely_bad_tokens_caught_without_injection():
    """Validation is not injection-only: out-of-range ids from the real
    pipeline are caught too."""
    it = make_train_iter(256, 16, 4, seed=11, skip_budget=1)
    real = it._draw

    def poisoned():
        b = real()
        t = b["tokens"].copy()
        t[0, 0] = -3
        b["tokens"] = t
        return b

    it._draw = poisoned
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(DataCorruptionError):
            next(it)


# -- serving degradation -----------------------------------------------------


@pytest.fixture(scope="module")
def serve_setup():
    cfg = tiny_dense(num_layers=1, vocab_size=64)
    return cfg, init_model(cfg, seed=0)


def _mk_reqs(n, L=10, mnt=6, seed=7, vocab=64, **kw):
    r = np.random.default_rng(seed)
    return [
        Request(i, r.integers(1, vocab, size=L).astype(np.int32),
                max_new_tokens=mnt, **kw)
        for i in range(n)
    ]


def test_admission_sheds_loudly_not_deadlocks(serve_setup):
    cfg, params = serve_setup
    eng = ServingEngine(cfg, params, cache_mode="paged", max_batch=2,
                        max_seq=64, page_size=8, num_pages=8,
                        max_queue=2, shed_watermark=1)
    reqs = _mk_reqs(5)
    accepted, shed = [], []
    for r in reqs:
        try:
            eng.submit(r)
            accepted.append(r)
        except ShedError:
            shed.append(r.rid)
    assert shed, "overload never shed"
    assert eng.sched.shed_count == len(shed)
    for _ in range(200):
        if not eng.sched.has_work:
            break
        eng.step()
    assert all(len(r.output) == r.max_new_tokens for r in accepted)
    h = eng.health()
    assert h["shed_count"] == len(shed) and h["resident_pages"] == 0


def test_pool_exhaustion_alloc_faults_recover_with_parity(serve_setup):
    """Transient page-allocation failures (the pool-exhaustion fault class)
    stall the affected request a step; outputs stay token-for-token equal
    to the clean run."""
    cfg, params = serve_setup
    outs = {}
    for label, specs in [
        ("clean", []),
        ("faulty", [FaultSpec("serving.alloc", "alloc_fail", at=1, count=3)]),
    ]:
        eng = ServingEngine(cfg, params, cache_mode="paged", max_batch=2,
                            max_seq=64, page_size=8)
        with faults.inject(*specs) as inj:
            outs[label] = eng.run(_mk_reqs(3), max_steps=300)
            if specs:
                assert inj.fired, "alloc fault never fired"
    assert outs["clean"] == outs["faulty"]


def test_deadline_eviction_reclaims_pages(serve_setup):
    cfg, params = serve_setup
    eng = ServingEngine(cfg, params, cache_mode="paged", max_batch=2,
                        max_seq=64, page_size=8, deadline_steps=4)
    # rid 2 carries a per-request deadline long enough to finish
    reqs = _mk_reqs(2, mnt=40) + _mk_reqs(1, mnt=4, seed=9)
    reqs[2].rid = 2
    reqs[2].deadline_steps = 1000
    out = eng.run(reqs, max_steps=300)
    assert reqs[0].status == "deadline" and reqs[1].status == "deadline"
    assert reqs[2].status == "ok" and len(out[2]) == 4
    h = eng.health()
    assert h["deadline_evictions"] == 2
    assert h["resident_pages"] == 0  # evicted pages reclaimed
    assert h["free_pages"] == h["num_pages"]


def test_serving_hang_watchdog(serve_setup):
    cfg, params = serve_setup
    eng = ServingEngine(cfg, params, cache_mode="paged", max_batch=2,
                        max_seq=64, page_size=8, step_timeout_s=60.0)
    eng.submit(_mk_reqs(1)[0])
    eng.step()  # compile prefill under a generous budget
    eng.step()  # ... and decode
    eng.step_timeout_s = 0.05
    with faults.inject(
        FaultSpec("serving.step", "hang", at=0, args={"seconds": 0.2})
    ), pytest.raises(HangError, match="wall"):
        eng.step()


def test_ring_mode_rejects_paged_only_knobs(serve_setup):
    cfg, params = serve_setup
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params, cache_mode="ring", deadline_steps=5)
