"""Checkpoint subsystem: atomic commit + retention, crash-mid-save safety,
exact (bitwise) resume parity, elastic mesh-reshape restore, format-1
backward compat, and async-save donation safety."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    list_steps,
    load_checkpoint,
    restore_tree,
    save_checkpoint,
)
from repro.config import MoEConfig, TrainConfig
from repro.data.pipeline import make_train_iter
from repro.train.callbacks import CheckpointCallback, LoggingCallback
from repro.train.state import restore_train_state, state_to_tree
from repro.train.trainer import Trainer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tcfg(steps=30, B=4, S=16, **kw):
    return TrainConfig(global_batch=B, seq_len=S, lr=3e-3, lr_min=3e-4,
                       warmup_steps=5, total_steps=steps, log_every=1, seed=3,
                       **kw)


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(r.standard_normal((6, 4)), jnp.float32),
        "b": {"c": jnp.asarray(r.standard_normal(8), jnp.float32).astype(jnp.bfloat16),
              "step": jnp.int32(5)},
    }


def _leaves_equal(t1, t2) -> bool:
    l1, l2 = jax.tree.leaves(t1), jax.tree.leaves(t2)
    return len(l1) == len(l2) and all(
        np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(l1, l2)
    )


# -- manager: atomicity, retention, crash safety ---------------------------


def test_manager_commit_and_retention(tmp_path):
    d = str(tmp_path / "ck")
    m = CheckpointManager(d, keep_last=2, async_save=False)
    for s in (1, 2, 3, 4):
        m.save(_tree(s), s)
    assert list_steps(d) == [3, 4]
    assert latest_step(d) == 4
    tree, manifest = restore_tree(d)
    assert manifest["step"] == 4 and _leaves_equal(tree, _tree(4))
    # no stale tmp dirs after commits
    assert not [n for n in os.listdir(d) if n.startswith(".tmp-")]


def test_crash_mid_save_keeps_last_good(tmp_path):
    """Kill the process while step-2's shard files are being written: the
    tmp dir must never be promoted, step 1 stays the restorable latest, and
    the next manager instance sweeps the debris."""
    d = str(tmp_path / "ck")
    code = f"""
import os
os.environ["JAX_PLATFORMS"] = "cpu"
import numpy as np, jax.numpy as jnp
from repro.checkpoint import CheckpointManager
from repro.checkpoint import sharded

tree = {{"a": jnp.arange(24, dtype=jnp.float32).reshape(6, 4),
         "b": {{"c": jnp.ones(8, jnp.float32), "d": jnp.zeros(8, jnp.float32)}}}}
m = CheckpointManager({d!r}, keep_last=5, async_save=False)
m.save(tree, 1)

calls = [0]
real = np.save
def dying_save(*a, **kw):
    calls[0] += 1
    if calls[0] > 1:  # die after the first shard file of step 2
        os._exit(9)
    return real(*a, **kw)
np.save = dying_save
sharded.np.save = dying_save
m.save(tree, 2)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 9, f"expected the injected kill: {r.stderr[-2000:]}"
    # last-good checkpoint survives; the torn write is invisible
    assert latest_step(d) == 1
    tree, _ = restore_tree(d)
    assert float(np.asarray(tree["a"]).ravel()[-1]) == 23.0
    tmp = [n for n in os.listdir(d) if n.startswith(".tmp-")]
    assert tmp, "the killed writer should have left a tmp dir behind"
    CheckpointManager(d)  # init sweeps stale tmp dirs
    assert not [n for n in os.listdir(d) if n.startswith(".tmp-")]


def test_async_save_matches_blocking_and_is_donation_safe(tmp_path):
    """An async save snapshots the state at save time: training on (which
    donates and overwrites the device buffers) must not corrupt the bytes
    that land on disk."""
    cfg = tiny_dense(num_layers=1, vocab_size=256)
    tcfg = _tcfg()
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
    tr = Trainer(cfg, tcfg, data_iter=it)
    cb = CheckpointCallback(str(tmp_path / "async"), every=2, async_save=True)
    tr.run(2, log=lambda *_: None, callbacks=[cb])
    snap_at_2 = jax.device_get(state_to_tree(tr.state))  # values at step 2
    tr.run(2, log=lambda *_: None, callbacks=[cb])  # donates/overwrites buffers
    cb.manager.wait()
    tree2, _ = restore_tree(str(tmp_path / "async"), step=2)
    assert _leaves_equal(tree2, snap_at_2)
    tree4, _ = restore_tree(str(tmp_path / "async"), step=4)
    assert _leaves_equal(tree4, jax.device_get(state_to_tree(tr.state)))
    assert not _leaves_equal(tree2["params"], tree4["params"])


# -- flat checkpoints: format compat ---------------------------------------


def test_load_checkpoint_v1_compat(tmp_path):
    """Seed-era format-1 manifests (one whole-array .npy per leaf, bf16 as
    uint16 view) stay loadable."""
    d = tmp_path / "v1"
    d.mkdir()
    w = np.arange(12, dtype=np.float32).reshape(3, 4)
    e = (np.ones(8, np.float32) * 1.5).astype(jnp.bfloat16)
    np.save(d / "layer__w.npy", w)
    np.save(d / "layer__e.npy", e.view(np.uint16))
    manifest = {"step": 7, "meta": {}, "leaves": {
        "layer::w": {"file": "layer__w.npy", "dtype": "float32"},
        "layer::e": {"file": "layer__e.npy", "dtype": "bfloat16"},
    }}
    (d / "manifest.json").write_text(json.dumps(manifest))
    loaded = load_checkpoint(str(d))
    assert np.array_equal(np.asarray(loaded["layer"]["w"]), w)
    assert loaded["layer"]["e"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(loaded["layer"]["e"]), np.asarray(e))


def test_flat_roundtrip_v2(tmp_path):
    t = _tree(1)
    save_checkpoint(str(tmp_path / "flat"), t, step=9)
    assert _leaves_equal(load_checkpoint(str(tmp_path / "flat")), t)
    man = json.load(open(tmp_path / "flat" / "manifest.json"))
    assert man["format"] == 2 and man["step"] == 9
    # every leaf records its spec slot and shard indices
    assert all("shards" in e for e in man["leaves"].values())


# -- data pipeline state ----------------------------------------------------


def test_data_iterator_state_restore():
    it = make_train_iter(256, 16, 4, seed=11)
    for _ in range(3):
        next(it)
    snap = it.state()
    want = [next(it) for _ in range(2)]
    it2 = make_train_iter(256, 16, 4, seed=11).restore(snap)
    got = [next(it2) for _ in range(2)]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w["tokens"], g["tokens"])
        np.testing.assert_array_equal(w["labels"], g["labels"])
    # snapshot must survive a JSON round trip (it rides the manifest meta)
    snap_json = json.loads(json.dumps(snap))
    it3 = make_train_iter(256, 16, 4, seed=11).restore(snap_json)
    np.testing.assert_array_equal(next(it3)["tokens"], want[0]["tokens"])


# -- exact resume parity ----------------------------------------------------


def _run_straight(cfg, tcfg, steps, **trainer_kw):
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                         tcfg.blend_ratio, tcfg.seed)
    tr = Trainer(cfg, tcfg, data_iter=it, **trainer_kw)
    tr.run(steps, log=lambda *_: None)
    return tr


def test_resume_bitwise_parity(tmp_path):
    """k steps + save + restore-in-a-fresh-Trainer + n steps == k+n straight
    steps, bitwise: params, fp32 master/moments, and logged metrics."""
    cfg = tiny_dense(num_layers=1, vocab_size=256)
    tcfg = _tcfg()
    straight = _run_straight(cfg, tcfg, 6)

    d = str(tmp_path / "ck")
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                         tcfg.blend_ratio, tcfg.seed)
    tr1 = Trainer(cfg, tcfg, data_iter=it)
    cb = CheckpointCallback(d, every=3, async_save=True)
    tr1.run(3, log=lambda *_: None, callbacks=[LoggingCallback(log=lambda *_: None, log_every=1), cb])

    state, manifest = restore_train_state(d, cfg)
    assert manifest["step"] == 3
    it2 = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                          tcfg.blend_ratio, tcfg.seed)
    it2.restore(manifest["meta"]["data_state"])
    tr2 = Trainer(cfg, tcfg, state=state, data_iter=it2)
    tr2.run(3, log=lambda *_: None)

    assert int(jax.device_get(tr2.state.step)) == 6
    assert _leaves_equal(tr2.params, straight.params)
    assert _leaves_equal(tr2.opt_state.master, straight.opt_state.master)
    assert _leaves_equal(tr2.opt_state.m, straight.opt_state.m)
    assert _leaves_equal(tr2.opt_state.v, straight.opt_state.v)
    assert np.array_equal(np.asarray(tr2.rng), np.asarray(straight.rng))
    # logged metrics of the resumed tail are bitwise those of the straight run
    tail = {r["step"]: r for r in tr2.history}
    ref = {r["step"]: r for r in straight.history}
    for s in (4, 5, 6):
        for k in ("loss", "ce", "lr", "grad_norm"):
            assert tail[s][k] == ref[s][k], (s, k, tail[s][k], ref[s][k])


def test_resume_composes_with_upcycle(tmp_path):
    """A run started via upcycling restarts from its latest MoE state, not
    by re-upcycling — and matches the uninterrupted upcycled run bitwise."""
    from repro.core.upcycle import upcycle_config, upcycle_params

    dense_cfg = tiny_dense(num_layers=1, vocab_size=256)
    tcfg = _tcfg()
    dense = _run_straight(dense_cfg, tcfg, 3)
    moe_cfg = upcycle_config(
        dense_cfg, MoEConfig(num_experts=4, top_k=2, capacity_factor=None,
                             dispatcher="sorted"))
    moe_params = upcycle_params(dense_cfg, moe_cfg, dense.params,
                                jax.random.PRNGKey(9))

    straight = _run_straight(moe_cfg, tcfg, 4, params=moe_params)

    d = str(tmp_path / "ck")
    it = make_train_iter(moe_cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                         tcfg.blend_ratio, tcfg.seed)
    tr1 = Trainer(moe_cfg, tcfg, params=moe_params, data_iter=it)
    cb = CheckpointCallback(d, every=2, async_save=True,
                            extra_meta={"provenance": {"upcycled": True}})
    tr1.run(2, log=lambda *_: None, callbacks=[cb])

    state, manifest = restore_train_state(d, moe_cfg)
    assert manifest["meta"]["provenance"]["upcycled"] is True
    it2 = make_train_iter(moe_cfg.vocab_size, tcfg.seq_len, tcfg.global_batch,
                          tcfg.blend_ratio, tcfg.seed)
    it2.restore(manifest["meta"]["data_state"])
    tr2 = Trainer(moe_cfg, tcfg, state=state, data_iter=it2)
    tr2.run(2, log=lambda *_: None)
    assert _leaves_equal(tr2.params, straight.params)
    assert _leaves_equal(tr2.opt_state.master, straight.opt_state.master)


def test_restore_rejects_wrong_config(tmp_path):
    cfg = tiny_dense(num_layers=1, vocab_size=256)
    tcfg = _tcfg()
    d = str(tmp_path / "ck")
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
    tr = Trainer(cfg, tcfg, data_iter=it)
    tr.run(1, log=lambda *_: None,
           callbacks=[CheckpointCallback(d, every=1, async_save=False)])
    other = tiny_dense(num_layers=2, vocab_size=256)
    with pytest.raises(AssertionError, match="do(es)? not match"):
        restore_train_state(d, other)


# -- satellite: steady-state timing accounting ------------------------------


def test_history_timing_excludes_warmup():
    cfg = tiny_dense(num_layers=1, vocab_size=256)
    tcfg = _tcfg()
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
    tr = Trainer(cfg, tcfg, data_iter=it)
    tr.run(4, log=lambda *_: None)
    last = tr.history[-1]
    for key in ("ms_per_step_steady", "wall_total_s", "sec_per_step",
                "model_tflops_per_sec"):
        assert key in last and last[key] > 0, key
    # step 1 pays jit compilation; the steady figure must exclude it
    step1_s = tr.history[0]["wall_total_s"]
    assert last["sec_per_step"] <= step1_s, (last["sec_per_step"], step1_s)
    assert last["wall_total_s"] >= step1_s
    assert last["sec_per_step"] == pytest.approx(last["ms_per_step_steady"] / 1e3)


# -- elastic mesh reshaping -------------------------------------------------


def test_mesh_reshape_restore_parity():
    """Save the full TrainState under EP on the 3-D study mesh; restore it
    (a) onto the 2-D production-style mesh (EP folds onto 'model') and
    (b) onto the host (no plan) — bitwise both times, with the optimizer
    state re-sharded per the target plan's ZeRO-1 rules."""
    code = """
import json, numpy as np, jax, jax.numpy as jnp
from repro.config import ModelConfig, MoEConfig, TrainConfig
from repro.launch.mesh import make_study_mesh
from repro.sharding.rules import FoldingPlan
from repro.checkpoint import CheckpointManager, restore_tree
from repro.train.state import (create_train_state, restore_train_state,
                               state_to_tree)

moe = MoEConfig(num_experts=4, top_k=2, capacity_factor=None, dispatcher="sorted")
cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=64, num_heads=4,
                  num_kv_heads=4, d_ff=128, vocab_size=256, vocab_divisor=64,
                  dtype="float32", moe=moe)
tcfg = TrainConfig(global_batch=4, seq_len=16, seed=0)

study = make_study_mesh(1, 4, 2)
plan_s = FoldingPlan.make(cfg, study)
assert plan_s.moe_mode == "ep" and plan_s.ep_axis == "expert"
state = create_train_state(cfg, tcfg, plan_s)
ref = jax.device_get(state_to_tree(state))
m = CheckpointManager("/tmp/ck_reshape", keep_last=1, async_save=False)
m.save(state_to_tree(state), 1)

prod = jax.make_mesh((2, 4), ("data", "model"))
plan_p = FoldingPlan.make(cfg, prod)
assert plan_p.moe_mode == "ep" and plan_p.ep_axis == "model"
got_p, _ = restore_train_state("/tmp/ck_reshape", cfg, plan_p, zero1=tcfg.zero1)
got_h, _ = restore_train_state("/tmp/ck_reshape", cfg, plan=None)

def eq(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))

wg = got_p.params["stack"]["slot0"]["ffn"]["experts"]["w_gate"]
out = {
  "prod_equal": eq(jax.device_get(state_to_tree(got_p)), ref),
  "host_equal": eq(jax.device_get(state_to_tree(got_h)), ref),
  "wg_spec": str(wg.sharding.spec),
  "master_data_sharded": any(
      "data" in str(l.sharding.spec) for l in jax.tree.leaves(got_p.opt_state.master)
      if hasattr(l, "sharding")),
}
print(json.dumps(out))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # fake-device meshes live on the host (CPU) platform; pin it so the
    # child never probes a real accelerator plugin (libtpu init can hang
    # when the machine has the plugin but no device)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["prod_equal"], out
    assert out["host_equal"], out
    assert "model" in out["wg_spec"], out  # experts now shard the model axis
    assert out["master_data_sharded"], out
