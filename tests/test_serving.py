"""Serving: fused prefill == reference scan prefill == full forward; greedy
decode consistency across families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import init_model, make_batch
from repro.config import get_config, smoke_config
from repro.models.model import decode_step, forward, prefill_forward

CHECK = [
    "llama3.2-3b", "qwen2.5-14b", "stablelm-1.6b", "minicpm3-4b",
    "mamba2-2.7b", "jamba-1.5-large-398b", "qwen3-moe-30b-a3b",
    "arctic-480b", "llava-next-34b", "seamless-m4t-medium",
]


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(7)


@pytest.mark.parametrize("arch", CHECK)
def test_prefill_decode_matches_forward(arch, rng):
    """decode(prefill(x[:-1]), x[-1]) == forward(x)[-1] in fp32."""
    cfg = smoke_config(get_config(arch)).replace(dtype="float32")
    if cfg.moe is not None:
        # dropless for the equivalence check: with a finite CF the drop set
        # depends on the dispatch-group token count, which legitimately
        # differs between the 15-token prefill and the 16-token forward
        import dataclasses

        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=None))
    params = init_model(cfg, fp32=True)
    B, S = 2, 16
    pfx = cfg.num_prefix_embeds if cfg.family == "vlm" else 0
    batch = make_batch(cfg, B, S, rng, labels=False)
    full, _ = jax.jit(lambda p, b: forward(cfg, None, p, b))(params, batch)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, : S - 1]
    _, cache = jax.jit(
        lambda p, b: prefill_forward(cfg, None, p, b, cache_len=S + pfx)
    )(params, pb)
    dl, _ = jax.jit(lambda p, c, t: decode_step(cfg, None, p, c, t))(
        params, cache, batch["tokens"][:, S - 1]
    )
    ref = full[:, -1]
    rel = float(jnp.max(jnp.abs(dl - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 1e-3, rel


def test_prefill_decode_sorted_dispatcher(rng):
    """MoE decode path through the sorted dropless dispatcher: prefill +
    decode matches the full forward (same check as above, sorted)."""
    import dataclasses

    cfg = smoke_config(get_config("qwen3-moe-30b-a3b")).replace(dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=None, dispatcher="sorted"))
    params = init_model(cfg, fp32=True)
    B, S = 2, 16
    batch = make_batch(cfg, B, S, rng, labels=False)
    full, _ = jax.jit(lambda p, b: forward(cfg, None, p, b))(params, batch)
    pb = {"tokens": batch["tokens"][:, : S - 1]}
    _, cache = jax.jit(
        lambda p, b: prefill_forward(cfg, None, p, b, cache_len=S)
    )(params, pb)
    dl, _ = jax.jit(lambda p, c, t: decode_step(cfg, None, p, c, t))(
        params, cache, batch["tokens"][:, S - 1]
    )
    ref = full[:, -1]
    rel = float(jnp.max(jnp.abs(dl - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 1e-3, rel


def test_engine_serves_moe_with_sorted_dispatcher(rng):
    """ServingEngine end-to-end with the dispatcher override: batched
    continuous decode over an MoE model on the sorted dropless path."""
    import dataclasses

    from repro.serving.engine import Request, ServingEngine

    cfg = smoke_config(get_config("qwen3-moe-30b-a3b")).replace(dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=None))
    params = init_model(cfg, fp32=True)
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=32,
                           dispatcher="sorted")
    assert engine.cfg.moe.dispatcher == "sorted"
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                max_new_tokens=5)
        for i in range(3)
    ]
    out = engine.run(reqs)
    assert sorted(out) == [0, 1, 2]
    assert all(len(v) == 5 for v in out.values())
    # sorted vs allgather decode logits agree within fp reduction-order
    # noise (exact token equality would be brittle: a near-tie in the top-2
    # logits could flip greedy argmax between the two reduction orders)
    batch = {"tokens": jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)}
    l_sorted, _ = jax.jit(lambda p, b: forward(engine.cfg, None, p, b))(params, batch)
    l_ag, _ = jax.jit(lambda p, b: forward(cfg, None, p, b))(params, batch)
    rel = float(jnp.max(jnp.abs(l_sorted - l_ag)) / (jnp.max(jnp.abs(l_ag)) + 1e-9))
    assert rel < 1e-4, rel


def test_greedy_generation_deterministic(rng):
    cfg = smoke_config(get_config("llama3.2-3b")).replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    batch = make_batch(cfg, 2, 8, rng, labels=False)

    def gen():
        _, cache = prefill_forward(cfg, None, params, batch, cache_len=24)
        logits, _ = forward(cfg, None, params, batch)
        tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], -1)
        outs = [tok]
        for _ in range(8):
            logits, cache_new = decode_step(cfg, None, params, cache, tok)
            cache = cache_new
            tok = jnp.argmax(logits[:, : cfg.vocab_size], -1)
            outs.append(tok)
        return np.asarray(jnp.stack(outs, 1))

    a, b = gen(), gen()
    np.testing.assert_array_equal(a, b)


def test_sliding_window_ring_equals_full_context_within_window(rng):
    """With window W, a ring cache of W slots gives the same logits as an
    unbounded cache, once > W tokens have been decoded."""
    cfg = smoke_config(get_config("llama3.2-3b")).replace(
        dtype="float32", sliding_window=8
    )
    params = init_model(cfg, fp32=True)
    B, S = 1, 20
    batch = make_batch(cfg, B, S, rng, labels=False)
    full, _ = forward(cfg, None, params, batch)  # applies SWA mask globally
    pb = {"tokens": batch["tokens"][:, : S - 1]}
    _, cache = prefill_forward(cfg, None, params, pb, cache_len=S)  # W=8 ring
    assert cache["slot_pos"].shape[1] == 8
    dl, _ = decode_step(cfg, None, params, cache, batch["tokens"][:, S - 1])
    rel = float(jnp.max(jnp.abs(dl - full[:, -1])) / jnp.max(jnp.abs(full[:, -1])))
    assert rel < 1e-3, rel
