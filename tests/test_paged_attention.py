"""Paged-attention decode kernel vs the `kernels/ref.py` oracle over
shape / GQA-grouping / page-size sweeps, plus a dense cross-check that the
oracle itself equals ordinary causal attention on a contiguous layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import paged_attention
from repro.kernels.ref import paged_attention_ref


def _scatter_case(rng, B, H, KV, d, ps, maxP, num_pages, lens):
    """Random pool + disjoint per-sequence page lists covering `lens`."""
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((num_pages, ps, KV, d)), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((num_pages, ps, KV, d)), jnp.float32)
    perm = rng.permutation(num_pages)
    bt = np.full((B, maxP), -1, np.int32)
    used = 0
    for b in range(B):
        need = -(-int(lens[b]) // ps)
        bt[b, :need] = perm[used : used + need]
        used += need
    return q, k_pool, v_pool, jnp.asarray(bt), jnp.asarray(lens, jnp.int32)


@pytest.mark.parametrize("H,KV", [(4, 1), (4, 2), (8, 8), (6, 3)])
@pytest.mark.parametrize("ps", [1, 4, 8])
def test_kernel_matches_ref_gqa_page_sweep(H, KV, ps):
    rng = np.random.default_rng(H * 100 + ps)
    B, d, maxP = 3, 32, 6
    num_pages = B * maxP
    lens = rng.integers(1, maxP * ps + 1, B)
    q, kp, vp, bt, sl = _scatter_case(rng, B, H, KV, d, ps, maxP, num_pages, lens)
    out = paged_attention(q, kp, vp, bt, sl)
    ref = paged_attention_ref(q, kp, vp, bt, sl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window", [1, 3, 8, 64])
def test_kernel_matches_ref_window(window):
    rng = np.random.default_rng(window)
    B, H, KV, d, ps, maxP = 2, 4, 2, 64, 4, 8
    lens = rng.integers(1, maxP * ps + 1, B)
    q, kp, vp, bt, sl = _scatter_case(rng, B, H, KV, d, ps, maxP, B * maxP, lens)
    out = paged_attention(q, kp, vp, bt, sl, window=window)
    ref = paged_attention_ref(q, kp, vp, bt, sl, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_kernel_skips_released_pages():
    """-1 block-table entries below a window (released pages) change
    nothing: the masked set is identical."""
    rng = np.random.default_rng(0)
    B, H, KV, d, ps, maxP, w = 1, 4, 2, 32, 4, 8, 6
    lens = np.asarray([29])
    q, kp, vp, bt, sl = _scatter_case(rng, B, H, KV, d, ps, maxP, maxP, lens)
    full = paged_attention(q, kp, vp, bt, sl, window=w)
    bt_rel = np.asarray(bt).copy()
    # pages entirely below the window of the current query (pos = len - 1,
    # which masks kpos <= len - 1 - w) are dead
    for j in range(maxP):
        if (j + 1) * ps - 1 <= int(lens[0]) - 1 - w:
            bt_rel[0, j] = -1
    assert (bt_rel == -1).sum() > (np.asarray(bt) == -1).sum()
    rel = paged_attention(q, kp, vp, jnp.asarray(bt_rel), sl, window=w)
    np.testing.assert_allclose(np.asarray(full), np.asarray(rel), atol=1e-6)


def test_ref_matches_dense_attention():
    """Oracle sanity: with an identity page layout the paged ref equals
    plain masked attention over the contiguous KV prefix."""
    rng = np.random.default_rng(3)
    B, H, KV, d, ps, maxP = 2, 8, 2, 32, 4, 4
    S = maxP * ps
    lens = np.asarray([S, S - 5])
    k = rng.standard_normal((B, S, KV, d)).astype(np.float32)
    v = rng.standard_normal((B, S, KV, d)).astype(np.float32)
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.float32)
    # pool page b*maxP + j holds sequence b's tokens [j*ps, (j+1)*ps)
    k_pool = jnp.asarray(k.reshape(B * maxP, ps, KV, d))
    v_pool = jnp.asarray(v.reshape(B * maxP, ps, KV, d))
    bt = jnp.asarray(np.arange(B * maxP).reshape(B, maxP).astype(np.int32))
    out = paged_attention_ref(q, k_pool, v_pool, bt, jnp.asarray(lens, jnp.int32))

    G = H // KV
    qg = np.asarray(q).reshape(B, KV, G, d)
    want = np.zeros((B, KV, G, d), np.float32)
    for b in range(B):
        n = int(lens[b])
        s = np.einsum("kgd,skd->kgs", qg[b], k[b, :n]) * (d**-0.5)
        p = jax.nn.softmax(jnp.asarray(s), axis=-1)
        want[b] = np.einsum("kgs,skd->kgd", np.asarray(p), v[b, :n])
    np.testing.assert_allclose(
        np.asarray(out), want.reshape(B, H, d), atol=2e-5
    )


def test_kernel_idle_sequence_emits_zeros():
    """A batch slot with no pages (all -1) must produce exact zeros — the
    engine relies on this being well-defined, not NaN."""
    rng = np.random.default_rng(1)
    B, H, KV, d, ps, maxP = 2, 4, 2, 32, 4, 4
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((maxP, ps, KV, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((maxP, ps, KV, d)), jnp.float32)
    bt = np.full((B, maxP), -1, np.int32)
    bt[0, :2] = [0, 1]
    out = paged_attention(q, kp, vp, jnp.asarray(bt), jnp.asarray([5, 0], jnp.int32))
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray([5, 0], jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
