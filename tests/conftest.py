import os

# Tests run on the single real CPU device. Only the dry-run sets the
# 512-placeholder flag; distributed tests spawn subprocesses with their own
# XLA_FLAGS (see test_distributed.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Strict dispatch throughout the suite: an illegal EP dispatcher is a loud
# ValueError, never a silent allgather fallback that could mask dispatch
# bugs. Tests that exercise the quiet-fallback path unset this explicitly
# (monkeypatch.delenv / setenv to "0").
os.environ.setdefault("REPRO_STRICT_DISPATCH", "1")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tiny_dense(**kw):
    from repro.config import ModelConfig

    base = dict(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256, vocab_divisor=64,
    )
    base.update(kw)
    return ModelConfig(**base)


def init_model(cfg, seed=0, fp32=False):
    from repro.models.model import model_decl
    from repro.sharding.rules import init_from_decls

    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(seed))
    if fp32:
        params = jax.tree.map(
            lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, params
        )
    return params


def make_batch(cfg, B, S, rng, labels=True, enc_len=8):
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if labels:
        b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.family == "vlm":
        b["embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_prefix_embeds, cfg.d_model)), jnp.float32
        ) * 0.02
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.standard_normal((B, enc_len, cfg.d_model)), jnp.float32
        ) * 0.02
    return b
