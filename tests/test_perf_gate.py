"""Perf-gate unit tests: the tolerance-band diff that CI runs over the
BENCH artifacts. Pure JSON-in/JSON-out — no model, no benches."""
import json
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from benchmarks.perf_gate import compare, gate, update  # noqa: E402


def diff(base, fresh):
    failures, notes = [], []
    compare(base, fresh, "t", failures, notes)
    return failures, notes


def test_timing_band_is_generous_but_bounded():
    base = {"us_fwd_xla_ref": 100.0, "tokens_per_s": 20.0}
    ok, _ = diff(base, {"us_fwd_xla_ref": 300.0, "tokens_per_s": 10.0})
    assert not ok  # 3x slower / 2x less throughput: inside the CPU band
    bad, _ = diff(base, {"us_fwd_xla_ref": 600.0, "tokens_per_s": 20.0})
    assert len(bad) == 1 and "us_fwd_xla_ref" in bad[0]
    bad, _ = diff(base, {"us_fwd_xla_ref": 100.0, "tokens_per_s": 3.0})
    assert len(bad) == 1 and "tokens_per_s" in bad[0]
    # improvements always pass, and big ones are surfaced as notes
    ok, notes = diff(base, {"us_fwd_xla_ref": 10.0, "tokens_per_s": 200.0})
    assert not ok and len(notes) == 2


def test_exact_metrics_and_counts():
    base = {"parity_token_for_token": True, "prefill_traces": 3,
            "peak_resident_requests": 6, "mode": "paged"}
    assert not diff(base, dict(base))[0]
    for k, v in [("parity_token_for_token", False), ("prefill_traces", 4),
                 ("peak_resident_requests", 5), ("mode", "ring")]:
        fresh = dict(base)
        fresh[k] = v
        bad, _ = diff(base, fresh)
        assert len(bad) == 1 and k in bad[0], (k, bad)


def test_bytes_band_and_error_band():
    base = {"ckpt_bytes": 1000, "kernel_max_err": 1e-3}
    assert not diff(base, {"ckpt_bytes": 1015, "kernel_max_err": 2e-3})[0]
    bad, _ = diff(base, {"ckpt_bytes": 1500, "kernel_max_err": 1e-3})
    assert len(bad) == 1 and "ckpt_bytes" in bad[0]
    bad, _ = diff(base, {"ckpt_bytes": 1000, "kernel_max_err": 1e-2})
    assert len(bad) == 1 and "kernel_max_err" in bad[0]


def test_missing_metric_fails_new_metric_passes():
    base = {"rows": [{"name": "a", "gemm_rows": 8}]}
    bad, _ = diff(base, {"rows": [{"name": "a"}]})
    assert any("gemm_rows" in f and "disappeared" in f for f in bad)
    bad, _ = diff(base, {"rows": []})
    assert any("row disappeared" in f for f in bad)
    ok, notes = diff(base, {"rows": [{"name": "a", "gemm_rows": 8,
                                     "new_metric": 1.0}]})
    assert not ok and any("new_metric" in n for n in notes)


def test_rows_match_by_identity_not_index():
    base = {"rows": [{"name": "a", "gemm_rows": 1}, {"name": "b", "gemm_rows": 2}]}
    fresh = {"rows": [{"name": "b", "gemm_rows": 2}, {"name": "a", "gemm_rows": 1}]}
    assert not diff(base, fresh)[0]


def test_gate_roundtrip_and_missing_baseline(tmp_path):
    root = tmp_path / "root"
    bdir = tmp_path / "baselines"
    root.mkdir()
    art = ("BENCH_x.json",)
    (root / "BENCH_x.json").write_text(json.dumps(
        {"tokens_per_s": 10.0, "parity": True}))
    fails = gate(art, str(bdir), str(root), verbose=False)
    assert any("no committed baseline" in f for f in fails)
    update(art, str(bdir), str(root))
    assert gate(art, str(bdir), str(root), verbose=False) == []
    (root / "BENCH_x.json").write_text(json.dumps(
        {"tokens_per_s": 1.0, "parity": True}))
    fails = gate(art, str(bdir), str(root), verbose=False)
    assert len(fails) == 1 and "tokens_per_s" in fails[0]


def test_committed_baselines_cover_all_artifacts():
    """The repo ships a baseline for every gated artifact (the CI step
    fails closed otherwise)."""
    from benchmarks.perf_gate import ARTIFACTS, BASELINE_DIR

    for name in ARTIFACTS:
        assert os.path.exists(os.path.join(BASELINE_DIR, name)), name
