"""TokenDispatcher subsystem: three-way dispatcher parity, sorted-dropless
semantics, and the upcycled-init dense-match invariant (paper Fig. 3) under
the sorted path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig, with_dispatcher
from repro.core.dispatch import (
    AllGatherDispatcher,
    SortedDispatcher,
    get_dispatcher,
)
from repro.core.moe import moe_apply, moe_decl
from repro.sharding.rules import init_from_decls


def _cfg(E=4, k=2, cf=None, dispatcher="allgather", **kw):
    moe = MoEConfig(num_experts=E, top_k=k, capacity_factor=cf,
                    dispatcher=dispatcher, **kw)
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=128,
                      vocab_divisor=64, moe=moe)
    return cfg, moe


def _params(cfg, moe, seed=0):
    params = init_from_decls(moe_decl(cfg, moe), jax.random.PRNGKey(seed))
    return jax.tree.map(lambda x: x.astype(jnp.float32), params)


def test_registry_and_fallbacks(monkeypatch):
    cfg, moe = _cfg(dispatcher="sorted")
    assert isinstance(get_dispatcher(cfg, moe, None, 64, 2), SortedDispatcher)
    # alltoall without an EP plan: under the suite's strict default it is a
    # loud config error, not a silent allgather downgrade...
    cfg2, moe2 = _cfg(dispatcher="alltoall")
    with pytest.raises(ValueError, match="illegal"):
        get_dispatcher(cfg2, moe2, None, 64, 2)
    # ...and only with strict mode explicitly off does the historical quiet
    # fallback apply (warning included)
    monkeypatch.setenv("REPRO_STRICT_DISPATCH", "0")
    with pytest.warns(UserWarning, match="falling back"):
        assert isinstance(
            get_dispatcher(cfg2, moe2, None, 64, 2), AllGatherDispatcher
        )
    monkeypatch.setenv("REPRO_STRICT_DISPATCH", "1")
    # expert-choice routing has no flat top-k assignment list to sort
    cfg3, moe3 = _cfg(dispatcher="sorted", router_type="expert_choice")
    assert isinstance(get_dispatcher(cfg3, moe3, None, 64, 2), AllGatherDispatcher)
    with pytest.raises(AssertionError):
        MoEConfig(dispatcher="bogus")


def test_sorted_matches_allgather_dropless():
    """Fixed routing: the sorted dropless dispatcher's output equals the
    padded allgather reference (both dropless, fp32)."""
    cfg, moe = _cfg(cf=None)
    params = _params(cfg, moe)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    y_ag, _ = moe_apply(cfg, moe, None, params, x)
    moe_s = dataclasses.replace(moe, dispatcher="sorted")
    y_s, _ = moe_apply(cfg, moe_s, None, params, x)
    np.testing.assert_allclose(np.asarray(y_ag), np.asarray(y_s), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sorted_kernel_matches_xla(dtype):
    """The group-size-aware Pallas path (tile-aligned layout) agrees with
    the ragged_dot XLA path through the full dispatcher pipeline."""
    cfg, moe = _cfg(dispatcher="sorted")
    params = jax.tree.map(
        lambda x: x.astype(dtype), _params(cfg, moe)
    )
    x = (jax.random.normal(jax.random.PRNGKey(2), (2, 16, 32)) * 0.3).astype(dtype)
    y0, _ = moe_apply(cfg, moe, None, params, x, use_kernel=False)
    y1, _ = moe_apply(cfg, moe, None, params, x, use_kernel=True)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(y0, np.float32), np.asarray(y1, np.float32), atol=atol
    )


def test_sorted_is_dropless_under_imbalance():
    """All tokens routed to one expert: the sorted path computes every
    assignment (no capacity drops), unlike a CF-bounded padded dispatcher."""
    cfg, moe = _cfg(E=4, k=1, dispatcher="sorted")
    params = _params(cfg, moe)
    params["router"]["w_g"] = jnp.zeros_like(params["router"]["w_g"]).at[:, 0].set(10.0)
    x = jnp.ones((1, 32, 32), jnp.float32)
    y, _ = moe_apply(cfg, moe, None, params, x)
    nonzero = np.asarray(jnp.any(jnp.abs(y[0]) > 1e-9, axis=-1))
    assert nonzero.sum() == 32, nonzero.sum()


def test_sorted_upcycled_init_matches_dense_ffn():
    """Identical experts + mixtral gates under the sorted path == the dense
    FFN exactly — the paper's upcycling warm-start invariant (Fig. 3)."""
    cfg, moe = _cfg(dispatcher="sorted")
    params = _params(cfg, moe)
    for k in ("w_gate", "w_up", "w_down"):
        params["experts"][k] = jnp.broadcast_to(
            params["experts"][k][0:1], params["experts"][k].shape
        )
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    y, _ = moe_apply(cfg, moe, None, params, x)
    from repro.models.layers import mlp_apply

    dense = {k: params["experts"][k][0] for k in ("w_gate", "w_up", "w_down")}
    y_ref = mlp_apply(dense, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def test_sorted_gradients_flow():
    """The argsort/gather/scatter pipeline is differentiable end-to-end."""
    cfg, moe = _cfg(dispatcher="sorted")
    params = _params(cfg, moe)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 32)) * 0.3

    def loss(p):
        y, _ = moe_apply(cfg, moe, None, p, x)
        return jnp.sum(jnp.square(y))

    g = jax.grad(loss)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    for k in ("w_gate", "w_up", "w_down"):
        assert float(jnp.sum(jnp.abs(g["experts"][k]))) > 0, k


def test_sorted_dispatcher_reentrant():
    """dispatch/combine are pure: one instance can hold two in-flight
    dispatches and combine them in any order (impossible with the old
    mutable `_token`/`_dest` instance state), and a single instance works
    under jax.vmap."""
    cfg, moe = _cfg(dispatcher="sorted")
    params = _params(cfg, moe)
    d = SortedDispatcher(cfg, moe, None)
    key = jax.random.PRNGKey(5)
    x1 = jax.random.normal(key, (16, 32)) * 0.3
    x2 = jax.random.normal(jax.random.fold_in(key, 1), (16, 32)) * 0.3
    idx = jnp.tile(jnp.array([[0, 1]], jnp.int32), (16, 1))
    gates = jnp.full((16, 2), 0.5, jnp.float32)

    # interleaved: both dispatches before either combine, combined LIFO
    xe1, st1 = d.dispatch(x1, idx, gates)
    xe2, st2 = d.dispatch(x2, idx, gates)
    from repro.core.dispatch import expert_ffn

    y2 = d.combine(expert_ffn(params["experts"], xe2, st2.layout), st2)
    y1 = d.combine(expert_ffn(params["experts"], xe1, st1.layout), st1)
    y1_ref = d.apply(params["experts"], x1, gates, idx)
    y2_ref = d.apply(params["experts"], x2, gates, idx)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y1_ref), atol=1e-6)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y2_ref), atol=1e-6)

    # one instance under vmap + grad (elementwise FFN stand-in: ragged_dot
    # itself has no batching rule upstream, which is irrelevant here — the
    # point is that dispatch/combine close over no per-call instance state)
    xb = jnp.stack([x1, x2])

    def loss(xb):
        def one(x):
            xe, st = d.dispatch(x, idx, gates)
            return d.combine(xe * 2.0, st)

        return jnp.sum(jnp.square(jax.vmap(one)(xb)))

    g = jax.grad(loss)(xb)
    assert np.isfinite(float(jnp.sum(g))) and float(jnp.sum(jnp.abs(g))) > 0

    # DispatchState is a registered pytree: it may cross jit boundaries
    xe_j, st_j = jax.jit(lambda x: d.dispatch(x, idx, gates))(x1)
    y_j = d.combine(xe_j * 2.0, st_j)
    np.testing.assert_allclose(
        np.asarray(y_j),
        np.asarray(d.combine(d.dispatch(x1, idx, gates)[0] * 2.0, st1)),
        atol=1e-6,
    )


def test_combine_accumulates_in_fp32():
    """Regression for the bf16 scatter-add combine: many sorted rows adding
    into one token must accumulate in fp32 and round once. The old
    ye.dtype accumulator loses low bits on every += and lands measurably
    farther from the fp32 oracle than one final rounding."""
    cfg, moe = _cfg(E=4, k=4)
    d = SortedDispatcher(cfg, moe, None)
    rng = np.random.default_rng(0)
    T, k, D = 64, 4, 32
    token = jnp.asarray(np.repeat(np.arange(T), k).astype(np.int32))
    N = T * k
    dest = jnp.arange(N, dtype=jnp.int32)
    gate = jnp.asarray(rng.uniform(0.2, 1.0, size=(N,)).astype(np.float32))
    ye = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))

    from repro.core.dispatch.base import DispatchLayout, DispatchState

    state = DispatchState(
        layout=DispatchLayout("sorted", 4, group_sizes=None, row_block=1),
        residuals={"token": token, "dest": dest, "gate_sorted": gate},
        static={"tokens": T},
    )
    ye_bf = ye.astype(jnp.bfloat16)
    got = d.combine(ye_bf, state)
    assert got.dtype == jnp.bfloat16

    # fp32 oracle on the bf16 inputs: only the inputs are rounded
    oracle = jnp.zeros((T, D), jnp.float32).at[token].add(
        ye_bf.astype(jnp.float32) * gate[:, None]
    )
    # the old behavior: accumulate in bf16
    naive = jnp.zeros((T, D), jnp.bfloat16).at[token].add(
        ye_bf * gate[:, None].astype(jnp.bfloat16)
    )
    err_new = float(jnp.max(jnp.abs(got.astype(jnp.float32) - oracle)))
    err_old = float(jnp.max(jnp.abs(naive.astype(jnp.float32) - oracle)))
    # one final rounding: at most 1/2 ulp of the oracle value
    ulp = float(jnp.max(jnp.abs(oracle))) * 2.0**-8
    assert err_new <= ulp, (err_new, ulp)
    assert err_new < err_old, (err_new, err_old)


def test_fused_dispatch_matches_unfused_e2e():
    """Dispatcher-level fused mode at the production KERNEL_ROW_BLOCK=128:
    apply() with moe.fused_dispatch routes through the dispatch-in-kernel
    grouped GEMM and matches the materializing kernel path token for token
    (kernel-level sweeps over shapes/dtypes live in test_autotune.py)."""
    cfg, moe = _cfg(dispatcher="sorted")
    moe_f = dataclasses.replace(moe, fused_dispatch=True)
    params = _params(cfg, moe)
    dU = SortedDispatcher(cfg, moe, None)
    dF = SortedDispatcher(cfg, moe_f, None)
    rng = np.random.default_rng(9)
    T, E, k = 48, moe.num_experts, moe.top_k
    x = jnp.asarray(rng.normal(size=(T, 32)).astype(np.float32) * 0.5)
    idx = jnp.asarray(
        np.stack([rng.permutation(E)[:k] for _ in range(T)]).astype(np.int32)
    )
    gates = jnp.asarray(rng.uniform(0.1, 1.0, size=(T, k)).astype(np.float32))
    yU = dU.apply(params["experts"], x, gates, idx, use_kernel=True)
    yF = dF.apply(params["experts"], x, gates, idx, use_kernel=True)
    np.testing.assert_allclose(np.asarray(yF), np.asarray(yU), atol=2e-5)
    # without the kernel the flag is inert: plain XLA unfused path
    yX = dF.apply(params["experts"], x, gates, idx, use_kernel=False)
    np.testing.assert_allclose(np.asarray(yF), np.asarray(yX), atol=2e-4)


def test_fused_dispatch_requires_sorted():
    with pytest.raises(AssertionError, match="fused_dispatch"):
        MoEConfig(dispatcher="allgather", fused_dispatch=True)
    assert MoEConfig(dispatcher="sorted", fused_dispatch=True).fused_dispatch


def test_with_dispatcher_helper():
    cfg, _ = _cfg(dispatcher="allgather")
    assert with_dispatcher(cfg, "sorted").moe.dispatcher == "sorted"
    assert with_dispatcher(cfg, None).moe.dispatcher == "allgather"
    dense = ModelConfig(name="d", family="dense")
    assert with_dispatcher(dense, "sorted") is dense


def test_alltoall_parity_on_trivial_mesh():
    """alltoall == allgather == sorted on a 1-device EP mesh (the full
    multi-device parity check lives in test_distributed.py)."""
    from repro.sharding.rules import FoldingPlan

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg, moe = _cfg(cf=None, dispatcher="alltoall")
    params = _params(cfg, moe)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 32)) * 0.3
    plan = FoldingPlan.make(cfg, mesh)
    assert plan.moe_mode == "ep"
    with mesh:
        y_a2a, _ = jax.jit(
            lambda p, x: moe_apply(cfg, moe, plan, p, x)
        )(params, x)
        ys = {}
        for name in ("allgather", "sorted"):
            moe_n = dataclasses.replace(moe, dispatcher=name)
            ys[name], _ = jax.jit(
                lambda p, x, m=moe_n: moe_apply(cfg, m, plan, p, x)
            )(params, x)
    np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(ys["allgather"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(ys["sorted"]), atol=1e-5)
