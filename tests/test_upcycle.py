"""Sparse upcycling (paper §3.1, §5.2): exactness, subsets, checkpoints."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import init_model, make_batch, tiny_dense
from repro.config import MoEConfig
from repro.core.upcycle import upcycle_config, upcycle_params
from repro.models.model import forward, model_decl


def _dense(fp32=True):
    cfg = tiny_dense(num_layers=4, dtype="float32")
    return cfg, init_model(cfg, fp32=True)


def test_mixtral_upcycle_preserves_dense_function(rng):
    """THE paper claim (Fig. 3): with the Mixtral-type router, the upcycled
    MoE's first forward pass equals the dense model."""
    cfg, dp = _dense()
    moe_c = upcycle_config(cfg, MoEConfig(num_experts=4, top_k=2,
                                          capacity_factor=None, router_type="mixtral"))
    mp = upcycle_params(cfg, moe_c, dp, jax.random.PRNGKey(1))
    batch = make_batch(cfg, 2, 16, rng, labels=False)
    ld, _ = forward(cfg, None, dp, batch)
    lm, _ = forward(moe_c, None, mp, batch)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lm), atol=1e-4)


def test_st_upcycle_does_not_preserve(rng):
    cfg, dp = _dense()
    moe_c = upcycle_config(cfg, MoEConfig(num_experts=4, top_k=2,
                                          capacity_factor=None, router_type="st"))
    mp = upcycle_params(cfg, moe_c, dp, jax.random.PRNGKey(1))
    batch = make_batch(cfg, 2, 16, rng, labels=False)
    ld, _ = forward(cfg, None, dp, batch)
    lm, _ = forward(moe_c, None, mp, batch)
    assert float(jnp.max(jnp.abs(ld - lm))) > 1e-2


def test_experts_are_exact_copies():
    cfg, dp = _dense()
    moe_c = upcycle_config(cfg, MoEConfig(num_experts=4, top_k=2))
    mp = upcycle_params(cfg, moe_c, dp, jax.random.PRNGKey(1))
    wg = np.asarray(mp["stack"]["slot0"]["ffn"]["experts"]["w_gate"])
    dense_wg = np.asarray(dp["stack"]["slot0"]["ffn"]["w_gate"])
    for e in range(4):
        np.testing.assert_array_equal(wg[:, e], dense_wg)


def test_non_ffn_weights_copied_verbatim():
    cfg, dp = _dense()
    moe_c = upcycle_config(cfg, MoEConfig(num_experts=4, top_k=2))
    mp = upcycle_params(cfg, moe_c, dp, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(
        np.asarray(mp["embed"]["embedding"]), np.asarray(dp["embed"]["embedding"])
    )
    np.testing.assert_array_equal(
        np.asarray(mp["stack"]["slot0"]["mixer"]["wq"]),
        np.asarray(dp["stack"]["slot0"]["mixer"]["wq"]),
    )


def test_subset_upcycle_moe_layer_freq(rng):
    """Paper §3.1: 'convert a subset of the feed-forward layers'."""
    cfg, dp = _dense()
    moe_c = upcycle_config(cfg, MoEConfig(num_experts=4, top_k=2,
                                          capacity_factor=None, moe_layer_freq=2))
    mp = upcycle_params(cfg, moe_c, dp, jax.random.PRNGKey(1))
    assert set(mp["stack"]) == {"slot0", "slot1"}
    assert "router" in mp["stack"]["slot1"]["ffn"]  # every 2nd layer is MoE
    assert "w_gate" in mp["stack"]["slot0"]["ffn"]  # odd layers stay dense
    batch = make_batch(cfg, 2, 16, rng, labels=False)
    ld, _ = forward(cfg, None, dp, batch)
    lm, _ = forward(moe_c, None, mp, batch)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lm), atol=1e-4)


def test_upcycle_refuses_ffn_free_arch():
    from repro.config import get_config

    with pytest.raises(AssertionError):
        upcycle_config(get_config("mamba2-2.7b"), MoEConfig())


def test_checkpoint_roundtrip_and_upcycle_on_load(tmp_path, rng):
    from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint, upcycle_on_load

    cfg = tiny_dense(num_layers=2)
    dp = init_model(cfg)
    save_checkpoint(str(tmp_path / "ckpt"), dp, step=7)
    loaded = load_checkpoint(str(tmp_path / "ckpt"))
    for a, b in zip(jax.tree.leaves(dp), jax.tree.leaves(loaded)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))

    moe_c = upcycle_config(cfg, MoEConfig(num_experts=4, top_k=2))
    mp, _ = upcycle_on_load(str(tmp_path / "ckpt"), cfg, moe_c, None, jax.random.PRNGKey(0))
    assert mp["stack"]["slot0"]["ffn"]["experts"]["w_gate"].shape[1] == 4
