"""End-to-end training behaviour: loss decreases; upcycled-from-trained-dense
starts at the dense loss (the paper's warm-start effect, Fig. 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.config import MoEConfig, TrainConfig
from repro.core.upcycle import upcycle_config, upcycle_params
from repro.data.pipeline import make_train_iter
from repro.train.trainer import Trainer


def _tcfg(steps=30, B=8, S=32):
    return TrainConfig(global_batch=B, seq_len=S, lr=3e-3, lr_min=3e-4,
                       warmup_steps=5, total_steps=steps, log_every=10, seed=3)


def test_loss_decreases_dense():
    cfg = tiny_dense(num_layers=2, vocab_size=256)
    tcfg = _tcfg()
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
    tr = Trainer(cfg, tcfg, data_iter=it)
    tr.run(30, log=lambda *_: None)
    first, last = tr.history[0]["ce"], tr.history[-1]["ce"]
    assert last < first - 0.3, (first, last)


def test_loss_decreases_moe():
    cfg = tiny_dense(num_layers=2, vocab_size=256).replace(
        family="moe",
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0),
    )
    tcfg = _tcfg()
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
    tr = Trainer(cfg, tcfg, data_iter=it)
    tr.run(30, log=lambda *_: None)
    assert tr.history[-1]["ce"] < tr.history[0]["ce"] - 0.3
    assert tr.history[-1]["load_balance_loss"] > 0


def test_loss_decreases_moe_sorted_dispatcher():
    """End-to-end training through the sorted dropless dispatcher."""
    cfg = tiny_dense(num_layers=2, vocab_size=256).replace(
        family="moe",
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=None,
                      dispatcher="sorted"),
    )
    tcfg = _tcfg()
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
    tr = Trainer(cfg, tcfg, data_iter=it)
    tr.run(30, log=lambda *_: None)
    assert tr.history[-1]["ce"] < tr.history[0]["ce"] - 0.3
    assert tr.history[-1]["load_balance_loss"] > 0


def test_trainer_dispatcher_override_matches_explicit_config():
    """Trainer(dispatcher=...) rewrites the MoE config; same seed + data =>
    identical first-step loss to the explicitly-configured run."""
    base = tiny_dense(num_layers=1, vocab_size=256).replace(
        family="moe",
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=None),
    )
    tcfg = _tcfg(steps=2)
    runs = []
    for cfg, disp in [
        (base, "sorted"),
        (base.replace(moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=None,
                                    dispatcher="sorted")), None),
    ]:
        it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
        tr = Trainer(cfg, tcfg, data_iter=it, dispatcher=disp)
        assert tr.cfg.moe.dispatcher == "sorted"
        tr.run(2, log=lambda *_: None)
        runs.append(tr.history[0]["loss"])
    assert runs[0] == runs[1], runs


def test_train_step_use_kernel_sorted():
    """Full train steps on the Pallas hot path (interpret mode): the sorted
    dropless dispatcher's grouped GEMM AND flash attention both run under
    jax.grad via their custom_vjp backward kernels — finite loss/grad-norm,
    loss moves."""
    cfg = tiny_dense(num_layers=2, vocab_size=256).replace(
        family="moe",
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=None,
                      dispatcher="sorted"),
    )
    tcfg = TrainConfig(global_batch=4, seq_len=32, lr=3e-3, lr_min=3e-4,
                       warmup_steps=2, total_steps=3, log_every=1, seed=3)
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
    tr = Trainer(cfg, tcfg, data_iter=it, use_kernel=True)
    tr.run(3, log=lambda *_: None)
    for rec in tr.history:
        assert np.isfinite(rec["loss"]), rec
        assert np.isfinite(rec["grad_norm"]) and rec["grad_norm"] > 0, rec
    assert tr.history[-1]["loss"] < tr.history[0]["loss"] + 0.1


def test_kernel_step_matches_xla_step():
    """One optimizer step with use_kernel=True vs False from identical
    init/data: the kernel path is a numerical drop-in for training (same
    loss to fp tolerance)."""
    cfg = tiny_dense(num_layers=1, vocab_size=256).replace(
        family="moe",
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=None,
                      dispatcher="sorted"),
    )
    tcfg = TrainConfig(global_batch=4, seq_len=32, lr=3e-3, lr_min=3e-4,
                       warmup_steps=2, total_steps=2, log_every=1, seed=3)
    losses = {}
    for uk in (False, True):
        it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
        tr = Trainer(cfg, tcfg, data_iter=it, use_kernel=uk)
        tr.run(2, log=lambda *_: None)
        losses[uk] = [r["loss"] for r in tr.history]
    np.testing.assert_allclose(losses[False], losses[True], atol=5e-2)


def test_upcycled_starts_at_dense_loss():
    """Train dense briefly, upcycle, and check the MoE's first-step CE
    matches the dense model's CE (Mixtral router) — the warm-start claim."""
    cfg = tiny_dense(num_layers=2, vocab_size=256)
    tcfg = _tcfg(steps=40)
    it = make_train_iter(cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=3)
    tr = Trainer(cfg, tcfg, data_iter=it)
    tr.run(40, log=lambda *_: None)
    dense_eval = tr.eval_loss(batches=4)

    moe_cfg = upcycle_config(cfg, MoEConfig(num_experts=4, top_k=2, capacity_factor=None))
    moe_params = upcycle_params(cfg, moe_cfg, tr.params, jax.random.PRNGKey(9))
    tr_moe = Trainer(moe_cfg, tcfg, params=moe_params, data_iter=it)
    moe_eval = tr_moe.eval_loss(batches=4)
    assert abs(moe_eval - dense_eval) < 0.05, (dense_eval, moe_eval)
