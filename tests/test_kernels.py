"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import expert_gemm, flash_attention
from repro.kernels.ref import expert_gemm_ref, flash_attention_ref

EG_SHAPES = [  # (E, C, D, F)
    (2, 16, 32, 64),
    (4, 64, 128, 256),
    (8, 128, 64, 128),
    (1, 256, 128, 512),
    (3, 32, 96, 160),  # non-power-of-two dims
]


@pytest.mark.parametrize("shape", EG_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_gemm(rng, shape, dtype):
    E, C, D, F = shape
    xe = jnp.asarray(rng.standard_normal((E, C, D)), dtype) * 0.3
    wg = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), dtype) * 0.05
    y = expert_gemm(xe, wg, wu, wd)
    yr = expert_gemm_ref(xe, wg, wu, wd)
    atol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=atol
    )


def test_expert_gemm_group_batched(rng):
    """The (G, E, C, D) layout the MoE dispatcher feeds the kernel."""
    xe = jnp.asarray(rng.standard_normal((3, 4, 16, 32)), jnp.float32) * 0.2
    wg = jnp.asarray(rng.standard_normal((4, 32, 64)), jnp.float32) * 0.1
    wu = jnp.asarray(rng.standard_normal((4, 32, 64)), jnp.float32) * 0.1
    wd = jnp.asarray(rng.standard_normal((4, 64, 32)), jnp.float32) * 0.1
    y = expert_gemm(xe, wg, wu, wd)
    yr = jax.vmap(lambda x: expert_gemm_ref(x, wg, wu, wd))(xe)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)


FA_CASES = [  # (B, Sq, Sk, H, KV, d, causal, window)
    (2, 64, 64, 4, 2, 32, True, None),
    (1, 32, 128, 4, 4, 64, True, None),  # decode-ish: Sq < Sk, right-aligned
    (2, 128, 128, 8, 2, 32, True, 16),  # sliding window
    (1, 64, 64, 2, 2, 16, False, None),  # encoder (non-causal)
    (2, 1, 256, 4, 1, 64, True, None),  # single-token decode, MQA
    (1, 256, 256, 4, 4, 128, True, None),  # head_dim 128 (TPU-native)
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(rng, case, dtype):
    B, Sq, Sk, H, KV, d, causal, window = case
    q = jnp.asarray(rng.standard_normal((B, Sq, H, d)), dtype) * 0.3
    k = jnp.asarray(rng.standard_normal((B, Sk, KV, d)), dtype) * 0.3
    v = jnp.asarray(rng.standard_normal((B, Sk, KV, d)), dtype) * 0.3
    y = flash_attention(q, k, v, causal=causal, window=window)
    kb, vb = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)
    yr = flash_attention_ref(q, kb, vb, causal=causal, window=window)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=atol
    )


def test_flash_matches_model_blockwise_path(rng):
    """Kernel vs the model's blockwise XLA attention (same schedule)."""
    from repro.models.attention import attention_core

    B, S, H, KV, d = 2, 128, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32) * 0.3
    k = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    y_model = attention_core(q, k, v, pos, pos)
    y_kernel = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel), atol=1e-5)
