"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import expert_gemm, flash_attention
from repro.kernels.ref import expert_gemm_ref, flash_attention_ref

EG_SHAPES = [  # (E, C, D, F)
    (2, 16, 32, 64),
    (4, 64, 128, 256),
    (8, 128, 64, 128),
    (1, 256, 128, 512),
    (3, 32, 96, 160),  # non-power-of-two dims
]


@pytest.mark.parametrize("shape", EG_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_gemm(rng, shape, dtype):
    E, C, D, F = shape
    xe = jnp.asarray(rng.standard_normal((E, C, D)), dtype) * 0.3
    wg = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), dtype) * 0.05
    y = expert_gemm(xe, wg, wu, wd)
    yr = expert_gemm_ref(xe, wg, wu, wd)
    atol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=atol
    )


def test_expert_gemm_group_batched(rng):
    """The (G, E, C, D) layout the MoE dispatcher feeds the kernel."""
    xe = jnp.asarray(rng.standard_normal((3, 4, 16, 32)), jnp.float32) * 0.2
    wg = jnp.asarray(rng.standard_normal((4, 32, 64)), jnp.float32) * 0.1
    wu = jnp.asarray(rng.standard_normal((4, 32, 64)), jnp.float32) * 0.1
    wd = jnp.asarray(rng.standard_normal((4, 64, 32)), jnp.float32) * 0.1
    y = expert_gemm(xe, wg, wu, wd)
    yr = jax.vmap(lambda x: expert_gemm_ref(x, wg, wu, wd))(xe)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)


GG_CASES = [  # (E, D, F, group_sizes, row_block)
    (4, 32, 64, (16, 0, 7, 9), 8),
    (2, 64, 128, (128, 128), 128),  # exactly tile-aligned groups
    (3, 96, 160, (1, 50, 13), 16),  # non-power-of-two dims, ragged groups
    (4, 32, 64, (0, 0, 0, 40), 8),  # all tokens on one expert (imbalance)
    (2, 32, 64, (0, 0), 8),  # nothing routed at all
]


@pytest.mark.parametrize("case", GG_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_gemm(rng, case, dtype):
    """Group-size-aware grouped GEMM (sorted dropless layout) vs the
    pure-jnp oracle, interpret mode."""
    from repro.core.dispatch.sorted import aligned_rows
    from repro.kernels.ops import grouped_gemm
    from repro.kernels.ref import grouped_gemm_ref

    E, D, F, gs, bc = case
    gs = np.asarray(gs, np.int32)
    N_pad = aligned_rows(int(gs.sum()), E, bc)
    # build the tile-aligned expert-sorted buffer: valid rows random, padding
    # rows POISONED (not zero) — the kernel must mask them, not rely on zeros
    xs = np.full((N_pad, D), 7.5, np.float32)
    padded = (gs + bc - 1) // bc * bc
    starts = np.cumsum(padded) - padded
    for e in range(E):
        xs[starts[e]:starts[e] + gs[e]] = rng.standard_normal((gs[e], D)) * 0.3
    xs = jnp.asarray(xs, dtype)
    wg = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), dtype) * 0.05
    y = grouped_gemm(xs, wg, wu, wd, jnp.asarray(gs), row_block=bc)
    yr = grouped_gemm_ref(xs, wg, wu, wd, jnp.asarray(gs), row_block=bc)
    # compare valid rows; padding rows must come out exactly zero
    valid = np.zeros(N_pad, bool)
    for e in range(E):
        valid[starts[e]:starts[e] + gs[e]] = True
    atol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32)[valid], np.asarray(yr, np.float32)[valid], atol=atol
    )
    np.testing.assert_array_equal(np.asarray(y, np.float32)[~valid], 0.0)


def test_grouped_gemm_matches_padded_expert_gemm(rng):
    """Same tokens through both layouts: flat sorted+group_sizes == dense
    padded (E, C, D) expert_gemm on the populated slots."""
    from repro.kernels.ref import expert_gemm_ref, grouped_gemm_ref

    E, C, D, F = 3, 8, 32, 64
    gs = np.array([8, 3, 5], np.int32)
    xe = np.zeros((E, C, D), np.float32)
    for e in range(E):
        xe[e, : gs[e]] = rng.standard_normal((gs[e], D)) * 0.3
    wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.float32) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.float32) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.float32) * 0.05
    y_pad = expert_gemm_ref(jnp.asarray(xe), wg, wu, wd)
    xs = np.concatenate([xe[e, : gs[e]] for e in range(E)])
    y_sorted = grouped_gemm_ref(jnp.asarray(xs), wg, wu, wd, jnp.asarray(gs))
    off = 0
    for e in range(E):
        np.testing.assert_allclose(
            np.asarray(y_sorted)[off : off + gs[e]],
            np.asarray(y_pad)[e, : gs[e]],
            atol=1e-5,
        )
        off += gs[e]


FA_CASES = [  # (B, Sq, Sk, H, KV, d, causal, window)
    (2, 64, 64, 4, 2, 32, True, None),
    (1, 32, 128, 4, 4, 64, True, None),  # decode-ish: Sq < Sk, right-aligned
    (2, 128, 128, 8, 2, 32, True, 16),  # sliding window
    (1, 64, 64, 2, 2, 16, False, None),  # encoder (non-causal)
    (2, 1, 256, 4, 1, 64, True, None),  # single-token decode, MQA
    (1, 256, 256, 4, 4, 128, True, None),  # head_dim 128 (TPU-native)
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(rng, case, dtype):
    B, Sq, Sk, H, KV, d, causal, window = case
    q = jnp.asarray(rng.standard_normal((B, Sq, H, d)), dtype) * 0.3
    k = jnp.asarray(rng.standard_normal((B, Sk, KV, d)), dtype) * 0.3
    v = jnp.asarray(rng.standard_normal((B, Sk, KV, d)), dtype) * 0.3
    y = flash_attention(q, k, v, causal=causal, window=window)
    kb, vb = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)
    yr = flash_attention_ref(q, kb, vb, causal=causal, window=window)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=atol
    )


def test_flash_matches_model_blockwise_path(rng):
    """Kernel vs the model's blockwise XLA attention (same schedule)."""
    from repro.models.attention import attention_core

    B, S, H, KV, d = 2, 128, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32) * 0.3
    k = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    y_model = attention_core(q, k, v, pos, pos)
    y_kernel = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel), atol=1e-5)
