"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import expert_gemm, flash_attention
from repro.kernels.ref import expert_gemm_ref, flash_attention_ref

EG_SHAPES = [  # (E, C, D, F)
    (2, 16, 32, 64),
    (4, 64, 128, 256),
    (8, 128, 64, 128),
    (1, 256, 128, 512),
    (3, 32, 96, 160),  # non-power-of-two dims
]


@pytest.mark.parametrize("shape", EG_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_gemm(rng, shape, dtype):
    E, C, D, F = shape
    xe = jnp.asarray(rng.standard_normal((E, C, D)), dtype) * 0.3
    wg = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), dtype) * 0.05
    y = expert_gemm(xe, wg, wu, wd)
    yr = expert_gemm_ref(xe, wg, wu, wd)
    atol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=atol
    )


def test_expert_gemm_group_batched(rng):
    """The (G, E, C, D) layout the MoE dispatcher feeds the kernel."""
    xe = jnp.asarray(rng.standard_normal((3, 4, 16, 32)), jnp.float32) * 0.2
    wg = jnp.asarray(rng.standard_normal((4, 32, 64)), jnp.float32) * 0.1
    wu = jnp.asarray(rng.standard_normal((4, 32, 64)), jnp.float32) * 0.1
    wd = jnp.asarray(rng.standard_normal((4, 64, 32)), jnp.float32) * 0.1
    y = expert_gemm(xe, wg, wu, wd)
    yr = jax.vmap(lambda x: expert_gemm_ref(x, wg, wu, wd))(xe)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5)


GG_CASES = [  # (E, D, F, group_sizes, row_block)
    (4, 32, 64, (16, 0, 7, 9), 8),
    (2, 64, 128, (128, 128), 128),  # exactly tile-aligned groups
    (3, 96, 160, (1, 50, 13), 16),  # non-power-of-two dims, ragged groups
    (4, 32, 64, (0, 0, 0, 40), 8),  # all tokens on one expert (imbalance)
    (2, 32, 64, (0, 0), 8),  # nothing routed at all
]


@pytest.mark.parametrize("case", GG_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_gemm(rng, case, dtype):
    """Group-size-aware grouped GEMM (sorted dropless layout) vs the
    pure-jnp oracle, interpret mode."""
    from repro.core.dispatch.sorted import aligned_rows
    from repro.kernels.ops import grouped_gemm
    from repro.kernels.ref import grouped_gemm_ref

    E, D, F, gs, bc = case
    gs = np.asarray(gs, np.int32)
    N_pad = aligned_rows(int(gs.sum()), E, bc)
    # build the tile-aligned expert-sorted buffer: valid rows random, padding
    # rows POISONED (not zero) — the kernel must mask them, not rely on zeros
    xs = np.full((N_pad, D), 7.5, np.float32)
    padded = (gs + bc - 1) // bc * bc
    starts = np.cumsum(padded) - padded
    for e in range(E):
        xs[starts[e]:starts[e] + gs[e]] = rng.standard_normal((gs[e], D)) * 0.3
    xs = jnp.asarray(xs, dtype)
    wg = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), dtype) * 0.05
    y = grouped_gemm(xs, wg, wu, wd, jnp.asarray(gs), row_block=bc)
    yr = grouped_gemm_ref(xs, wg, wu, wd, jnp.asarray(gs), row_block=bc)
    # compare valid rows; padding rows must come out exactly zero
    valid = np.zeros(N_pad, bool)
    for e in range(E):
        valid[starts[e]:starts[e] + gs[e]] = True
    atol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32)[valid], np.asarray(yr, np.float32)[valid], atol=atol
    )
    np.testing.assert_array_equal(np.asarray(y, np.float32)[~valid], 0.0)


def test_grouped_gemm_matches_padded_expert_gemm(rng):
    """Same tokens through both layouts: flat sorted+group_sizes == dense
    padded (E, C, D) expert_gemm on the populated slots."""
    from repro.kernels.ref import expert_gemm_ref, grouped_gemm_ref

    E, C, D, F = 3, 8, 32, 64
    gs = np.array([8, 3, 5], np.int32)
    xe = np.zeros((E, C, D), np.float32)
    for e in range(E):
        xe[e, : gs[e]] = rng.standard_normal((gs[e], D)) * 0.3
    wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.float32) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.float32) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.float32) * 0.05
    y_pad = expert_gemm_ref(jnp.asarray(xe), wg, wu, wd)
    xs = np.concatenate([xe[e, : gs[e]] for e in range(E)])
    y_sorted = grouped_gemm_ref(jnp.asarray(xs), wg, wu, wd, jnp.asarray(gs))
    off = 0
    for e in range(E):
        np.testing.assert_allclose(
            np.asarray(y_sorted)[off : off + gs[e]],
            np.asarray(y_pad)[e, : gs[e]],
            atol=1e-5,
        )
        off += gs[e]


FA_CASES = [  # (B, Sq, Sk, H, KV, d, causal, window)
    (2, 64, 64, 4, 2, 32, True, None),
    (1, 32, 128, 4, 4, 64, True, None),  # decode-ish: Sq < Sk, right-aligned
    (2, 128, 128, 8, 2, 32, True, 16),  # sliding window
    (1, 64, 64, 2, 2, 16, False, None),  # encoder (non-causal)
    (2, 1, 256, 4, 1, 64, True, None),  # single-token decode, MQA
    (1, 256, 256, 4, 4, 128, True, None),  # head_dim 128 (TPU-native)
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(rng, case, dtype):
    B, Sq, Sk, H, KV, d, causal, window = case
    q = jnp.asarray(rng.standard_normal((B, Sq, H, d)), dtype) * 0.3
    k = jnp.asarray(rng.standard_normal((B, Sk, KV, d)), dtype) * 0.3
    v = jnp.asarray(rng.standard_normal((B, Sk, KV, d)), dtype) * 0.3
    y = flash_attention(q, k, v, causal=causal, window=window)
    kb, vb = jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2)
    yr = flash_attention_ref(q, kb, vb, causal=causal, window=window)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=atol
    )


# ---------------------------------------------------------------------------
# Gradient parity: jax.grad through the Pallas custom_vjp vs the ref / XLA
# oracles (interpret mode)
# ---------------------------------------------------------------------------

EG_GRAD_SHAPES = [  # (E, C, D, F): pow2, non-pow2, single-expert
    (2, 16, 32, 64),
    (3, 32, 96, 160),
    (1, 64, 128, 256),
]


@pytest.mark.parametrize("shape", EG_GRAD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_expert_gemm_grad_parity(rng, shape, dtype):
    """jax.grad through the padded Pallas kernel == grad of the ref oracle
    for inputs and all three expert weights."""
    E, C, D, F = shape
    xe = jnp.asarray(rng.standard_normal((E, C, D)), dtype) * 0.3
    wg = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), dtype) * 0.05
    r = jnp.asarray(rng.standard_normal((E, C, D)), dtype)

    gk = jax.grad(lambda *a: jnp.sum(expert_gemm(*a) * r), argnums=(0, 1, 2, 3))(
        xe, wg, wu, wd
    )
    gr = jax.grad(lambda *a: jnp.sum(expert_gemm_ref(*a) * r), argnums=(0, 1, 2, 3))(
        xe, wg, wu, wd
    )
    atol = 2e-4 if dtype == jnp.float32 else 5e-2
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=atol
        )


GG_GRAD_CASES = [  # (E, D, F, group_sizes, row_block)
    (4, 32, 64, (16, 0, 7, 9), 8),  # empty group + ragged tails
    (2, 64, 128, (128, 128), 128),  # exactly tile-aligned
    (3, 96, 160, (1, 50, 13), 16),  # non-power-of-two dims
    (4, 32, 64, (0, 0, 0, 40), 8),  # total imbalance
]


def _grouped_case(rng, E, D, F, gs, bc, dtype):
    from repro.core.dispatch.sorted import aligned_rows

    gs = np.asarray(gs, np.int32)
    N_pad = aligned_rows(int(gs.sum()), E, bc)
    xs = np.full((N_pad, D), 7.5, np.float32)  # poison the padding rows
    padded = (gs + bc - 1) // bc * bc
    starts = np.cumsum(padded) - padded
    for e in range(E):
        xs[starts[e]:starts[e] + gs[e]] = rng.standard_normal((gs[e], D)) * 0.3
    xs = jnp.asarray(xs, dtype)
    wg = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), dtype) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), dtype) * 0.05
    return xs, wg, wu, wd, jnp.asarray(gs), N_pad


@pytest.mark.parametrize("case", GG_GRAD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_gemm_grad_parity(rng, case, dtype):
    """jax.grad through the group-size-aware Pallas kernel (dgrad + wgrad +
    SwiGLU recompute) == grad of the masked-loop ref oracle. Covers empty
    experts (whose wgrad must be exactly zero) and poisoned padding rows
    (whose dx must be exactly zero)."""
    from repro.kernels.ops import grouped_gemm
    from repro.kernels.ref import grouped_gemm_ref

    E, D, F, gs, bc = case
    xs, wg, wu, wd, gsj, N_pad = _grouped_case(rng, E, D, F, gs, bc, dtype)
    r = jnp.asarray(rng.standard_normal((N_pad, D)), dtype)

    gk = jax.grad(
        lambda *a: jnp.sum(grouped_gemm(*a, gsj, row_block=bc) * r),
        argnums=(0, 1, 2, 3),
    )(xs, wg, wu, wd)
    gr = jax.grad(
        lambda *a: jnp.sum(grouped_gemm_ref(*a, gsj, row_block=bc) * r),
        argnums=(0, 1, 2, 3),
    )(xs, wg, wu, wd)
    atol = 2e-4 if dtype == jnp.float32 else 5e-2
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=atol
        )
    # empty experts: exactly-zero wgrad (their out blocks are never visited)
    gs_np = np.asarray(gs)
    for e in np.nonzero(gs_np == 0)[0]:
        np.testing.assert_array_equal(np.asarray(gk[1], np.float32)[e], 0.0)


def test_grouped_gemm_grad_matches_xla_path(rng):
    """Kernel-path grads == ragged_dot XLA-path grads on the same routing
    (the two paths Trainer(use_kernel=...) switches between)."""
    from repro.kernels.ops import grouped_gemm, grouped_gemm_xla

    E, D, F, gs, bc = 4, 32, 64, (16, 0, 7, 9), 8
    xs, wg, wu, wd, gsj, N_pad = _grouped_case(rng, E, D, F, gs, bc, jnp.float32)
    # XLA path consumes the compact buffer (row_block=1)
    gs_np = np.asarray(gs)
    padded = (gs_np + bc - 1) // bc * bc
    starts = np.cumsum(padded) - padded
    keep = np.concatenate(
        [np.arange(starts[e], starts[e] + gs_np[e]) for e in range(E)]
    )
    xc = jnp.asarray(np.asarray(xs)[keep])
    r = jnp.asarray(rng.standard_normal((N_pad, D)), jnp.float32)
    rc = jnp.asarray(np.asarray(r)[keep])

    gk = jax.grad(
        lambda *a: jnp.sum(grouped_gemm(*a, gsj, row_block=bc) * r),
        argnums=(1, 2, 3),
    )(xs, wg, wu, wd)
    gx = jax.grad(
        lambda *a: jnp.sum(grouped_gemm_xla(*a, gsj) * rc), argnums=(1, 2, 3)
    )(xc, wg, wu, wd)
    for a, b in zip(gk, gx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_grouped_gemm_backward_saves_no_nf_residual(rng):
    """The recompute contract: the custom_vjp forward saves only the O(N*D)
    inputs — never an (N, F) gate/up/h intermediate."""
    from repro.kernels.expert_gemm import grouped_gemm_residuals

    E, D, F, gs, bc = 4, 32, 64, (16, 0, 7, 9), 8
    xs, wg, wu, wd, gsj, N_pad = _grouped_case(rng, E, D, F, gs, bc, jnp.float32)
    res = grouped_gemm_residuals(xs, wg, wu, wd, gsj, blocks=(bc, 512, 512))
    shapes = [tuple(r.shape) for r in res]
    assert (N_pad, F) not in shapes, shapes
    # residuals are exactly the inputs
    assert sorted(shapes) == sorted(
        [(N_pad, D), (E, D, F), (E, D, F), (E, F, D), (E,)]
    ), shapes


FA_GRAD_CASES = [  # (B, Sq, Sk, H, KV, d, causal, window)
    (2, 64, 64, 4, 2, 32, True, None),  # GQA causal
    (1, 32, 128, 4, 4, 64, True, None),  # right-aligned Sq < Sk
    (2, 128, 128, 8, 2, 32, True, 16),  # sliding window
    (1, 64, 64, 2, 2, 16, False, None),  # non-causal (encoder)
]


@pytest.mark.parametrize("case", FA_GRAD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_grad_parity(rng, case, dtype):
    """jax.grad through the two-pass flash backward (p recomputed from the
    saved logsumexp) == grad of the dense softmax reference."""
    B, Sq, Sk, H, KV, d, causal, window = case
    q = jnp.asarray(rng.standard_normal((B, Sq, H, d)), dtype) * 0.3
    k = jnp.asarray(rng.standard_normal((B, Sk, KV, d)), dtype) * 0.3
    v = jnp.asarray(rng.standard_normal((B, Sk, KV, d)), dtype) * 0.3
    r = jnp.asarray(rng.standard_normal((B, Sq, H, d)), dtype)
    G = H // KV

    def loss_k(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=causal, window=window) * r)

    def loss_r(q, k, v):
        kb, vb = jnp.repeat(k, G, 2), jnp.repeat(v, G, 2)
        return jnp.sum(
            flash_attention_ref(q, kb, vb, causal=causal, window=window) * r
        )

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    atol = 5e-4 if dtype == jnp.float32 else 6e-2
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=atol
        )


def test_attention_core_kernel_path_grad(rng):
    """use_kernel=True routes attention_core through the Pallas kernel with
    matching values AND grads vs the XLA path."""
    from repro.models.attention import attention_core

    B, S, H, KV, d = 2, 64, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32) * 0.3
    k = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    r = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32)

    def loss(q, k, v, uk):
        return jnp.sum(attention_core(q, k, v, pos, pos, use_kernel=uk) * r)

    y0 = attention_core(q, k, v, pos, pos, use_kernel=False)
    y1 = attention_core(q, k, v, pos, pos, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)
    g0 = jax.grad(lambda *a: loss(*a, False), argnums=(0, 1, 2))(q, k, v)
    g1 = jax.grad(lambda *a: loss(*a, True), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g0, g1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_pick_lane_alignment():
    """_pick never returns a misaligned tile smaller than the dim: for the
    lane dims (align=128) it picks the largest multiple-of-128 divisor; the
    row/sublane dim only needs align=8, so padded capacities like C=192
    stay legal."""
    from repro.kernels.expert_gemm import _pick

    assert _pick(512, 384) == 384
    assert _pick(256, 384) == 128  # old halving loop landed on 96-ish splits
    assert _pick(512, 640) == 128
    assert _pick(512, 1536) == 512
    assert _pick(128, 96) == 96  # non-128-divisible dims: whole-dim tile
    assert _pick(512, 160) == 160
    for block, dim in [(512, 384), (128, 256), (512, 640)]:
        assert _pick(block, dim) % 128 == 0
    # row dim: sublane alignment preferred, never crashes on odd capacities
    assert _pick(128, 192, align=8) == 96
    assert _pick(128, 320, align=8) == 80
    assert _pick(128, 1, align=8) == 1
    assert _pick(128, 282, align=8) == 94  # no 8-divisor: largest divisor


def test_flash_matches_model_blockwise_path(rng):
    """Kernel vs the model's blockwise XLA attention (same schedule)."""
    from repro.models.attention import attention_core

    B, S, H, KV, d = 2, 128, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32) * 0.3
    k = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    y_model = attention_core(q, k, v, pos, pos)
    y_kernel = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel), atol=1e-5)
