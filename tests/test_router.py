"""Router algorithms (paper §2, §5.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig
from repro.core.router import route, router_decl
from repro.sharding.rules import init_from_decls


def _setup(router_type="mixtral", E=8, k=2, noisy=False, D=32):
    moe = MoEConfig(num_experts=E, top_k=k, router_type=router_type, noisy_gating=noisy)
    params = init_from_decls(router_decl(D, moe), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, D))
    return moe, params, x


def test_mixtral_gates_sum_to_one():
    moe, params, x = _setup("mixtral")
    gates, idx, _ = route(moe, params, x)
    np.testing.assert_allclose(np.sum(np.asarray(gates), -1), 1.0, rtol=1e-5)


def test_st_gates_do_not_sum_to_one():
    """ST-type keeps absolute softmax magnitudes (paper §5.2)."""
    moe, params, x = _setup("st")
    gates, idx, _ = route(moe, params, x)
    s = np.sum(np.asarray(gates), -1)
    assert np.all(s < 1.0) and np.all(s > 0.0)


def test_same_topk_selection():
    """Both routers pick the same experts (softmax is monotone)."""
    moe_m, params, x = _setup("mixtral")
    moe_s = MoEConfig(num_experts=8, top_k=2, router_type="st")
    _, idx_m, _ = route(moe_m, params, x)
    _, idx_s, _ = route(moe_s, params, x)
    np.testing.assert_array_equal(np.asarray(idx_m), np.asarray(idx_s))


def test_topk_indices_valid_and_distinct():
    moe, params, x = _setup(E=16, k=4)
    _, idx, _ = route(moe, params, x)
    idx = np.asarray(idx)
    assert idx.min() >= 0 and idx.max() < 16
    for row in idx:
        assert len(set(row.tolist())) == 4


def test_load_balance_loss_uniform_is_one():
    """With perfectly uniform routing, E * sum(f*p) == 1 (Switch §4)."""
    moe = MoEConfig(num_experts=4, top_k=1, aux_loss_coef=1.0)
    params = {"w_g": jnp.zeros((8, 4))}
    # uniform logits: p uniform; hard assignment via top_k picks expert 0
    # -> use random x with orthogonal w to get near-uniform dispatch
    key = jax.random.PRNGKey(0)
    params = {"w_g": jax.random.normal(key, (8, 4)) * 10}
    x = jax.random.normal(jax.random.PRNGKey(1), (4096, 8))
    _, _, aux = route(moe, params, x)
    assert aux["load_balance_loss"] >= 1.0 - 1e-5  # >= 1 always; =1 iff balanced


def test_noisy_gating_changes_selection():
    moe, params, x = _setup(noisy=True)
    params["w_noise"] = jnp.ones_like(params["w_noise"]) * 0.5
    _, idx1, _ = route(moe, params, x, rng=jax.random.PRNGKey(2), train=True)
    _, idx2, _ = route(moe, params, x, rng=jax.random.PRNGKey(3), train=True)
    assert not np.array_equal(np.asarray(idx1), np.asarray(idx2))
    # eval mode: deterministic
    _, idx3, _ = route(moe, params, x, rng=jax.random.PRNGKey(2), train=False)
    _, idx4, _ = route(moe, params, x, rng=jax.random.PRNGKey(3), train=False)
    np.testing.assert_array_equal(np.asarray(idx3), np.asarray(idx4))


def test_router_fp32_under_bf16_inputs():
    moe, params, x = _setup()
    gates, _, _ = route(moe, params, x.astype(jnp.bfloat16))
    assert gates.dtype == jnp.float32
