"""EP x DP sharded serving: the mesh-aware paged engine (expert weights
over the 'expert' axis, page pool partitioned per DP shard, decode through
the overlapped expert all-to-all) emits exactly the single-host engine's
greedy token streams — with Pallas kernels on and off, and across
mid-stream preemption under a tight per-shard pool.

Fake-device meshes lock jax's device count at first init, so every mesh
case runs in a subprocess (the ``test_distributed.py`` pattern). The HLO
structure test pins the overlap schedule's lowering: the compiled decode
step must contain ``collective-permute`` ops (the double-buffered ring
hops), not a monolithic ``all-to-all``.
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # fake-device meshes live on the host (CPU) platform; pin it so the
    # child never probes a real accelerator plugin (libtpu init can hang
    # when the machine has the plugin but no device)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=timeout,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


PREAMBLE = """
import dataclasses, json
import jax, numpy as np
from repro.config import get_config, smoke_config
from repro.launch.mesh import make_serving_mesh
from repro.models.model import model_decl
from repro.serving.engine import Request, ServingEngine
from repro.sharding.rules import init_from_decls

cfg = smoke_config(get_config("llama3-e8t2")).replace(dtype="float32")
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=None))
# the single-host oracles have no EP plan, so the e8t2 default 'alltoall'
# would trip strict dispatch (REPRO_STRICT_DISPATCH=1 in tests/CI);
# 'allgather' is what the fallback resolved to, and the mesh path still
# upgrades it to 'a2a_overlap' (engine defaults padded-CF dispatchers)
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatcher="allgather"))
params = jax.tree.map(
    lambda x: x.astype("float32") if x.dtype == "bfloat16" else x,
    init_from_decls(model_decl(cfg), jax.random.PRNGKey(0)),
)

def requests(seed=11, n=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(3, 40))).astype(np.int32),
                    max_new_tokens=int(rng.integers(3, 8)))
            for i in range(n)]

def run_engine(**kw):
    eng = ServingEngine(cfg, params, max_seq=64, cache_mode="paged",
                        page_size=8, prefill_chunk=16, **kw)
    outs = eng.run(requests())
    eng.page_pool.check_invariants()
    assert eng.page_pool.free_pages == eng.page_pool.num_pages
    return eng, outs
"""


def test_ep_dp_parity_and_preemption():
    """dp=2 x ep=4: sharded paged greedy decode == the single-host RING
    oracle, both with a roomy pool and with a tight per-shard pool that
    forces mid-stream preemption (recompute is exact for greedy)."""
    out = run_sub(PREAMBLE + """
ring = ServingEngine(cfg, params, max_batch=4, max_seq=64)
ref = ring.run(requests())

mesh = make_serving_mesh(dp=2, ep=4)
eng, sharded = run_engine(max_batch=4, mesh=mesh)
assert eng.cfg.moe.dispatcher == "a2a_overlap" and eng.cfg.moe.strict_dispatch
assert eng.page_pool.num_shards == 2 and eng.max_batch == 8
assert sharded == ref, {r: (ref[r], sharded[r])
                        for r in ref if ref[r] != sharded[r]}

# tight per-shard pool (7 pages/shard; the largest request alone needs 6):
# preemption-by-recompute must fire and still match token-for-token
eng2, tight = run_engine(max_batch=4, mesh=make_serving_mesh(dp=2, ep=4),
                         num_pages=14)
assert tight == ref
npre = sum(r.preemptions for r in eng2.sched.requests.values())
print("PREEMPTIONS", npre)
print("EP_PARITY_OK")
""")
    assert "EP_PARITY_OK" in out
    npre = int(out.split("PREEMPTIONS")[1].split()[0])
    assert npre > 0, "tight per-shard pool never exercised preemption"


def test_ep_dp_parity_with_kernels():
    """Same parity with the Pallas paged-attention decode kernel and expert
    GEMM kernels enabled under the sharded mesh."""
    out = run_sub(PREAMBLE + """
_, ref = run_engine(max_batch=4, use_kernel=True)
eng, sharded = run_engine(max_batch=4, use_kernel=True,
                          mesh=make_serving_mesh(dp=2, ep=2))
assert sharded == ref, {r: (ref[r], sharded[r])
                        for r in ref if ref[r] != sharded[r]}
print("EP_KERNEL_PARITY_OK")
""", devices=4)
    assert "EP_KERNEL_PARITY_OK" in out


def test_overlap_dispatcher_lowers_to_collective_permute():
    """The a2a_overlap decode step lowers to ppermute hops (the overlap
    schedule), while plain alltoall keeps the monolithic exchange — pinned
    so a refactor cannot silently fold the ring back into one collective."""
    out = run_sub(PREAMBLE + """
from repro.sharding.rules import FoldingPlan
from repro.core.moe import moe_apply, moe_decl

mesh = make_serving_mesh(dp=1, ep=4)
plan = FoldingPlan.make(cfg, mesh)
moe_params = init_from_decls(
    moe_decl(cfg, cfg.moe), jax.random.PRNGKey(1))
x = jax.random.normal(jax.random.PRNGKey(2), (8, 1, cfg.d_model), "float32")

def lower(name):
    m = dataclasses.replace(cfg.moe, dispatcher=name, strict_dispatch=True)
    fn = jax.jit(lambda p, x: moe_apply(cfg, m, plan, p, x)[0])
    return fn.lower(moe_params, x).compile().as_text()

hlo_overlap = lower("a2a_overlap")
hlo_mono = lower("alltoall")
assert "collective-permute" in hlo_overlap, "overlap schedule lost its ppermute hops"
assert "all-to-all" in hlo_mono, "monolithic schedule lost its all-to-all"
print("HLO_STRUCTURE_OK")
""", devices=4)
    assert "HLO_STRUCTURE_OK" in out
