"""Engine-parity suite: greedy decode through the paged-KV subsystem is
token-for-token identical to the ring-buffer engine (the parity oracle)
across a config sweep — MoE (e8t2), dense (llama3-8b), sliding-window,
sorted dispatcher, Pallas kernels on/off — including mid-stream slot
refill, preemption under a tight page pool, and mid-stream defrag.

Also pins the ring engine's bucketed-prefill compile cache (satellite:
one trace per padded prompt-length bucket, not per request)."""
import dataclasses

import numpy as np
import pytest

from conftest import init_model, tiny_dense
from repro.config import get_config, smoke_config
from repro.serving.engine import Request, ServingEngine


def _dropless(cfg):
    """Finite-CF drop sets depend on dispatch-group token counts, which
    legitimately differ between full prefill and chunked prefill — parity
    checks run dropless, like the prefill==forward equivalence tests.

    Also pins the e8t2 default 'alltoall' to 'allgather': this suite is
    single-host (no EP plan), where alltoall would trip the strict-dispatch
    gate (REPRO_STRICT_DISPATCH=1 in tests/CI) instead of quietly falling
    back — 'allgather' is exactly what the fallback resolved to."""
    if cfg.moe is None:
        return cfg
    moe = dataclasses.replace(cfg.moe, capacity_factor=None)
    if moe.dispatcher == "alltoall":
        moe = dataclasses.replace(moe, dispatcher="allgather")
    return cfg.replace(moe=moe)


def _requests(cfg, seed, n=6, lmin=3, lmax=40, new=(3, 8)):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(lmin, lmax))).astype(np.int32),
            max_new_tokens=int(rng.integers(*new)),
        )
        for i in range(n)
    ]


def _parity(cfg, params, paged_kw, seed=11, n=6, max_batch=3, max_seq=64,
            ring_kw=None, new=(3, 8)):
    ring = ServingEngine(cfg, params, max_batch=max_batch, max_seq=max_seq,
                         **(ring_kw or {}))
    out_ring = ring.run(_requests(cfg, seed, n, new=new))
    paged = ServingEngine(cfg, params, max_batch=max_batch, max_seq=max_seq,
                          cache_mode="paged", **paged_kw)
    out_paged = paged.run(_requests(cfg, seed, n, new=new))
    assert out_ring == out_paged, {
        rid: (out_ring[rid], out_paged[rid])
        for rid in out_ring if out_ring[rid] != out_paged[rid]
    }
    # the pool drains completely: freed == allocated
    paged.page_pool.check_invariants()
    assert paged.page_pool.free_pages == paged.page_pool.num_pages
    return paged


SWEEP = {
    "llama3-e8t2": {},
    "llama3-8b": {},
    "llama3-e8t2-sorted": dict(dispatcher="sorted"),
}


@pytest.mark.parametrize("arch_tag", sorted(SWEEP))
def test_engine_parity_archs(arch_tag):
    """Paged == ring, token for token, with mid-stream slot refill (6
    requests through 3 slots)."""
    arch = arch_tag.replace("-sorted", "")
    cfg = _dropless(smoke_config(get_config(arch)).replace(dtype="float32"))
    params = init_model(cfg, fp32=True)
    kw = dict(SWEEP[arch_tag])
    _parity(cfg, params, dict(page_size=8, prefill_chunk=16, **kw),
            ring_kw=kw, n=6)


def test_engine_parity_sliding_window():
    """Window config: ring keeps a W-slot ring; paged releases pages below
    the window. Same masked KV set => same tokens."""
    cfg = tiny_dense().replace(dtype="float32", sliding_window=16)
    params = init_model(cfg, fp32=True)
    paged = _parity(cfg, params, dict(page_size=4, prefill_chunk=8), n=5)
    # the window bound held: live pages never exceeded
    # ceil((W + ps)/ps) + 1 per active request
    per_req = paged.page_pool.pages_for(16 + 4) + 1
    assert paged.peak_used_pages <= 3 * per_req


def test_engine_parity_use_kernel():
    """Pallas path on both ends: expert GEMMs + paged-attention decode
    kernel vs the XLA gather path give the same greedy tokens."""
    cfg = _dropless(smoke_config(get_config("llama3-e8t2")).replace(dtype="float32"))
    params = init_model(cfg, fp32=True)
    xla = ServingEngine(cfg, params, max_batch=2, max_seq=48, cache_mode="paged",
                        page_size=8, prefill_chunk=16)
    out_xla = xla.run(_requests(cfg, 7, n=3, lmax=24, new=(3, 6)))
    kern = ServingEngine(cfg, params, max_batch=2, max_seq=48, cache_mode="paged",
                         page_size=8, prefill_chunk=16, use_kernel=True)
    out_kern = kern.run(_requests(cfg, 7, n=3, lmax=24, new=(3, 6)))
    assert out_xla == out_kern


def test_engine_parity_under_preemption():
    """A pool far smaller than ring capacity forces preemption-by-recompute;
    greedy determinism makes the recomputed streams identical."""
    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    paged = _parity(cfg, params, dict(page_size=8, num_pages=8, prefill_chunk=16),
                    seed=0, n=7, new=(6, 12))
    assert sum(r.preemptions for r in paged.sched.requests.values()) > 0, (
        "pool was large enough that preemption never fired — shrink it"
    )


def test_engine_parity_mid_stream_defrag():
    """Defrag (pool compaction + block-table rewrite) mid-stream is
    invisible to the decoded tokens."""
    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    ring = ServingEngine(cfg, params, max_batch=3, max_seq=64)
    out_ring = ring.run(_requests(cfg, 13))

    paged = ServingEngine(cfg, params, max_batch=3, max_seq=64,
                          cache_mode="paged", page_size=4, prefill_chunk=8)
    reqs = _requests(cfg, 13)
    for r in reqs:
        paged.submit(r)
    for i in range(40):
        if not paged.sched.has_work:
            break
        paged.step()
        if i % 3 == 2:
            paged.defrag()
            paged.page_pool.check_invariants()
    assert not paged.sched.has_work
    assert out_ring == {r.rid: r.output for r in reqs}


def test_ring_prefill_compiles_once_per_bucket():
    """Regression (satellite): `_prefill_into_slot` used to build a fresh
    jax.jit per call, retracing every prefill. Prompts of length 5/6/7
    share the 16-bucket, 17 lands in 32 => exactly two traces."""
    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    engine = ServingEngine(cfg, params, max_batch=4, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                max_new_tokens=2)
        for i, L in enumerate([5, 6, 7, 17])
    ]
    engine.run(reqs)
    assert engine.prefill_traces == 2, engine.prefill_traces
    # same buckets again: zero new traces even across fresh requests
    more = [
        Request(rid=10 + i, prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                max_new_tokens=2)
        for i, L in enumerate([4, 9, 20])
    ]
    engine.run(more)
    assert engine.prefill_traces == 2, engine.prefill_traces


def test_bucketed_prefill_matches_exact():
    """Right-padded bucketed prefill (valid_len path) produces the same
    tokens as an engine whose bucket is the exact prompt length."""
    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    bucketed = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    out_b = bucketed.run(_requests(cfg, 17, n=4, lmax=30))
    exact = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    exact._bucket = lambda L: L  # defeat bucketing
    out_e = exact.run(_requests(cfg, 17, n=4, lmax=30))
    assert out_b == out_e
    assert bucketed.prefill_traces < exact.prefill_traces
