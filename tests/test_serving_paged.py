"""Engine-parity suite: greedy decode through the paged-KV subsystem is
token-for-token identical to the ring-buffer engine (the parity oracle)
across a config sweep — MoE (e8t2), dense (llama3-8b), sliding-window,
sorted dispatcher, Pallas kernels on/off — including mid-stream slot
refill, preemption under a tight page pool, and mid-stream defrag.

Also pins the ring engine's bucketed-prefill compile cache (satellite:
one trace per padded prompt-length bucket, not per request)."""
import dataclasses

import numpy as np
import pytest

from conftest import init_model, tiny_dense
from repro.config import get_config, smoke_config
from repro.serving.engine import Request, ServingEngine


def _dropless(cfg):
    """Finite-CF drop sets depend on dispatch-group token counts, which
    legitimately differ between full prefill and chunked prefill — parity
    checks run dropless, like the prefill==forward equivalence tests.

    Also pins the e8t2 default 'alltoall' to 'allgather': this suite is
    single-host (no EP plan), where alltoall would trip the strict-dispatch
    gate (REPRO_STRICT_DISPATCH=1 in tests/CI) instead of quietly falling
    back — 'allgather' is exactly what the fallback resolved to."""
    if cfg.moe is None:
        return cfg
    moe = dataclasses.replace(cfg.moe, capacity_factor=None)
    if moe.dispatcher == "alltoall":
        moe = dataclasses.replace(moe, dispatcher="allgather")
    return cfg.replace(moe=moe)


def _requests(cfg, seed, n=6, lmin=3, lmax=40, new=(3, 8)):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(lmin, lmax))).astype(np.int32),
            max_new_tokens=int(rng.integers(*new)),
        )
        for i in range(n)
    ]


def _parity(cfg, params, paged_kw, seed=11, n=6, max_batch=3, max_seq=64,
            ring_kw=None, new=(3, 8)):
    ring = ServingEngine(cfg, params, max_batch=max_batch, max_seq=max_seq,
                         **(ring_kw or {}))
    out_ring = ring.run(_requests(cfg, seed, n, new=new))
    paged = ServingEngine(cfg, params, max_batch=max_batch, max_seq=max_seq,
                          cache_mode="paged", **paged_kw)
    out_paged = paged.run(_requests(cfg, seed, n, new=new))
    assert out_ring == out_paged, {
        rid: (out_ring[rid], out_paged[rid])
        for rid in out_ring if out_ring[rid] != out_paged[rid]
    }
    # the pool drains completely: freed == allocated
    paged.page_pool.check_invariants()
    assert paged.page_pool.free_pages == paged.page_pool.num_pages
    return paged


SWEEP = {
    "llama3-e8t2": {},
    "llama3-8b": {},
    "llama3-e8t2-sorted": dict(dispatcher="sorted"),
}


@pytest.mark.parametrize("arch_tag", sorted(SWEEP))
def test_engine_parity_archs(arch_tag):
    """Paged == ring, token for token, with mid-stream slot refill (6
    requests through 3 slots)."""
    arch = arch_tag.replace("-sorted", "")
    cfg = _dropless(smoke_config(get_config(arch)).replace(dtype="float32"))
    params = init_model(cfg, fp32=True)
    kw = dict(SWEEP[arch_tag])
    _parity(cfg, params, dict(page_size=8, prefill_chunk=16, **kw),
            ring_kw=kw, n=6)


def test_engine_parity_sliding_window():
    """Window config: ring keeps a W-slot ring; paged releases pages below
    the window. Same masked KV set => same tokens."""
    cfg = tiny_dense().replace(dtype="float32", sliding_window=16)
    params = init_model(cfg, fp32=True)
    paged = _parity(cfg, params, dict(page_size=4, prefill_chunk=8), n=5)
    # the window bound held: live pages never exceeded
    # ceil((W + ps)/ps) + 1 per active request
    per_req = paged.page_pool.pages_for(16 + 4) + 1
    assert paged.peak_used_pages <= 3 * per_req


def test_engine_parity_use_kernel():
    """Pallas path on both ends: expert GEMMs + paged-attention decode
    kernel vs the XLA gather path give the same greedy tokens."""
    cfg = _dropless(smoke_config(get_config("llama3-e8t2")).replace(dtype="float32"))
    params = init_model(cfg, fp32=True)
    xla = ServingEngine(cfg, params, max_batch=2, max_seq=48, cache_mode="paged",
                        page_size=8, prefill_chunk=16)
    out_xla = xla.run(_requests(cfg, 7, n=3, lmax=24, new=(3, 6)))
    kern = ServingEngine(cfg, params, max_batch=2, max_seq=48, cache_mode="paged",
                         page_size=8, prefill_chunk=16, use_kernel=True)
    out_kern = kern.run(_requests(cfg, 7, n=3, lmax=24, new=(3, 6)))
    assert out_xla == out_kern


def test_engine_parity_under_preemption():
    """A pool far smaller than ring capacity forces preemption-by-recompute;
    greedy determinism makes the recomputed streams identical."""
    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    paged = _parity(cfg, params, dict(page_size=8, num_pages=8, prefill_chunk=16),
                    seed=0, n=7, new=(6, 12))
    assert sum(r.preemptions for r in paged.sched.requests.values()) > 0, (
        "pool was large enough that preemption never fired — shrink it"
    )


def test_engine_parity_mid_stream_defrag():
    """Defrag (pool compaction + block-table rewrite) mid-stream is
    invisible to the decoded tokens."""
    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    ring = ServingEngine(cfg, params, max_batch=3, max_seq=64)
    out_ring = ring.run(_requests(cfg, 13))

    paged = ServingEngine(cfg, params, max_batch=3, max_seq=64,
                          cache_mode="paged", page_size=4, prefill_chunk=8)
    reqs = _requests(cfg, 13)
    for r in reqs:
        paged.submit(r)
    for i in range(40):
        if not paged.sched.has_work:
            break
        paged.step()
        if i % 3 == 2:
            paged.defrag()
            paged.page_pool.check_invariants()
    assert not paged.sched.has_work
    assert out_ring == {r.rid: r.output for r in reqs}


def test_ring_prefill_compiles_once_per_bucket():
    """Regression (satellite): `_prefill_into_slot` used to build a fresh
    jax.jit per call, retracing every prefill. Prompts of length 5/6/7
    share the 16-bucket, 17 lands in 32 => exactly two traces."""
    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    engine = ServingEngine(cfg, params, max_batch=4, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                max_new_tokens=2)
        for i, L in enumerate([5, 6, 7, 17])
    ]
    engine.run(reqs)
    assert engine.prefill_traces == 2, engine.prefill_traces
    # same buckets again: zero new traces even across fresh requests
    more = [
        Request(rid=10 + i, prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                max_new_tokens=2)
        for i, L in enumerate([4, 9, 20])
    ]
    engine.run(more)
    assert engine.prefill_traces == 2, engine.prefill_traces


# -- prefix-cache KV reuse ---------------------------------------------------


def _shared_prefix_requests(cfg, seed, n=6, stem_len=12, tail=(1, 10),
                            new=(3, 6)):
    """n requests sharing one stem: rid 4 is the bare stem and admits in
    the second wave once the stem is cached (full-coverage hit -> COW);
    the rest append random tails (partial hits)."""
    rng = np.random.default_rng(seed)
    stem = rng.integers(0, cfg.vocab_size, stem_len).astype(np.int32)
    reqs = []
    for i in range(n):
        t = rng.integers(0, cfg.vocab_size, int(rng.integers(*tail))).astype(np.int32)
        prompt = stem.copy() if i == 4 else np.concatenate([stem, t])
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(*new))))
    return reqs


def test_prefix_cache_parity_and_hit_accounting():
    """Shared-prefix traffic with the radix cache on decodes token-for-token
    what the cache-less paged engine decodes, while the stats show real
    hits, credited admission, and at least one COW clone."""
    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    kw = dict(max_batch=3, max_seq=64, cache_mode="paged", page_size=4,
              prefill_chunk=8)
    plain = ServingEngine(cfg, params, **kw)
    out_plain = plain.run(_shared_prefix_requests(cfg, 5))
    cached = ServingEngine(cfg, params, prefix_cache=True, **kw)
    out_cached = cached.run(_shared_prefix_requests(cfg, 5))
    assert out_plain == out_cached
    st = cached.kv_stats()["prefix"]
    # 6 requests x 12-token stem through 3 slots: the later waves must hit
    assert st["hits"] > 0 and st["hit_pages"] > 0
    assert st["hit_tokens"] > 0 and st["inserted_pages"] >= 3
    assert st["cow_clones"] >= 1, "the bare-stem request never COW-cloned"
    # drained: every cached page at refcount zero, fully reclaimable
    pool = cached.page_pool
    pool.check_invariants()
    assert not pool._refs and all(r == 0 for r in pool._shared.values())
    pool.drop_prefix_cache()
    assert pool.free_pages == pool.num_pages


def test_cow_never_mutates_shared_pages_on_device():
    """Device-content check: a full-coverage hit writes its recompute chunk
    and decode tokens into the COW clone and fresh pages — the cached KV
    pages are bit-identical before and after the hit request's lifetime."""
    import jax

    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64,
                        cache_mode="paged", page_size=4, prefill_chunk=8,
                        prefix_cache=True)
    stem = np.arange(12, dtype=np.int32) % cfg.vocab_size
    out0 = eng.run([Request(rid=0, prompt=stem.copy(), max_new_tokens=3)])
    pool = eng.page_pool
    shared = sorted(pool._shared)
    assert len(shared) == 3, "12-token prompt should cache 3 full pages"
    before = [np.asarray(leaf[:, shared])
              for leaf in jax.tree.leaves(eng.pool_dev)]
    out1 = eng.run([Request(rid=1, prompt=stem.copy(), max_new_tokens=5)])
    assert pool.cow_clones >= 1
    assert out1[1][:3] == out0[0], "same prompt, same greedy stream"
    after = [np.asarray(leaf[:, shared])
             for leaf in jax.tree.leaves(eng.pool_dev)]
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)


# -- speculative decoding ----------------------------------------------------


def test_speculative_parity_same_drafter():
    """Drafter == verifier (same params): token-for-token parity with the
    plain paged engine, every draft accepted, and verify steps strictly
    fewer than one-token-per-step decode would need."""
    from repro.serving.speculative import SpeculativeEngine

    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    kw = dict(max_batch=2, max_seq=64, page_size=4, prefill_chunk=8)
    plain = ServingEngine(cfg, params, cache_mode="paged", **kw)
    out_plain = plain.run(_requests(cfg, 21, n=4, lmax=20, new=(4, 9)))
    spec = SpeculativeEngine(cfg, params, cfg, params, draft_k=3, **kw)
    out_spec = spec.run(_requests(cfg, 21, n=4, lmax=20, new=(4, 9)))
    assert out_plain == out_spec
    assert spec.drafted_tokens > 0 and spec.acceptance_rate == 1.0
    total_new = sum(len(o) for o in out_spec.values())
    assert spec.spec_steps < total_new, "speculation never batched tokens"
    spec.page_pool.check_invariants()
    assert spec.page_pool.free_pages == spec.page_pool.num_pages


def test_speculative_parity_with_bad_drafter():
    """A drafter that disagrees with the verifier (independently initialized
    params) costs acceptance, never correctness: greedy output is still
    identical to non-speculative decode."""
    from repro.serving.speculative import SpeculativeEngine

    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    bad = init_model(cfg, seed=99, fp32=True)
    kw = dict(max_batch=2, max_seq=64, page_size=4, prefill_chunk=8)
    plain = ServingEngine(cfg, params, cache_mode="paged", **kw)
    out_plain = plain.run(_requests(cfg, 23, n=4, lmax=20, new=(4, 9)))
    spec = SpeculativeEngine(cfg, params, cfg, bad, draft_k=3, **kw)
    out_spec = spec.run(_requests(cfg, 23, n=4, lmax=20, new=(4, 9)))
    assert out_plain == out_spec
    assert spec.acceptance_rate < 1.0, (
        "independent random params should disagree somewhere"
    )


def test_speculative_from_upcycle_pair():
    """The paper's pairing: upcycle the dense parent into the MoE, draft on
    dense, verify on MoE. Function-preserving init (Mixtral router) makes
    acceptance ~1; output matches a plain engine serving the same MoE."""
    from repro.config import MoEConfig
    from repro.core.upcycle import upcycle_config, upcycle_params
    from repro.serving.speculative import SpeculativeEngine

    dense = tiny_dense().replace(dtype="float32")
    dp = init_model(dense, fp32=True)
    moe_cfg = _dropless(upcycle_config(
        dense, MoEConfig(num_experts=4, top_k=2, capacity_factor=None)
    ))
    kw = dict(max_batch=2, max_seq=64, page_size=4, prefill_chunk=8)
    spec = SpeculativeEngine.from_upcycle(dense, moe_cfg, dp, draft_k=3, **kw)
    assert spec.provenance is not None
    out_spec = spec.run(_requests(moe_cfg, 29, n=4, lmax=20, new=(4, 9)))
    import jax

    mp = upcycle_params(dense, moe_cfg, dp, jax.random.PRNGKey(0))
    plain = ServingEngine(moe_cfg, mp, cache_mode="paged", **kw)
    out_plain = plain.run(_requests(moe_cfg, 29, n=4, lmax=20, new=(4, 9)))
    assert out_spec == out_plain
    assert spec.acceptance_rate > 0.9, spec.kv_stats()["speculation"]


def test_speculative_with_prefix_cache():
    """The two features compound: prefix hits skip prefill for drafter AND
    verifier (lockstep pools), speculation still decodes the exact greedy
    stream."""
    from repro.serving.speculative import SpeculativeEngine

    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    kw = dict(max_batch=2, max_seq=64, page_size=4, prefill_chunk=8)
    plain = ServingEngine(cfg, params, cache_mode="paged", **kw)
    out_plain = plain.run(_shared_prefix_requests(cfg, 31, n=5))
    spec = SpeculativeEngine(cfg, params, cfg, params, draft_k=3,
                             prefix_cache=True, **kw)
    out_spec = spec.run(_shared_prefix_requests(cfg, 31, n=5))
    assert out_plain == out_spec
    stats = spec.kv_stats()
    assert stats["prefix"]["hits"] > 0
    assert stats["speculation"]["acceptance_rate"] == 1.0


def test_bucketed_prefill_matches_exact():
    """Right-padded bucketed prefill (valid_len path) produces the same
    tokens as an engine whose bucket is the exact prompt length."""
    cfg = tiny_dense().replace(dtype="float32")
    params = init_model(cfg, fp32=True)
    bucketed = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    out_b = bucketed.run(_requests(cfg, 17, n=4, lmax=30))
    exact = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    exact._bucket = lambda L: L  # defeat bucketing
    out_e = exact.run(_requests(cfg, 17, n=4, lmax=30))
    assert out_b == out_e
    assert bucketed.prefill_traces < exact.prefill_traces
