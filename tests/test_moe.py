"""MoE dispatch: capacity semantics, dropping, dropless, conservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, MoEConfig
from repro.core.moe import _dispatch_tables, capacity, moe_apply, moe_decl
from repro.sharding.rules import init_from_decls


def _cfg(E=4, k=2, cf=2.0, **kw):
    moe = MoEConfig(num_experts=E, top_k=k, capacity_factor=cf, **kw)
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=128,
                      vocab_divisor=64, moe=moe)
    return cfg, moe


def test_capacity_formula():
    moe = MoEConfig(num_experts=8, top_k=2, capacity_factor=4.0)
    assert capacity(moe, 64) == 64  # 2*64/8*4
    assert capacity(MoEConfig(num_experts=8, top_k=2, capacity_factor=1.0), 64) == 16
    assert capacity(MoEConfig(num_experts=8, top_k=2, capacity_factor=None), 64) == 64


def test_dispatch_tables_positions():
    idx = jnp.array([[0, 1], [0, 1], [0, 2], [0, 1]], jnp.int32)  # expert 0 x4
    gates = jnp.full((4, 2), 0.5)
    sel, slot_gate = _dispatch_tables(idx, gates, E=4, C=2)
    # expert 0 receives tokens 0,1 (capacity 2); tokens 2,3 overflow -> dropped
    np.testing.assert_array_equal(np.asarray(sel[0]), [0, 1])
    assert float(slot_gate[0].sum()) == 1.0  # two kept assignments at 0.5
    # expert 1: tokens 0,1 kept, token 3 dropped
    np.testing.assert_array_equal(np.asarray(sel[1]), [0, 1])
    # expert 2: token 2 in slot 0
    assert int(sel[2, 0]) == 2 and float(slot_gate[2, 0]) == 0.5
    assert float(slot_gate[2, 1]) == 0.0


def test_dropless_equals_dense_ffn_when_experts_identical():
    """Dropless + identical experts + mixtral gates == plain FFN (paper's
    upcycling identity at the layer level)."""
    cfg, moe = _cfg(cf=None)
    params = init_from_decls(moe_decl(cfg, moe), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    # make all experts identical
    for k in ("w_gate", "w_up", "w_down"):
        params["experts"][k] = jnp.broadcast_to(
            params["experts"][k][0:1], params["experts"][k].shape
        )
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    y, _ = moe_apply(cfg, moe, None, params, x)
    from repro.models.layers import mlp_apply

    dense = {k: params["experts"][k][0] for k in ("w_gate", "w_up", "w_down")}
    y_ref = mlp_apply(dense, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def test_cf1_drops_tokens_under_imbalance():
    cfg, moe = _cfg(E=4, k=1, cf=1.0)
    params = init_from_decls(moe_decl(cfg, moe), jax.random.PRNGKey(0))
    # bias router hard toward expert 0 -> most tokens overflow
    params["router"]["w_g"] = jnp.zeros_like(params["router"]["w_g"]).at[:, 0].set(10.0)
    x = jnp.ones((1, 32, 32), jnp.float32)
    y, _ = moe_apply(cfg, moe, None, params, x)
    # capacity = ceil(1*32/4*1) = 8 -> only 8 of 32 tokens processed
    nonzero = np.asarray(jnp.any(jnp.abs(y[0]) > 1e-9, axis=-1))
    assert nonzero.sum() == 8, nonzero.sum()


def test_dense_residual():
    cfg, moe = _cfg(cf=None, dense_residual=True)
    params = init_from_decls(moe_decl(cfg, moe), jax.random.PRNGKey(0))
    assert "dense_residual" in params
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32)) * 0.1
    y, _ = moe_apply(cfg, moe, None, params, x)
    # zero the experts: output must equal the dense residual alone
    params2 = jax.tree.map(lambda v: v, params)
    params2["experts"] = jax.tree.map(jnp.zeros_like, params["experts"])
    y2, _ = moe_apply(cfg, moe, None, params2, x)
    from repro.models.layers import mlp_apply

    np.testing.assert_allclose(
        np.asarray(y2, dtype=np.float32),
        np.asarray(mlp_apply(params["dense_residual"], x), dtype=np.float32),
        atol=1e-2,
    )


def test_kernel_path_matches_xla_path():
    cfg, moe = _cfg(cf=2.0)
    params = init_from_decls(moe_decl(cfg, moe), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.3
    y0, _ = moe_apply(cfg, moe, None, params, x, use_kernel=False)
    y1, _ = moe_apply(cfg, moe, None, params, x, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-5)
