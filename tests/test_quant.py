"""Low-precision serving: int8 expert weights + int8 KV pages.

Covers the error-budget contract from core/quant.py:

* int8 kernels vs their quantized oracles (exact rewrite — tight parity);
* quantized vs bf16 model logits within the published budgets on the e8t2
  smoke config;
* EXACT greedy-token parity over a short decode, on a sharpened probe
  model (random-init logits are near-uniform, so token parity there is a
  coin flip — see quant.sharpen_for_parity);
* the PagePool scale sidecar can never desync from its page payload
  across alloc / COW / defrag / free (property test);
* int8-aware tile sizing in the Pallas block picker;
* engine/config validation of the quant modes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, smoke_config
from repro.core.quant import (
    INT8_KV_LOGIT_BUDGET,
    INT8_LOGIT_BUDGET,
    KERNEL_PARITY_TOL,
    dequantize_kv,
    dequantize_weight,
    quantize_experts,
    quantize_kv,
    quantize_params,
    quantize_weight,
    sharpen_for_parity,
)
from repro.models.model import forward, model_decl
from repro.serving.engine import Request, ServingEngine
from repro.sharding.rules import init_from_decls


def _e8t2():
    cfg = smoke_config(get_config("llama3-e8t2"))
    # dropless + the single-host dispatcher (alltoall needs an EP plan and
    # would trip REPRO_STRICT_DISPATCH)
    return cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=None, dispatcher="allgather"))


# -- quantizer round trips ----------------------------------------------------


def test_quantize_weight_roundtrip():
    rng = np.random.default_rng(11)
    w = jnp.asarray(rng.standard_normal((4, 64, 96)), jnp.bfloat16) * 0.05
    q, s = quantize_weight(w)
    assert q.dtype == jnp.int8 and s.dtype == jnp.bfloat16
    assert s.shape == (4, 96)
    err = jnp.max(
        jnp.abs(dequantize_weight(q, s) - w.astype(jnp.float32)), axis=-2)
    # per channel: half a quantization step, plus up to 127 steps' worth of
    # the bf16 scale's half-ulp relative rounding (7 mantissa bits -> 2^-8),
    # and one more 2^-8 factor because the bound is stated in the *rounded*
    # scale -- together just under one full step
    bound = s.astype(jnp.float32) * (0.5 + 127 * 2.0**-8) * (1 + 2.0**-8)
    assert bool(jnp.all(err <= bound)), float(jnp.max(err - bound))


def test_quantize_kv_roundtrip():
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.standard_normal((3, 8, 2, 64)), jnp.bfloat16) * 0.3
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (3, 8, 2, 1)
    err = jnp.max(
        jnp.abs(dequantize_kv(q, s) - x.astype(jnp.float32)),
        axis=-1, keepdims=True)
    # f32 scales: half a step per (token, head) vector, tiny rounding slack
    assert bool(jnp.all(err <= s * 0.51)), float(jnp.max(err - s * 0.51))


def test_quantize_experts_idempotent(rng):
    experts = {
        "w_gate": jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.bfloat16),
        "w_up": jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.bfloat16),
        "w_down": jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.bfloat16),
    }
    q = quantize_experts(experts)
    assert q["w_gate"].dtype == jnp.int8 and "w_down_scale" in q
    assert quantize_experts(q) is q  # second pass is a no-op


# -- kernel vs quantized oracle ----------------------------------------------


def _quant_ffn(rng, E=4, D=128, F=256):
    wg = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wu = jnp.asarray(rng.standard_normal((E, D, F)), jnp.bfloat16) * 0.05
    wd = jnp.asarray(rng.standard_normal((E, F, D)), jnp.bfloat16) * 0.05
    (qg, sg), (qu, su), (qd, sd) = map(quantize_weight, (wg, wu, wd))
    return qg, qu, qd, sg, su, sd


def test_expert_gemm_q8_matches_oracle(rng):
    from repro.kernels.ops import expert_gemm_q8
    from repro.kernels.ref import expert_gemm_q8_ref

    E, C, D, F = 4, 64, 128, 256
    xe = jnp.asarray(rng.standard_normal((E, C, D)), jnp.bfloat16) * 0.3
    qargs = _quant_ffn(rng, E, D, F)
    y = expert_gemm_q8(xe, *qargs)
    ref = expert_gemm_q8_ref(xe, *qargs)
    err = float(jnp.max(jnp.abs(
        y.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err <= KERNEL_PARITY_TOL, err


def test_grouped_gemm_q8_matches_oracle(rng):
    from repro.kernels.ops import grouped_gemm_q8
    from repro.kernels.ref import grouped_gemm_q8_ref

    E, D, F, N = 4, 128, 256, 512
    xs = jnp.asarray(rng.standard_normal((N, D)), jnp.bfloat16) * 0.3
    gs = jnp.full((E,), N // E, jnp.int32)
    qargs = _quant_ffn(rng, E, D, F)
    y = grouped_gemm_q8(xs, *qargs, gs, row_block=128)
    ref = grouped_gemm_q8_ref(xs, *qargs, gs)
    err = float(jnp.max(jnp.abs(
        y.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err <= KERNEL_PARITY_TOL, err


def test_paged_attention_q8_matches_oracle(rng):
    from repro.kernels.ops import paged_attention_q8
    from repro.kernels.ref import paged_attention_q8_ref

    P, ps, B, H, KV, d, maxP = 16, 8, 3, 4, 2, 64, 4
    kq, ks = quantize_kv(
        jnp.asarray(rng.standard_normal((P, ps, KV, d)), jnp.bfloat16) * 0.3)
    vq, vs = quantize_kv(
        jnp.asarray(rng.standard_normal((P, ps, KV, d)), jnp.bfloat16) * 0.3)
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.bfloat16) * 0.3
    bt = jnp.asarray(rng.permutation(P)[: B * maxP].reshape(B, maxP), jnp.int32)
    sl = jnp.asarray(rng.integers(1, maxP * ps, B), jnp.int32)
    y = paged_attention_q8(q, kq, vq, ks, vs, bt, sl)
    ref = paged_attention_q8_ref(q, kq, vq, ks, vs, bt, sl)
    err = float(jnp.max(jnp.abs(
        y.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err <= KERNEL_PARITY_TOL, err


# -- model-level logit budgets ------------------------------------------------


def test_quant_weights_logit_budget():
    # own generator: the shared session rng's state depends on which tests
    # ran first, and this budget is a measurement, not an exact property
    rng = np.random.default_rng(7)
    cfg = _e8t2()
    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    base, _ = forward(cfg, None, params, batch)
    quant, _ = forward(cfg, None, quantize_params(params), batch)
    err = float(jnp.max(jnp.abs(
        base.astype(jnp.float32) - quant.astype(jnp.float32))))
    assert err <= INT8_LOGIT_BUDGET, err


def test_quant_kv_logit_budget(sharpened):
    """Prefill through the cache-bearing forward with bf16 vs int8 pages:
    per-position logits must agree within the KV budget (both pool
    variants use the same page-table view; the Pallas decode kernel's read
    path is covered by the oracle test above). Measured on the sharpened
    probe with an in-distribution prompt: a random-init model's router
    sits at near-ties, so the tiny KV perturbation flips top-k expert
    choices and the logit delta measures routing luck, not dequant error
    (observed 0.36-0.45 across seeds vs a stable ~0.14 here)."""
    from repro.models.model import paged_forward
    from repro.serving.kv_cache import init_paged_pool

    cfg, params, pattern = sharpened
    toks = jnp.asarray(pattern[None, :24], jnp.int32)
    out = {}
    for tag, quant in (("bf16", "none"), ("int8", "int8")):
        qcfg = cfg.replace(quant_kv=quant)
        pool = init_paged_pool(qcfg, 7, 8)  # 7 usable + trailing trash page
        lg, _ = paged_forward(
            qcfg, None, params, pool, toks,
            pos_start=jnp.zeros((1,), jnp.int32),
            page_table=jnp.asarray([[0, 1, 2, -1]], jnp.int32),
            valid_len=jnp.asarray([24], jnp.int32),
            return_all_logits=True,
        )
        out[tag] = lg.astype(jnp.float32)
    err = float(jnp.max(jnp.abs(out["bf16"] - out["int8"])))
    assert err <= INT8_KV_LOGIT_BUDGET, err
    assert jnp.array_equal(out["bf16"].argmax(-1), out["int8"].argmax(-1))


# -- greedy-token parity on the sharpened probe -------------------------------


@pytest.fixture(scope="module")
def sharpened():
    cfg = _e8t2()
    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(0))
    params, pattern = sharpen_for_parity(cfg, params)
    return cfg, params, pattern


def _probe_requests(pattern, n=4, prompt_len=24, new=8):
    return [
        Request(rid=i,
                prompt=np.roll(pattern, -i)[:prompt_len].astype(np.int32),
                max_new_tokens=new)
        for i in range(n)
    ]


@pytest.mark.parametrize("quant", [
    dict(quant_weights="int8"),
    dict(quant_kv="int8"),
    dict(quant_weights="int8", quant_kv="int8"),
])
def test_greedy_parity_sharpened(sharpened, quant):
    """EXACT greedy-token parity: on the probe model the top-1 margins
    (~4.7) dwarf the int8 logit error (~0.04), so any token flip is a real
    quantization bug, not noise."""
    cfg, params, pattern = sharpened
    kw = dict(max_batch=4, max_seq=64, cache_mode="paged", page_size=8,
              prefill_chunk=16)
    base = ServingEngine(cfg, params, **kw)
    out_base = base.run(_probe_requests(pattern))
    eng = ServingEngine(cfg, params, **kw, **quant)
    out_q = eng.run(_probe_requests(pattern))
    assert out_base == out_q, {
        rid: (out_base[rid], out_q[rid])
        for rid in out_base if out_base[rid] != out_q[rid]
    }
    eng.page_pool.check_invariants()
    assert eng.page_pool.free_pages == eng.page_pool.num_pages


# -- sidecar/payload no-desync property ---------------------------------------


def _apply_pool_ops(pool, ops):
    from repro.serving.kv_cache import copy_pages, permute_pool

    for kind, a, b in ops:
        if kind == "copy" and a != b:
            pool = copy_pages(pool, [(a, b)])
        elif kind == "permute" and a != b:
            # a legal defrag mapping is a permutation: swap a <-> b
            pool = permute_pool(pool, {a: b, b: a})
    return pool


def _check_sidecar_sync(ops):
    """Fill every payload entry of page p with the constant p and its
    sidecar scale likewise; apply an arbitrary COW/defrag sequence through
    the real pool-tree operators. Because the sidecar is a pool leaf, the
    page-id pattern must stay identical across payload and sidecar — any
    structural divergence (a future op touching only k/v) desyncs the
    constants and fails here."""
    from conftest import tiny_dense
    from repro.serving.kv_cache import init_paged_pool

    cfg = tiny_dense(num_layers=1).replace(quant_kv="int8")
    pool = init_paged_pool(cfg, 8, 4)
    n = jax.tree.leaves(pool)[0].shape[1]
    ids = jnp.arange(n)
    pool = jax.tree.map(
        lambda a: jnp.broadcast_to(
            ids.reshape(1, n, 1, 1, 1), a.shape
        ).astype(a.dtype),
        pool,
    )
    pool = _apply_pool_ops(pool, ops)
    leaves = jax.tree.leaves(pool)
    ref = leaves[0][0, :, 0, 0, 0].astype(jnp.int32)
    for leaf in leaves[1:]:
        got = leaf[0, :, 0, 0, 0].astype(jnp.int32)
        assert jnp.array_equal(ref, got), (ref, got)


def test_pool_sidecar_never_desyncs_seeded():
    """Deterministic fallback for environments without hypothesis: 20
    seeded random COW/defrag sequences through the same checker."""
    rng = np.random.default_rng(42)
    for _ in range(20):
        ops = [
            (("copy", "permute")[int(rng.integers(2))],
             int(rng.integers(8)), int(rng.integers(8)))
            for _ in range(int(rng.integers(1, 12)))
        ]
        _check_sidecar_sync(ops)


def test_pool_sidecar_never_desyncs_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    _op = st.one_of(
        st.tuples(st.just("copy"), st.integers(0, 7), st.integers(0, 7)),
        st.tuples(st.just("permute"), st.integers(0, 7), st.integers(0, 7)),
    )

    @settings(max_examples=30, deadline=None)
    @given(ops=st.lists(_op, max_size=12))
    def run(ops):
        _check_sidecar_sync(ops)

    run()


# -- tile sizing --------------------------------------------------------------


def test_pick_scales_with_itemsize():
    from repro.kernels.expert_gemm import _pick

    # int8 operands get twice the rows of the bf16-calibrated budget...
    assert _pick(256, 1024, itemsize=1) == 512
    assert _pick(256, 1024, itemsize=2) == 256
    # ...f32 half, and lane alignment survives the scaling
    assert _pick(256, 1024, itemsize=4) == 128
    for item in (1, 2, 4):
        assert _pick(256, 1024, itemsize=item) % 128 == 0
    with pytest.raises(AssertionError):
        _pick(256, 1024, itemsize=3)
    # misaligned split still asserts regardless of scaling: 192 has no
    # 128-aligned divisor, and the int8-scaled block (128) != whole dim
    with pytest.raises(AssertionError):
        _pick(64, 192, itemsize=1)


# -- validation ---------------------------------------------------------------


def test_engine_quant_validation():
    cfg = _e8t2()
    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params, max_batch=2, max_seq=32,
                      cache_mode="ring", quant_kv="int8")
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, max_batch=2, max_seq=32,
                      quant_weights="int4")


def test_config_quant_validation():
    cfg = _e8t2()
    with pytest.raises(AssertionError):
        cfg.replace(quant_weights="fp8")
    with pytest.raises(AssertionError):
        cfg.replace(quant_kv="int4")
    assert cfg.replace(quant_kv="int8").quant_kv == "int8"
