"""Optimizer, schedule, and data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.data.pipeline import BlendedDataset, make_train_iter
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule


def test_cosine_schedule_shape():
    t = TrainConfig(lr=3e-5, lr_min=3e-7, warmup_steps=100, total_steps=1000)
    s = lambda i: float(cosine_schedule(i, t.lr, t.lr_min, t.warmup_steps, t.total_steps))
    assert s(0) == pytest.approx(3e-7)  # first step is NOT a no-op
    assert abs(s(100) - 3e-5) < 1e-9
    assert abs(s(1000) - 3e-7) < 1e-9
    assert s(50) == pytest.approx(51 / 100 * 3e-5)
    assert s(300) > s(600) > s(900)


def test_adamw_minimizes_quadratic():
    tcfg = TrainConfig(lr=0.0, weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(tcfg, g, state, jnp.float32(0.05))
    assert float(loss(params)) < 1e-3


def test_adamw_grad_clip():
    tcfg = TrainConfig(grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params)
    g = {"w": jnp.full((4,), 100.0)}
    p2, _ = adamw_update(tcfg, g, state, jnp.float32(1.0))
    # clipped update ~ lr * mhat/sqrt(vhat) bounded ~ lr
    assert float(jnp.max(jnp.abs(p2["w"]))) < 1.5


def test_master_weights_fp32():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = adamw_init(params)
    assert state.master["w"].dtype == jnp.float32
    p2, s2 = adamw_update(TrainConfig(), {"w": jnp.ones((4,), jnp.bfloat16)}, state, jnp.float32(1e-3))
    assert p2["w"].dtype == jnp.bfloat16
    assert s2.master["w"].dtype == jnp.float32


def test_data_deterministic():
    it1 = make_train_iter(128, 16, 4, seed=5)
    it2 = make_train_iter(128, 16, 4, seed=5)
    b1, b2 = next(it1), next(it2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])
    it3 = make_train_iter(128, 16, 4, seed=6)
    assert not np.array_equal(next(it3)["tokens"], b1["tokens"])


def test_labels_are_shifted_tokens():
    b = next(make_train_iter(128, 16, 4, seed=0))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_blend_ratio():
    """7:3 blend (paper §4.1): sources have different Zipf stats."""
    ds = BlendedDataset(1024, 64, blend_ratio=0.7, seed=0)
    rng = np.random.default_rng(0)
    src = rng.random(10000) < 0.7
    assert abs(src.mean() - 0.7) < 0.02
    # sources produce distinguishable distributions
    r1 = ds.web.sample(np.random.default_rng(1), 5000)
    r2 = ds.academic.sample(np.random.default_rng(1), 5000)
    assert not np.array_equal(r1, r2)


def test_learnable_structure():
    """The Markov component makes next-token partially predictable."""
    ds = BlendedDataset(256, 64, seed=0)
    seq = ds.web.sample(np.random.default_rng(2), 20000)
    hits = np.mean(ds.web._succ[seq[:-1]] == seq[1:])
    assert hits > 0.5  # markov_p=0.7 minus collision noise
