"""Attention: blockwise==direct, SWA masking, MLA absorbed decode, kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as A
from repro.config import MLAConfig, ModelConfig
from repro.models.attention import attention_core, gqa_apply, gqa_decl, mla_apply, mla_decl
from repro.sharding.rules import init_from_decls


def _qkv(rng, B=2, Sq=32, Sk=32, H=4, KV=2, d=16, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((B, Sq, H, d)), dtype) * 0.3
    k = jnp.asarray(rng.standard_normal((B, Sk, KV, d)), dtype) * 0.3
    v = jnp.asarray(rng.standard_normal((B, Sk, KV, d)), dtype) * 0.3
    return q, k, v


def test_blockwise_matches_direct(rng, monkeypatch):
    q, k, v = _qkv(rng, Sq=256, Sk=256)
    pos = jnp.broadcast_to(jnp.arange(256), (2, 256))
    direct = attention_core(q, k, v, pos, pos)
    monkeypatch.setattr(A, "_BLOCKWISE_MIN_SEQ", 64)
    monkeypatch.setattr(A, "_KV_BLOCK", 64)
    block = attention_core(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(block), atol=1e-5)


def test_blockwise_sliding_window(rng, monkeypatch):
    q, k, v = _qkv(rng, Sq=256, Sk=256)
    pos = jnp.broadcast_to(jnp.arange(256), (2, 256))
    direct = attention_core(q, k, v, pos, pos, window=32)
    monkeypatch.setattr(A, "_BLOCKWISE_MIN_SEQ", 64)
    monkeypatch.setattr(A, "_KV_BLOCK", 64)
    block = attention_core(q, k, v, pos, pos, window=32)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(block), atol=1e-5)


def test_sliding_window_ignores_far_context(rng):
    """Perturbing keys outside the window must not change the output."""
    q, k, v = _qkv(rng, Sq=64, Sk=64)
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    y1 = attention_core(q, k, v, pos, pos, window=8)
    k2 = k.at[:, :32].add(5.0)  # far past for the last query
    v2 = v.at[:, :32].add(5.0)
    y2 = attention_core(q, k2, v2, pos, pos, window=8)
    np.testing.assert_allclose(
        np.asarray(y1[:, -1]), np.asarray(y2[:, -1]), atol=1e-5
    )
    assert not np.allclose(np.asarray(y1[:, 32]), np.asarray(y2[:, 32]), atol=1e-3)


def test_causality(rng):
    q, k, v = _qkv(rng, Sq=32, Sk=32)
    pos = jnp.broadcast_to(jnp.arange(32), (2, 32))
    y1 = attention_core(q, k, v, pos, pos)
    k2 = k.at[:, 20:].add(3.0)
    v2 = v.at[:, 20:].add(3.0)
    y2 = attention_core(q, k2, v2, pos, pos)
    np.testing.assert_allclose(np.asarray(y1[:, :20]), np.asarray(y2[:, :20]), atol=1e-5)


def test_invalid_slots_masked(rng):
    """k_pos = -1 slots (unwritten ring-buffer entries) are ignored."""
    q, k, v = _qkv(rng, Sq=1, Sk=16)
    qp = jnp.full((2, 1), 7)
    kp = jnp.where(jnp.arange(16) < 8, jnp.arange(16), -1)[None].repeat(2, 0)
    y1 = attention_core(q, k, v, qp, kp)
    k2 = k.at[:, 8:].set(99.0)
    v2 = v.at[:, 8:].set(99.0)
    y2 = attention_core(q, k2, v2, qp, kp)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def _mla_cfg():
    return ModelConfig(
        name="t", family="dense", num_layers=1, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=128, vocab_divisor=64,
        use_mla=True, dtype="float32",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
    )


def test_mla_absorbed_decode_matches_train_path(rng):
    """The latent-space (absorbed) decode is algebraically identical to the
    expanded train path."""
    cfg = _mla_cfg()
    params = init_from_decls(mla_decl(cfg), jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    B, S = 2, 12
    x = jnp.asarray(rng.standard_normal((B, S, 64)), jnp.float32) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    y_train, _ = mla_apply(cfg, None, params, x, pos)
    # decode step-by-step
    m = cfg.mla
    cache = {
        "ckv": jnp.zeros((B, S, m.kv_lora_rank)),
        "krope": jnp.zeros((B, S, m.qk_rope_head_dim)),
    }
    outs = []
    for t in range(S):
        cv = {
            "slot": jnp.full((B,), t, jnp.int32),
            "slot_pos": jnp.where(jnp.arange(S) <= t, jnp.arange(S), -1)[None].repeat(B, 0),
        }
        yt, cache = mla_apply(cfg, None, params, x[:, t : t + 1],
                              jnp.full((B, 1), t), cache, cv)
        outs.append(yt)
    y_dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec), atol=2e-4)


def test_gqa_bias(rng):
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=128,
                      vocab_divisor=64, qkv_bias=True)
    params = init_from_decls(gqa_decl(cfg), jax.random.PRNGKey(0))
    assert {"bq", "bk", "bv"} <= set(params)
    x = jnp.asarray(rng.standard_normal((1, 8, 32)), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    y, _ = gqa_apply(cfg, None, params, x, pos)
    assert y.shape == (1, 8, 32) and bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
