"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import MoEConfig
from repro.core.moe import _dispatch_tables, capacity
from repro.models.attention import _mask
from repro.models.layers import rope_apply
from repro.roofline.analysis import _shape_bytes


@settings(max_examples=50, deadline=None)
@given(
    T=st.integers(1, 64),
    E=st.integers(1, 16),
    k=st.integers(1, 4),
    cf=st.one_of(st.none(), st.floats(0.1, 8.0)),
)
def test_capacity_invariants(T, E, k, cf):
    k = min(k, E)
    moe = MoEConfig(num_experts=E, top_k=k, capacity_factor=cf)
    C = capacity(moe, T)
    assert 1 <= C <= T  # an expert never needs more than T slots
    if cf is None:
        assert C == T  # dropless worst case
    else:
        assert C >= min(int(np.floor(k * T / E * cf)), T) or C == 1


@settings(max_examples=30, deadline=None)
@given(
    T=st.integers(1, 32),
    E=st.integers(2, 8),
    k=st.integers(1, 3),
    seed=st.integers(0, 100),
)
def test_dispatch_conservation(T, E, k, seed):
    """Every slot_gate entry comes from exactly one kept assignment; total
    combine weight == sum of kept gates; per-expert load <= capacity."""
    k = min(k, E)
    rng = np.random.default_rng(seed)
    idx_np = np.stack([rng.choice(E, size=k, replace=False) for _ in range(T)])
    gates_np = rng.uniform(0.1, 1.0, (T, k)).astype(np.float32)
    moe = MoEConfig(num_experts=E, top_k=k, capacity_factor=1.5)
    C = capacity(moe, T)
    sel, slot_gate = _dispatch_tables(
        jnp.asarray(idx_np, jnp.int32), jnp.asarray(gates_np), E, C
    )
    sel, slot_gate = np.asarray(sel), np.asarray(slot_gate)
    # per-expert kept count never exceeds capacity
    kept = (slot_gate > 0).sum(axis=1)
    assert (kept <= C).all()
    # total routed weight <= total gate weight; equality iff nothing dropped
    assert slot_gate.sum() <= gates_np.sum() + 1e-4
    # each kept slot's gate matches the original assignment's gate
    for e in range(E):
        for c in range(C):
            if slot_gate[e, c] > 0:
                t = sel[e, c]
                assert any(
                    idx_np[t, j] == e and abs(gates_np[t, j] - slot_gate[e, c]) < 1e-6
                    for j in range(k)
                )


@settings(max_examples=25, deadline=None)
@given(
    S=st.integers(2, 40),
    window=st.one_of(st.none(), st.integers(1, 16)),
)
def test_mask_properties(S, window):
    pos = jnp.arange(S)[None]
    m = np.asarray(_mask(pos, pos, window))
    assert m[0].diagonal().all()  # self always visible
    assert not np.triu(m[0], 1).any()  # causal
    if window is not None:
        i, j = np.tril_indices(S)
        visible = m[0][i, j]
        assert ((i - j < window) == visible).all()


@settings(max_examples=25, deadline=None)
@given(
    S=st.integers(1, 16),
    H=st.integers(1, 4),
    d_half=st.sampled_from([2, 4, 8, 16]),
    shift=st.integers(0, 100),
)
def test_rope_norm_and_relativity(S, H, d_half, shift):
    """RoPE preserves norms, and q.k depends only on relative positions."""
    d = 2 * d_half
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, S, H, d)), jnp.float32)
    pos = jnp.arange(S)[None]
    y = rope_apply(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        atol=1e-4,
    )
    q = jnp.asarray(rng.standard_normal((1, S, H, d)), jnp.float32)
    dot1 = np.einsum("bshd,bthd->bhst", np.asarray(rope_apply(q, pos, 1e4)), np.asarray(y))
    y2 = rope_apply(x, pos + shift, 10000.0)
    q2 = rope_apply(q, pos + shift, 10000.0)
    dot2 = np.einsum("bshd,bthd->bhst", np.asarray(q2), np.asarray(y2))
    np.testing.assert_allclose(dot1, dot2, atol=2e-3)


@settings(max_examples=50, deadline=None)
@given(
    dims=st.lists(st.integers(1, 64), min_size=0, max_size=4),
    dt=st.sampled_from(["f32", "bf16", "s32", "u8"]),
)
def test_hlo_shape_bytes(dims, dt):
    sizes = {"f32": 4, "bf16": 2, "s32": 4, "u8": 1}
    type_str = f"{dt}[{','.join(map(str, dims))}]"
    expect = sizes[dt] * int(np.prod(dims)) if dims else sizes[dt]
    assert _shape_bytes(type_str) == expect
