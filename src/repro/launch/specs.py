"""Abstract input specs (ShapeDtypeStruct + sharding) for every
(architecture x input shape) pair — the dry-run's stand-ins. No device
memory is allocated (the shannon/kernels pattern).

Shape semantics per kind:
* train    — train_step(params, opt_state, batch, rng)
* prefill  — prefill_forward(params, batch) -> (logits, cache)
* decode   — decode_step(params, cache, tokens) -> (logits, cache); the
             cache stands at seq_len tokens (ring-window for SWA configs).

Multimodal stubs: vlm batches put ``num_prefix_embeds`` positions of the
sequence budget into precomputed patch embeddings; encdec splits the budget
between encoder frames and decoder tokens. Decode for encdec uses a 4096-
frame encoder memory (documented in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import InputShape, ModelConfig
from repro.models.model import cache_decl, model_decl
from repro.sharding.rules import (
    FoldingPlan,
    ParamDecl,
    abstract_from_decls,
    shardings_from_decls,
)

ENCDEC_DECODE_MEMORY = 4096


def _sds(shape, dtype, plan: Optional[FoldingPlan], *axes):
    if plan is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=plan.sharding(shape, *axes))


def batch_specs(
    cfg: ModelConfig, shape: InputShape, plan: Optional[FoldingPlan]
) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": _sds((B,), jnp.int32, plan, "batch")}
    if cfg.family == "vlm":
        P = cfg.num_prefix_embeds
        St = S - P
        out = {
            "tokens": _sds((B, St), jnp.int32, plan, "batch", None),
            "embeds": _sds((B, P, cfg.d_model), jnp.float32, plan, "batch", None, None),
        }
        if shape.kind == "train":
            out["labels"] = _sds((B, St), jnp.int32, plan, "batch", None)
        return out
    if cfg.family == "encdec":
        Se = Sd = S // 2
        out = {
            "tokens": _sds((B, Sd), jnp.int32, plan, "batch", None),
            "frames": _sds((B, Se, cfg.d_model), jnp.float32, plan, "batch", None, None),
        }
        if shape.kind == "train":
            out["labels"] = _sds((B, Sd), jnp.int32, plan, "batch", None)
        return out
    out = {"tokens": _sds((B, S), jnp.int32, plan, "batch", None)}
    if shape.kind == "train":
        out["labels"] = _sds((B, S), jnp.int32, plan, "batch", None)
    return out


def param_specs(cfg: ModelConfig, plan: Optional[FoldingPlan]):
    decls = model_decl(cfg)
    abstract = abstract_from_decls(decls)
    if plan is None:
        return abstract
    sh = shardings_from_decls(decls, plan)
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s), abstract, sh
    )


def cache_specs(cfg: ModelConfig, shape: InputShape, plan: Optional[FoldingPlan]):
    assert shape.kind == "decode"
    enc_len = ENCDEC_DECODE_MEMORY if cfg.family == "encdec" else 0
    decls = cache_decl(cfg, shape.global_batch, shape.seq_len, enc_len)

    def to_sds(d: ParamDecl):
        if plan is None:
            return jax.ShapeDtypeStruct(d.shape, d.dtype)
        return jax.ShapeDtypeStruct(d.shape, d.dtype, sharding=plan.sharding(d.shape, *d.axes))

    return jax.tree.map(to_sds, decls, is_leaf=lambda d: isinstance(d, ParamDecl))


def rng_spec(plan: Optional[FoldingPlan]):
    return _sds((2,), jnp.uint32, plan, None)
