import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes (16x16 single-pod; 2x16x16 multi-pod) and record
memory/cost/collective analysis for the roofline report.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first initialization.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.config import ARCH_IDS, SHAPES, InputShape, ModelConfig, TrainConfig, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import batch_specs, cache_specs, param_specs, rng_spec  # noqa: E402
from repro.models.model import decode_step, model_decl, prefill_forward  # noqa: E402
from repro.optim.adamw import AdamWState, opt_state_shardings  # noqa: E402
from repro.roofline.analysis import roofline_from_hlo  # noqa: E402
from repro.sharding.rules import FoldingPlan  # noqa: E402
from repro.train.trainer import make_train_step  # noqa: E402

SWA_FOR_LONG = 8192  # sliding window used by dense archs on long_500k

# Dry-run combos skipped per DESIGN.md's sub-quadratic rule.
SKIPS = {
    ("seamless-m4t-medium", "long_500k"): "enc-dec full attention; 500k decoder stream over a short encoder memory is out of scope (DESIGN.md)",
}


def adapt_for_shape(cfg: ModelConfig, shape: InputShape) -> Optional[ModelConfig]:
    """Apply the long_500k policy; None = documented skip."""
    if (cfg.name, shape.name) in SKIPS:
        return None
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            return cfg  # O(1)/O(S) native
        if cfg.use_mla:
            return cfg  # compressed latent cache, seq-sharded
        if cfg.family == "encdec":
            return None
        # dense/moe/vlm: sub-quadratic via the sliding-window variant
        return cfg.replace(sliding_window=SWA_FOR_LONG)
    return cfg


def _opt_specs(cfg: ModelConfig, plan: FoldingPlan, params_abs):
    sh = opt_state_shardings(model_decl(cfg), plan, zero1=True)
    f32 = lambda a, s: jax.ShapeDtypeStruct(a.shape, jnp.float32, sharding=s)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32, sharding=sh.step),
        master=jax.tree.map(f32, params_abs, sh.master),
        m=jax.tree.map(f32, params_abs, sh.m),
        v=jax.tree.map(f32, params_abs, sh.v),
    )


def lower_combo(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    dispatcher: Optional[str] = None,
    cfg_override: Optional[ModelConfig] = None,
    verbose: bool = True,
    save_hlo_dir: Optional[str] = None,
):
    """Lower+compile one combo. Returns a result record (dict)."""
    shape = SHAPES[shape_name]
    cfg = cfg_override or get_config(arch)
    cfg = adapt_for_shape(cfg, shape)
    if cfg is None:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod, "status": "skipped",
                "reason": SKIPS.get((arch, shape_name), "long-context policy")}
    if dispatcher:
        from repro.config import with_dispatcher

        cfg = with_dispatcher(cfg, dispatcher)

    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = FoldingPlan.make(cfg, mesh)
    chips = mesh.devices.size
    t0 = time.time()

    params_abs = param_specs(cfg, plan)
    if shape.kind == "train":
        tcfg = TrainConfig(global_batch=shape.global_batch, seq_len=shape.seq_len)
        step = make_train_step(cfg, tcfg, plan)
        args = (params_abs, _opt_specs(cfg, plan, params_abs),
                batch_specs(cfg, shape, plan), rng_spec(plan))
        fn = jax.jit(step, donate_argnums=(0, 1))
    elif shape.kind == "prefill":
        fn = jax.jit(lambda p, b: prefill_forward(cfg, plan, p, b))
        args = (params_abs, batch_specs(cfg, shape, plan))
    else:  # decode
        fn = jax.jit(
            lambda p, c, t: decode_step(cfg, plan, p, c, t), donate_argnums=(1,)
        )
        args = (params_abs, cache_specs(cfg, shape, plan), batch_specs(cfg, shape, plan)["tokens"])

    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    if save_hlo_dir:
        import gzip

        os.makedirs(save_hlo_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'2pod' if multi_pod else '1pod'}"
        with gzip.open(os.path.join(save_hlo_dir, tag + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    terms, coll = roofline_from_hlo(hlo, chips)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "status": "ok",
        "chips": chips,
        "attn_mode": plan.attn_mode,
        "moe_mode": plan.moe_mode if cfg.moe else None,
        "dispatcher": cfg.moe.dispatcher if cfg.moe else None,
        "fsdp": plan.fsdp,
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0),
        },
        "cost": {k: cost[k] for k in ("flops", "bytes accessed") if k in cost},
        "collectives": coll,
        "roofline": terms.as_dict(),
    }
    if verbose:
        gb = 1 << 30
        print(
            f"[{arch} x {shape_name} x {'2pod' if multi_pod else '1pod'}] OK "
            f"compile={rec['compile_s']}s args={rec['memory']['argument_bytes']/gb:.2f}GB "
            f"temp={rec['memory']['temp_bytes']/gb:.2f}GB flops={terms.flops:.3e} "
            f"coll={coll['total']/gb:.3f}GB dominant={terms.dominant}",
            flush=True,
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--dispatcher", default=None,
                    choices=[None, "allgather", "alltoall", "sorted"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", default=None, help="dir for gzipped HLO text")
    args = ap.parse_args()

    archs = [a for a in ARCH_IDS if a not in ("llama3-8b", "llama3-e8t2")] if args.all else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2pod' if mp else '1pod'}"
                try:
                    rec = lower_combo(arch, shape, mp, args.dispatcher,
                                      save_hlo_dir=args.save_hlo)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    rec = {
                        "arch": arch, "shape": shape, "multi_pod": mp,
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                    }
                    print(f"[{tag}] FAIL {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
