"""Device meshes.

``make_production_mesh`` — the deliverable mesh: 16x16 ('data','model') per
pod, 2x16x16 ('pod','data','model') for the two-pod run. A function, not a
module constant, so importing this module never touches jax device state.

``make_study_mesh`` — paper-study 3-D meshes ('data','expert','model') used
by the Table-2 folding benchmarks, where the attention layers fold the
'expert' axis into their data-parallel group while the MoE layers use it as
EP (the paper's TP2CP2 <-> TP1EP8 example).

``make_serving_mesh`` — EP x DP ('data', 'expert') mesh for the sharded
serving engine (``ServingEngine(mesh=...)``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    assert len(devices) >= n, (
        f"need {n} devices (run under XLA_FLAGS=--xla_force_host_platform_device_count=512); "
        f"have {len(devices)}"
    )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_study_mesh(data: int, expert: int, model: int) -> Mesh:
    n = data * expert * model
    devices = jax.devices()
    assert len(devices) >= n, (n, len(devices))
    return jax.make_mesh((data, expert, model), ("data", "expert", "model"), devices=devices[:n])


def make_host_mesh() -> Mesh:
    """1x1 mesh on the real local device — used by tests/examples so the
    sharding code paths run identically at laptop scale."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])


def make_serving_mesh(dp: int = 1, ep: int = 1) -> Mesh:
    """EP x DP serving mesh: ('data', 'expert') with ``dp * ep`` devices.
    The 'data' axis shards the decode batch rows and the KV page pool (one
    sub-pool stride per DP shard); 'expert' plays expert-parallel for the
    MoE FFN weights and the decode all-to-all. Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for CPU tests."""
    n = dp * ep
    devices = jax.devices()
    assert len(devices) >= n, (
        f"serving mesh dp={dp} x ep={ep} needs {n} devices; have "
        f"{len(devices)} (set --xla_force_host_platform_device_count)"
    )
    return jax.make_mesh((dp, ep), ("data", "expert"), devices=devices[:n])
