"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \\
      --steps 100 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \\
      --upcycle 4 --top-k 2 --cf 4 --from-ckpt /tmp/dense_ckpt --steps 200
  # preempt it, then pick up exactly where it stopped:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \\
      --upcycle 4 --top-k 2 --cf 4 --from-ckpt /tmp/dense_ckpt --steps 200 \\
      --resume

``--smoke`` selects the reduced config (CPU-runnable); without it the full
assigned config is used (cluster scale). ``--upcycle N`` converts the dense
config to an N-expert MoE, optionally initializing from ``--from-ckpt`` via
online upcycling.

Resume semantics: ``--ckpt-every`` writes FULL TrainState checkpoints
(params + AdamW state + RNG + data-stream snapshot) into step-numbered
subdirectories of the checkpoint dir via the async manager; ``--resume``
restores the latest one and continues to ``--steps`` total steps. A run
that started via upcycling restarts from its latest MoE state — the dense
source is only touched when no full-state checkpoint exists yet (the
provenance block in the manifest records the recipe).
"""
from __future__ import annotations

import argparse
import os

import jax

from repro.config import MoEConfig, TrainConfig, get_config, smoke_config
from repro.data.pipeline import make_train_iter
from repro.train.trainer import Trainer


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--upcycle", type=int, default=0, help="num experts")
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--cf", type=float, default=4.0, help="<=0 => dropless")
    ap.add_argument("--router", default="mixtral", choices=["mixtral", "st"])
    ap.add_argument(
        "--dispatcher", default=None,
        choices=["allgather", "alltoall", "sorted"],
        help="MoE token dispatcher; default keeps the config's choice "
             "(sorted = dropless, recommended with --cf <= 0)",
    )
    ap.add_argument("--from-ckpt", default=None)
    ap.add_argument("--save-ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="full-state checkpoint period (0 = off)")
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="keep-last-k retention for full-state checkpoints")
    ap.add_argument("--blocking-ckpt", action="store_true",
                    help="disable the async double-buffered save path")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest full-state checkpoint from the "
                         "checkpoint dir and continue to --steps total")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--fused-dispatch", action="store_true",
                    help="fold the sorted dispatcher's token gather and "
                         "gate-weighted combine into the grouped-GEMM "
                         "kernel (no (N_pad, D) dispatch buffer in HBM); "
                         "requires --dispatcher sorted and --use-kernel")
    ap.add_argument("--autotune", action="store_true",
                    help="enable the roofline-driven Pallas tile autotuner "
                         "(sets REPRO_AUTOTUNE=1; winners persist in "
                         "~/.cache/repro_autotune.json)")
    ap.add_argument("--supervise", action="store_true",
                    help="arm the anomaly supervisor: skip NaN/spike steps, "
                         "roll back to the last good checkpoint after "
                         "--rollback-after consecutive bad steps")
    ap.add_argument("--rollback-after", type=int, default=3,
                    help="consecutive anomalous steps before rollback")
    ap.add_argument("--step-timeout", type=float, default=0.0,
                    help="per-step wall-clock watchdog in seconds "
                         "(0 = disabled); a hung step raises HangError")
    ap.add_argument("--quant-weights", default="none",
                    choices=["none", "int8"],
                    help="after training, re-run the held-out eval with "
                         "int8-quantized expert-FFN weights (the serving "
                         "path's quantization) and report the CE delta; "
                         "training itself stays bf16")
    return ap


def main(argv=None):
    args = build_argparser().parse_args(argv)
    if args.autotune:
        os.environ["REPRO_AUTOTUNE"] = "1"  # before any kernel wrapper runs
    if args.fused_dispatch:
        if not args.use_kernel:
            raise SystemExit("--fused-dispatch requires --use-kernel "
                             "(the fusion lives in the Pallas grouped GEMM)")
        if args.dispatcher not in (None, "sorted"):
            raise SystemExit("--fused-dispatch requires --dispatcher sorted")
        args.dispatcher = "sorted"
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    ckpt_dir = args.save_ckpt or "/tmp/repro_ckpt"

    provenance = {}
    dense_cfg = None
    if args.upcycle:
        from repro.core.upcycle import upcycle_config

        cf = args.cf if args.cf > 0 else None
        # dropless default: the sorted dispatcher computes every assignment
        # without the padded layout's C = T blow-up
        dispatcher = args.dispatcher or ("sorted" if cf is None else "allgather")
        moe = MoEConfig(
            num_experts=args.upcycle, top_k=args.top_k, capacity_factor=cf,
            router_type=args.router, dispatcher=dispatcher,
            fused_dispatch=args.fused_dispatch,
        )
        dense_cfg = cfg
        cfg = upcycle_config(dense_cfg, moe)

    tcfg = TrainConfig(
        global_batch=args.batch, seq_len=args.seq, lr=args.lr, lr_min=args.lr / 100,
        warmup_steps=min(100, args.steps // 10 + 1), total_steps=args.steps,
        seed=args.seed, ckpt_every=args.ckpt_every, ckpt_dir=ckpt_dir,
    )

    # -- resume first: a run that started via upcycling restarts from its
    # latest MoE TrainState, NOT by re-upcycling the dense source ----------
    state = None
    data_state = None
    if args.resume:
        from repro.checkpoint.manager import latest_step
        from repro.train.state import restore_train_state

        latest = latest_step(ckpt_dir)
        if latest is not None:
            state, manifest = restore_train_state(ckpt_dir, cfg, plan=None,
                                                  zero1=tcfg.zero1)
            data_state = manifest["meta"].get("data_state")
            provenance = manifest["meta"].get("provenance", {})
            print(f"resumed step {latest} from {ckpt_dir}"
                  + (" (upcycled run)" if provenance.get("upcycled") else ""))
        else:
            print(f"--resume: no full-state checkpoint under {ckpt_dir}; "
                  "starting fresh")

    params = None
    if state is None and args.upcycle and args.from_ckpt:
        from repro.checkpoint.ckpt import load_checkpoint
        from repro.core.upcycle import upcycle_params, upcycle_provenance

        dense_params = load_checkpoint(args.from_ckpt)
        params = upcycle_params(dense_cfg, cfg, dense_params,
                                jax.random.PRNGKey(args.seed))
        provenance = upcycle_provenance(dense_cfg, cfg, args.from_ckpt)
        print(f"upcycled {dense_cfg.name} -> {cfg.name} from {args.from_ckpt}")

    extra = None
    if cfg.family == "vlm":
        extra = {"embeds": (args.batch, cfg.num_prefix_embeds, cfg.d_model)}
    if cfg.family == "encdec":
        extra = {"frames": (args.batch, args.seq, cfg.d_model)}
    it = make_train_iter(cfg.vocab_size, args.seq, args.batch,
                         tcfg.blend_ratio, args.seed, extra)
    if data_state is not None:
        it.restore(data_state)
    t, a = cfg.param_counts()
    print(f"training {cfg.name}: {t/1e6:.1f}M total / {a/1e6:.1f}M active params")
    # archs that are already MoE take the --dispatcher override here
    tr = Trainer(cfg, tcfg, params=params, state=state, data_iter=it,
                 use_kernel=args.use_kernel, dispatcher=args.dispatcher,
                 step_timeout_s=args.step_timeout or None)

    from repro.train.callbacks import (
        AnomalySupervisor,
        CheckpointCallback,
        LoggingCallback,
    )

    callbacks = [LoggingCallback(log_every=tcfg.log_every)]
    ckpt_cb = None
    if args.ckpt_every:
        ckpt_cb = CheckpointCallback(
            ckpt_dir, every=args.ckpt_every, keep_last=args.ckpt_keep,
            async_save=not args.blocking_ckpt,
            extra_meta={"arch": args.arch, "seed": args.seed,
                        **({"provenance": provenance} if provenance else {})},
        )
        callbacks.append(ckpt_cb)
    if args.supervise:
        # AFTER the checkpoint callback: a rollback joins the in-flight
        # write before restoring
        callbacks.append(AnomalySupervisor(
            ckpt=ckpt_cb, rollback_after=args.rollback_after,
        ))

    done = int(jax.device_get(tr.state.step))
    remaining = max(0, args.steps - done)
    if remaining:
        tr.run(remaining, callbacks=callbacks)
    else:
        print(f"checkpoint already at step {done} >= --steps {args.steps}; "
              "nothing to run")
    if args.save_ckpt:
        from repro.checkpoint.ckpt import save_checkpoint

        save_checkpoint(args.save_ckpt, tr.params, step=args.steps)
        print(f"saved checkpoint to {args.save_ckpt}")
    ce = tr.eval_loss(batches=4)
    print(f"final held-out CE: {ce:.4f}")
    if args.quant_weights == "int8":
        if cfg.moe is None:
            print("--quant-weights int8: dense config has no expert FFNs; "
                  "nothing to quantize")
        else:
            from repro.core.quant import quantize_params

            # serving-style inference check: quantize a copy of the expert
            # weights, eval, restore — the TrainState keeps its bf16 params
            dense_params = tr.params
            tr.params = quantize_params(dense_params)
            qce = tr.eval_loss(batches=4)
            tr.params = dense_params
            print(f"int8-expert held-out CE: {qce:.4f} "
                  f"(delta {qce - ce:+.4f} vs bf16)")
    return tr


if __name__ == "__main__":
    main()
