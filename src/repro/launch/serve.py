"""Serving launcher: batched greedy decoding with the ServingEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b --smoke \\
      --requests 8 --max-new 24 --cache-mode paged --page-size 16 \\
      --prefill-chunk 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import get_config, smoke_config
from repro.models.model import model_decl
from repro.serving.engine import Request, ServingEngine
from repro.sharding.rules import init_from_decls


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--dispatcher", default=None,
        choices=["allgather", "alltoall", "a2a_overlap", "sorted"],
        help="MoE token dispatcher for decode (default: config's choice; "
        "mesh mode defaults to the overlapped EP exchange)",
    )
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel shards (per-shard KV sub-pools)")
    ap.add_argument("--ep", type=int, default=1,
                    help="expert-parallel shards for MoE decode")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument(
        "--cache-mode", default="ring", choices=["ring", "paged"],
        help="KV cache backend: dense ring buffer or block-table page pool",
    )
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool size (default: ring-capacity parity)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens prefetched per chunked-prefill step")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if cfg.family == "vlm":
        # serving demo drives the text path; image prefix handled at prefill
        cfg = cfg.replace(num_prefix_embeds=0, family="dense")
    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(args.seed))
    mesh = None
    if args.dp > 1 or args.ep > 1:
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.dp, args.ep)
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           max_seq=args.prompt_len + args.max_new + 8,
                           dispatcher=args.dispatcher, use_kernel=args.use_kernel,
                           cache_mode=args.cache_mode, page_size=args.page_size,
                           num_pages=args.num_pages,
                           prefill_chunk=args.prefill_chunk, mesh=mesh)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    outputs = engine.run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(v) for v in outputs.values())
    print(f"served {len(reqs)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s, batch={args.max_batch}, "
          f"cache={args.cache_mode})")
    kv = engine.kv_stats()
    print(f"  kv peak {kv['kv_bytes_peak']/1e6:.2f} MB"
          + (f", page util {kv['page_utilization']:.2f}, "
             f"peak pages {kv['peak_used_pages']}/{kv['num_pages']}"
             if args.cache_mode == "paged" else ""))
    for rid, out in sorted(outputs.items())[:4]:
        print(f"  req {rid}: {out[:12]}{'...' if len(out) > 12 else ''}")
    return outputs


if __name__ == "__main__":
    main()
