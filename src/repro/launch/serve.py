"""Serving launcher: batched greedy decoding with the ServingEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b --smoke \\
      --requests 8 --max-new 24 --cache-mode paged --page-size 16 \\
      --prefill-chunk 32
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.config import get_config, smoke_config
from repro.models.model import model_decl
from repro.serving.engine import Request, ServingEngine
from repro.sharding.rules import init_from_decls


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--dispatcher", default=None,
        choices=["allgather", "alltoall", "a2a_overlap", "sorted"],
        help="MoE token dispatcher for decode (default: config's choice; "
        "mesh mode defaults to the overlapped EP exchange)",
    )
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel shards (per-shard KV sub-pools)")
    ap.add_argument("--ep", type=int, default=1,
                    help="expert-parallel shards for MoE decode")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument(
        "--cache-mode", default="ring", choices=["ring", "paged"],
        help="KV cache backend: dense ring buffer or block-table page pool",
    )
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool size (default: ring-capacity parity)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens prefetched per chunked-prefill step")
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="evict requests older than this many engine steps "
                         "(0 = no deadlines; paged mode only)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="shed submits past this queue depth with a typed "
                         "ShedError (0 = unbounded)")
    ap.add_argument("--shed-watermark", type=int, default=0,
                    help="shed submits when free KV pages minus backlog dip "
                         "below this reserve (0 = off; paged mode only)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over the page pool: requests "
                         "sharing a prompt stem reuse its KV pages (paged "
                         "mode only; the demo prompts share a stem so the "
                         "cache actually hits)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="speculative decoding: draft K tokens per step on "
                         "a dense drafter, verify in one step (paged mode "
                         "only). For an MoE --arch the drafter is its dense "
                         "parent and the served params are upcycled from it "
                         "(the paper's function-preserving pair); otherwise "
                         "the drafter self-speculates with the same params "
                         "unless --draft-arch says otherwise")
    ap.add_argument("--draft-arch", default=None,
                    help="drafter architecture for --speculate (must share "
                         "the tokenizer/vocab; independently initialized, so "
                         "expect low acceptance — a correctness demo)")
    ap.add_argument("--quant-weights", default="none",
                    choices=["none", "int8"],
                    help="serve int8 expert-FFN weights (per-channel scales, "
                         "dequant fused into the Pallas epilogue)")
    ap.add_argument("--quant-kv", default="none", choices=["none", "int8"],
                    help="int8 KV pages with a per-token scale sidecar "
                         "(requires --cache-mode paged)")
    ap.add_argument("--fused-dispatch", action="store_true",
                    help="dispatch-in-kernel MoE decode: the sorted "
                         "dispatcher's gather/combine run inside the "
                         "grouped-GEMM kernel (requires --use-kernel; "
                         "implies --dispatcher sorted)")
    ap.add_argument("--autotune", action="store_true",
                    help="enable the roofline-driven Pallas tile autotuner "
                         "(sets REPRO_AUTOTUNE=1; winners persist in "
                         "~/.cache/repro_autotune.json)")
    args = ap.parse_args(argv)
    if args.autotune:
        os.environ["REPRO_AUTOTUNE"] = "1"  # before any kernel wrapper runs
    if (args.speculate or args.prefix_cache) and args.cache_mode != "paged":
        ap.error("--speculate/--prefix-cache require --cache-mode paged")
    if args.quant_kv != "none" and args.cache_mode != "paged":
        ap.error("--quant-kv requires --cache-mode paged (the scale sidecar "
                 "lives in the page pool)")
    if args.fused_dispatch:
        if not args.use_kernel:
            ap.error("--fused-dispatch requires --use-kernel (the fusion "
                     "lives in the Pallas grouped GEMM)")
        if args.dispatcher not in (None, "sorted"):
            ap.error("--fused-dispatch requires --dispatcher sorted")
        args.dispatcher = "sorted"

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if cfg.family == "vlm":
        # serving demo drives the text path; image prefix handled at prefill
        cfg = cfg.replace(num_prefix_embeds=0, family="dense")
    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(args.seed))
    mesh = None
    if args.dp > 1 or args.ep > 1:
        from repro.launch.mesh import make_serving_mesh

        mesh = make_serving_mesh(args.dp, args.ep)
    common = dict(max_batch=args.max_batch,
                  max_seq=args.prompt_len + args.max_new + 8,
                  dispatcher=args.dispatcher, use_kernel=args.use_kernel,
                  cache_mode=args.cache_mode, page_size=args.page_size,
                  num_pages=args.num_pages,
                  prefill_chunk=args.prefill_chunk, mesh=mesh,
                  deadline_steps=args.deadline_steps or None,
                  max_queue=args.max_queue or None,
                  shed_watermark=args.shed_watermark or None,
                  prefix_cache=args.prefix_cache,
                  quant_weights=args.quant_weights, quant_kv=args.quant_kv,
                  fused_dispatch=args.fused_dispatch)
    if args.speculate:
        from repro.serving.speculative import SpeculativeEngine

        if args.draft_arch is not None:
            dcfg = get_config(args.draft_arch)
            if args.smoke:
                dcfg = smoke_config(dcfg)
            dparams = init_from_decls(model_decl(dcfg), jax.random.PRNGKey(args.seed + 1))
            engine = SpeculativeEngine(cfg, params, dcfg, dparams,
                                       draft_k=args.speculate, **common)
        elif cfg.moe is not None:
            # the paper's pairing: serve params upcycled from the dense
            # parent, draft on the parent itself (function-preserving init
            # -> near-100% acceptance)
            dense_cfg = cfg.replace(name=f"{cfg.name}-parent", family="dense",
                                    moe=None)
            dense_params = init_from_decls(
                model_decl(dense_cfg), jax.random.PRNGKey(args.seed)
            )
            engine = SpeculativeEngine.from_upcycle(
                dense_cfg, cfg, dense_params, draft_k=args.speculate, **common
            )
        else:
            engine = SpeculativeEngine(cfg, params, cfg, params,
                                       draft_k=args.speculate, **common)
    else:
        engine = ServingEngine(cfg, params, **common)
    rng = np.random.default_rng(args.seed)

    def _prompt():
        return rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32)

    stem = _prompt()[: args.prompt_len // 2]  # shared head for --prefix-cache
    reqs = [
        Request(rid=i,
                prompt=(np.concatenate([stem, _prompt()[len(stem):]])
                        if args.prefix_cache else _prompt()),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    from repro.resilience import ShedError

    accepted, shed = [], 0
    for r in reqs:
        try:
            engine.submit(r)
            accepted.append(r)
        except ShedError as e:
            shed += 1
            print(f"  SHED: {e}")
    outputs = {r.rid: r.output for r in accepted}
    steps = 0
    while steps < 10_000 and (
        engine.sched.has_work if args.cache_mode == "paged"
        else (any(engine.slots) or engine.queue)
    ):
        engine.step()
        steps += 1
    dt = time.perf_counter() - t0
    total_tokens = sum(len(v) for v in outputs.values())
    print(f"served {len(accepted)} requests ({shed} shed), {total_tokens} "
          f"tokens in {dt:.2f}s ({total_tokens/dt:.1f} tok/s, "
          f"batch={args.max_batch}, cache={args.cache_mode})")
    if args.quant_weights != "none" or args.quant_kv != "none":
        print(f"  quant: weights={args.quant_weights}, kv={args.quant_kv} "
              f"(int8 payloads, fp32 accumulate, scales in sidecars)")
    h = engine.health()
    expired = [r.rid for r in accepted if r.status == "deadline"]
    if expired:
        print(f"  deadline-evicted requests: {expired}")
    print(f"  health: shed {h['shed_count']}, deadline evictions "
          f"{h['deadline_evictions']}, queued {h['queued_requests']}, "
          f"resident {h['resident_requests']}")
    kv = engine.kv_stats()
    print(f"  kv peak {kv['kv_bytes_peak']/1e6:.2f} MB"
          + (f", page util {kv['page_utilization']:.2f}, "
             f"peak pages {kv['peak_used_pages']}/{kv['num_pages']}"
             if args.cache_mode == "paged" else ""))
    if args.prefix_cache:
        p = kv["prefix"]
        print(f"  prefix cache: {p['hits']}/{p['lookups']} hits, "
              f"{p['hit_tokens']} prompt tokens served from cache, "
              f"{p['cow_clones']} COW clones, "
              f"{p['resident_pages']} pages resident")
    if args.speculate:
        s = kv["speculation"]
        print(f"  speculation: k={s['draft_k']}, acceptance "
              f"{s['acceptance_rate']:.2%} "
              f"({s['accepted_tokens']}/{s['drafted_tokens']} drafts over "
              f"{s['spec_steps']} verify steps)")
    for rid, out in sorted(outputs.items())[:4]:
        print(f"  req {rid}: {out[:12]}{'...' if len(out) > 12 else ''}")
    return outputs


if __name__ == "__main__":
    main()
