"""LR schedules. Paper §4.2: cosine annealing 3e-5 -> 3e-7 with 100 warmup
steps (per-step schedule is microbatch-invariant)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, lr: float, lr_min: float, warmup_steps: int, total_steps: int):
    """step is the 0-based optimizer step; warmup is 1-indexed so the FIRST
    update already has lr = lr/warmup (lr=0 at step 0 would silently no-op
    the first step — found by tests/test_smoke_archs)."""
    step = jnp.asarray(step, jnp.float32)
    warm = lr * jnp.minimum(step + 1, warmup_steps) / jnp.maximum(warmup_steps, 1)
    denom = jnp.maximum(total_steps - warmup_steps, 1)
    frac = jnp.clip((step - warmup_steps) / denom, 0.0, 1.0)
    cos = lr_min + 0.5 * (lr - lr_min) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup_steps, warm, cos)
