from repro.optim.adamw import (  # noqa: F401
    AdamWState,
    adamw_init,
    adamw_update,
    opt_state_shardings,
)
from repro.optim.schedule import cosine_schedule  # noqa: F401
