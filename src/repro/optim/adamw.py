"""AdamW with bf16 compute params + fp32 master weights and ZeRO-1-style
optimizer-state sharding (paper §3.2: "DP with ZeRO-1 ... replicates model
weights and shards optimizer states across DP ranks").

State layout per parameter:
  master — fp32 copy (authoritative), m/v — fp32 moments.

ZeRO-1 on TPU: compute params keep their TP/EP sharding and stay replicated
over 'data'; the optimizer state additionally shards its largest divisible
dim over the 'data' axis. XLA then keeps the optimizer update fully
data-sharded and re-broadcasts (all-gathers) only the updated bf16 params —
the same communication shape as Megatron's distributed optimizer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import TrainConfig
from repro.sharding.rules import FoldingPlan, ParamDecl, resolve_spec


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    master: Any
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(jnp.zeros((), jnp.int32), f32(params), zeros(params), zeros(params))


def opt_state_abstract(params_abs) -> AdamWState:
    """ShapeDtypeStruct skeleton of the optimizer state for a params
    abstraction (ShapeDtypeStructs or concrete arrays) — used by checkpoint
    restore to validate a manifest against the model before materializing."""
    f32 = lambda t: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), t
    )
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        master=f32(params_abs),
        m=f32(params_abs),
        v=f32(params_abs),
    )


def _zero1_spec(decl: ParamDecl, plan: FoldingPlan) -> P:
    """Param spec + shard the largest remaining dim over 'data' (ZeRO-1).
    No-op for dims already data-sharded (e.g. FSDP params)."""
    from repro.sharding.rules import _resolve_decl, fsdp_spec

    base = _resolve_decl(decl, plan)
    return fsdp_spec(base, decl.shape, plan.mesh, "data")


def opt_state_shardings(decls, plan: Optional[FoldingPlan], zero1: bool = True):
    """Shardings for AdamWState given the model's ParamDecl tree."""
    if plan is None:
        return None

    def param_sh(d: ParamDecl):
        if zero1:
            spec = _zero1_spec(d, plan)
        else:
            from repro.sharding.rules import _resolve_decl

            spec = _resolve_decl(d, plan)
        return NamedSharding(plan.mesh, spec)

    is_leaf = lambda d: isinstance(d, ParamDecl)
    tree = jax.tree.map(param_sh, decls, is_leaf=is_leaf)
    return AdamWState(
        step=NamedSharding(plan.mesh, P()), master=tree, m=tree, v=tree
    )


def adamw_update(
    cfg: TrainConfig,
    grads,
    state: AdamWState,
    lr: jax.Array,
) -> Tuple[Any, AdamWState]:
    """Returns (new bf16-compute params, new state). Applies global-norm
    clipping and decoupled weight decay."""
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(g32)) + 1e-16
    )
    clip = jnp.minimum(1.0, cfg.grad_clip / gnorm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.adam_b1**t
    bc2 = 1.0 - cfg.adam_b2**t

    def upd(g, master, m, v):
        g = g * clip
        m = cfg.adam_b1 * m + (1 - cfg.adam_b1) * g
        v = cfg.adam_b2 * v + (1 - cfg.adam_b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.adam_eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if master.ndim >= 2 else 0.0
        master = master - lr * (delta + wd * master)
        return master, m, v

    flat_g, treedef = jax.tree.flatten(g32)
    flat_ms = jax.tree.leaves(state.master)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(*args) for args in zip(flat_g, flat_ms, flat_m, flat_v)]
    new_master = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])

    new_params = jax.tree.map(
        lambda ms, p_old: ms.astype(p_old.dtype), new_master, grads
    )
    return new_params, AdamWState(step, new_master, new_m, new_v)
