from repro.data.pipeline import BlendedDataset, SyntheticSource, make_train_iter  # noqa: F401
