from repro.data.pipeline import (  # noqa: F401
    BlendedDataset,
    SyntheticSource,
    TrainIterator,
    make_train_iter,
)
