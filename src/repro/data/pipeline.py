"""Deterministic, shardable synthetic data pipeline.

Mirrors the paper's §4.1 setup structurally: two sources (a large "web"
corpus standing in for the RedPajama-V2 low-perplexity bucket, and a small
"academic" source) blended 7:3, sequence-packed to fixed length, with
next-token labels. The container has no internet, so both sources are
deterministic synthetic token streams — but with *different statistics*
(different Zipf exponents and n-gram structure) so blend-ratio ablations are
meaningful and loss curves differ measurably between sources.

The iterator is host-side numpy (cheap, reproducible) and yields
global-batch arrays; the launcher device_puts them with the batch sharding.

**Corrupt-batch handling**: every batch is validated (token ids in range,
float fields finite) before it is handed to the trainer; a corrupt batch —
injected via the ``data.batch`` fault site or a genuinely bad shard — is
skipped with a warning, its index recorded in ``state()["skipped"]`` (and
therefore in the checkpoint meta), up to a bounded ``skip_budget``; past
the budget the iterator raises
:class:`~repro.resilience.recovery.DataCorruptionError` — a pipeline
producing mostly garbage should stop the run, not silently thin the data.
Because skipped batches still consume the bit-generator stream, an
uninterrupted run and a checkpoint-resumed one see byte-identical batch
sequences.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.resilience import faults
from repro.resilience.recovery import DataCorruptionError


@dataclasses.dataclass
class SyntheticSource:
    """Markov-ish Zipf token stream: token t+1 depends on t via a seeded
    per-token permutation, mixed with fresh Zipf draws. Gives learnable
    structure (so training loss drops) with source-distinct statistics."""

    vocab_size: int
    seed: int
    zipf_a: float = 1.2
    markov_p: float = 0.7  # prob. next token is the deterministic successor

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._succ = rng.permutation(self.vocab_size)
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-self.zipf_a)
        self._probs = probs / probs.sum()

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        fresh = rng.choice(self.vocab_size, size=n + 1, p=self._probs)
        out = np.empty(n + 1, dtype=np.int64)
        out[0] = fresh[0]
        use_markov = rng.random(n) < self.markov_p
        for i in range(1, n + 1):
            out[i] = self._succ[out[i - 1]] if use_markov[i - 1] else fresh[i]
        return out


@dataclasses.dataclass
class BlendedDataset:
    """Two-source blend at a token-budget ratio (paper: 7:3)."""

    vocab_size: int
    seq_len: int
    blend_ratio: float = 0.7
    seed: int = 0

    def __post_init__(self):
        self.web = SyntheticSource(self.vocab_size, self.seed * 2 + 1, zipf_a=1.2)
        self.academic = SyntheticSource(
            self.vocab_size, self.seed * 2 + 2, zipf_a=1.05, markov_p=0.85
        )

    def batch(self, rng: np.random.Generator, batch_size: int) -> Dict[str, np.ndarray]:
        toks = np.empty((batch_size, self.seq_len + 1), dtype=np.int32)
        src = rng.random(batch_size) < self.blend_ratio
        for i in range(batch_size):
            source = self.web if src[i] else self.academic
            toks[i] = source.sample(rng, self.seq_len)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


class TrainIterator:
    """Stateful blended-batch iterator with an exact-resume snapshot.

    ``state()`` captures the numpy bit-generator state (plus a consumed-batch
    counter for bookkeeping) as a JSON-serializable dict; ``restore()`` puts
    an iterator built with the SAME constructor arguments back to that point,
    so the post-restore batch sequence is bitwise the uninterrupted one. The
    snapshot rides in the checkpoint manifest's ``meta`` (it is host state,
    not a device array — see ``train/state.py``).
    """

    def __init__(
        self,
        dataset: BlendedDataset,
        batch_size: int,
        extra: Optional[Dict[str, Tuple[int, ...]]] = None,
        sample_seed: int = 0,
        skip_budget: int = 16,
    ):
        self.ds = dataset
        self.batch_size = batch_size
        self.extra = extra
        self.skip_budget = skip_budget
        self._rng = np.random.default_rng(sample_seed + 17)
        self._batches = 0
        self._skipped: List[int] = []

    def __iter__(self) -> "TrainIterator":
        return self

    def _draw(self) -> Dict[str, np.ndarray]:
        b = self.ds.batch(self._rng, self.batch_size)
        if self.extra:
            for k, shape in self.extra.items():
                b[k] = self._rng.standard_normal(shape).astype(np.float32) * 0.02
        return b

    def _validate(self, b: Dict[str, np.ndarray]) -> Optional[str]:
        """None if the batch is servable, else a description of the rot."""
        V = self.ds.vocab_size
        for k, v in b.items():
            if np.issubdtype(v.dtype, np.integer):
                lo, hi = int(v.min()), int(v.max())
                if lo < 0 or hi >= V:
                    return f"'{k}' token ids outside [0, {V}): min {lo} max {hi}"
            elif not np.isfinite(v).all():
                return f"'{k}' has non-finite values"
        return None

    def __next__(self) -> Dict[str, np.ndarray]:
        while True:
            b = self._draw()
            idx = self._batches
            self._batches += 1
            for spec in faults.fire("data.batch"):
                if spec.kind == "corrupt_batch":
                    b = dict(b)
                    toks = b["tokens"].copy()
                    toks.flat[0] = spec.args.get(
                        "value", self.ds.vocab_size + 7
                    )
                    b["tokens"] = toks
            err = self._validate(b)
            if err is None:
                return b
            self._skipped.append(idx)
            warnings.warn(
                f"data batch {idx} corrupt ({err}) — skipped "
                f"[{len(self._skipped)}/{self.skip_budget} budget]",
                stacklevel=2,
            )
            if len(self._skipped) > self.skip_budget:
                raise DataCorruptionError(
                    f"{len(self._skipped)} corrupt batches exceeds the "
                    f"skip budget of {self.skip_budget} (indices "
                    f"{self._skipped}); the pipeline is rotten, stopping"
                )

    def state(self) -> Dict:
        return {
            "rng": self._rng.bit_generator.state,
            "batches": self._batches,
            "batch_size": self.batch_size,
            "skipped": list(self._skipped),
        }

    def restore(self, state: Dict) -> "TrainIterator":
        assert state.get("batch_size", self.batch_size) == self.batch_size, (
            "resuming with a different global batch size changes the sample "
            "stream; restart the data state explicitly if that is intended"
        )
        self._rng.bit_generator.state = state["rng"]
        self._batches = int(state["batches"])
        self._skipped = list(state.get("skipped", []))
        return self


def make_train_iter(
    vocab_size: int,
    seq_len: int,
    batch_size: int,
    blend_ratio: float = 0.7,
    seed: int = 0,
    extra: Optional[Dict[str, Tuple[int, ...]]] = None,
    sample_seed: Optional[int] = None,
    skip_budget: int = 16,
) -> TrainIterator:
    """Yields global batches forever, deterministically. ``seed`` defines
    the LANGUAGE (the two sources' statistics); ``sample_seed`` the sampling
    stream — held-out evaluation uses the same seed with a fresh
    sample_seed. ``extra`` adds float stub inputs (vlm 'embeds' / audio
    'frames') of the given shapes. The returned iterator exposes
    ``state()/restore()`` for exact checkpoint-resume of the data stream."""
    ds = BlendedDataset(vocab_size, seq_len, blend_ratio, seed)
    return TrainIterator(
        ds, batch_size, extra,
        sample_seed=(sample_seed if sample_seed is not None else seed),
        skip_budget=skip_budget,
    )
