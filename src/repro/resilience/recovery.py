"""Typed error taxonomy + bounded-retry helper for supervised recovery.

Every fault class the harness can inject maps to exactly one outcome:
automatic recovery (retry, fallback-to-verified, skip-and-log, preempt) or
one of these exception types. Code catching them can act on the *class* —
a :class:`ShedError` means "back off and resubmit", a
:class:`CheckpointCorruptionError` means "this checkpoint directory has no
restorable state", a :class:`TrainingDivergedError` means "the run cannot
self-heal and needs operator attention".
"""
from __future__ import annotations

import time
import warnings
from typing import Callable, Tuple, Type


class InjectedFault(OSError):
    """Raised by an injection site simulating an I/O failure. Subclasses
    ``OSError`` so production retry paths treat it exactly like the real
    transient failures it stands in for."""


class ShardCorruptionError(RuntimeError):
    """A checkpoint shard file failed validation (missing, torn, or
    checksum mismatch). Carries enough context to name the bad file."""


class CheckpointCorruptionError(RuntimeError):
    """No restorable checkpoint: the requested (or every) step failed
    verification. The message lists every step tried and why it failed —
    restore never silently returns garbage."""


class DataCorruptionError(RuntimeError):
    """The data pipeline exhausted its corrupt-batch skip budget."""


class ShedError(RuntimeError):
    """Admission rejected under load (queue bound or page-pool watermark).
    The request was NOT enqueued; the client should back off and retry or
    route elsewhere. Loud by design — the alternative is a deadlocked or
    unboundedly-queued engine."""


class HangError(RuntimeError):
    """A watchdog tripped: one step exceeded its wall-clock budget (hung
    collective, device stall, or a wedged host thread)."""


class TrainingDivergedError(RuntimeError):
    """The anomaly supervisor hit its strike limit and has no good
    checkpoint to roll back to."""


def retry_io(
    fn: Callable,
    *args,
    attempts: int = 3,
    base_delay_s: float = 0.01,
    retry_on: Tuple[Type[BaseException], ...] = (OSError,),
    sleep: Callable[[float], None] = time.sleep,
    what: str = "io",
    **kwargs,
):
    """Call ``fn`` with bounded retries and exponential backoff
    (``base_delay_s * 2**attempt`` between tries). Non-``retry_on``
    exceptions propagate immediately; the final failure propagates with the
    retry count already warned, so a persistent fault is loud, not looping.
    """
    assert attempts >= 1
    for attempt in range(attempts):
        try:
            return fn(*args, **kwargs)
        except retry_on as e:
            if attempt == attempts - 1:
                raise
            delay = base_delay_s * (2 ** attempt)
            warnings.warn(
                f"{what}: attempt {attempt + 1}/{attempts} failed ({e}); "
                f"retrying in {delay:.3f}s",
                stacklevel=2,
            )
            sleep(delay)
