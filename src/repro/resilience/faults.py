"""Deterministic fault-injection harness.

Injection is *site + event-counter* based, not probability based, so every
chaos test is exactly reproducible: an installed :class:`FaultInjector`
counts the events at each named site and a :class:`FaultSpec` fires on a
chosen window of that counter (``at``/``count``) or periodically
(``every``). The optional ``seed`` only parameterizes payloads that need
randomness (e.g. which bit a bit-flip corrupts), never *whether* a fault
fires.

Known sites and the fault kinds their host code applies:

================== ==================================== =====================
site               kinds                                threaded through
================== ==================================== =====================
``ckpt.shard_write`` ``write_fail`` | ``torn`` |        ``checkpoint/sharded.
                   ``bitflip``                          py:_save_shard``
``ckpt.shard_read``  ``read_fail``                      ``checkpoint/sharded.
                                                        py:_load_shard``
``train.step``     ``nan_grads`` | ``loss_spike`` |     ``train/trainer.py:
                   ``hang``                             Trainer.run``
``data.batch``     ``corrupt_batch``                    ``data/pipeline.py:
                                                        TrainIterator``
``serving.alloc``  ``alloc_fail``                       ``serving/kv_cache.
                                                        py:PagePool.alloc``
``serving.step``   ``hang``                             ``serving/engine.py:
                                                        ServingEngine.step``
================== ==================================== =====================

Installation is a context manager (``with faults.inject(spec, ...)``), so a
test cannot leak an injector into the rest of the suite; the async
checkpoint writer thread sees the same injector (module global), which is
exactly what the crash-mid-save chaos tests need.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from collections import defaultdict
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class FaultSpec:
    """One fault to inject: fires at ``site`` when that site's event
    counter lands in ``[at, at + count)``, or (with ``every``) whenever
    ``counter % every == at``."""

    site: str
    kind: str
    at: int = 0
    count: int = 1
    every: Optional[int] = None
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def matches(self, event: int) -> bool:
        if self.every is not None:
            return event % self.every == self.at % self.every
        return self.at <= event < self.at + self.count


class FaultInjector:
    """Counts events per site and reports which specs fire on each one.

    ``fired`` is the audit log — ``(site, kind, event_index)`` triples in
    firing order — which the chaos suite asserts against to prove a fault
    was actually exercised (a recovery test that never fired its fault
    proves nothing).
    """

    def __init__(self, specs, seed: int = 0):
        self.specs: List[FaultSpec] = list(specs)
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self._counts: Dict[str, int] = defaultdict(int)
        self._lock = threading.Lock()
        self.fired: List[tuple] = []

    def hits(self, site: str) -> List[FaultSpec]:
        with self._lock:
            event = self._counts[site]
            self._counts[site] += 1
            out = [s for s in self.specs if s.site == site and s.matches(event)]
            for s in out:
                self.fired.append((site, s.kind, event))
        return out

    def events(self, site: str) -> int:
        with self._lock:
            return self._counts[site]


_ACTIVE: Optional[FaultInjector] = None


def active() -> Optional[FaultInjector]:
    return _ACTIVE


@contextlib.contextmanager
def inject(*specs: FaultSpec, seed: int = 0):
    """Install a :class:`FaultInjector` for the dynamic extent of the
    ``with`` block (re-entrant: the previous injector is restored)."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = FaultInjector(specs, seed=seed)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def fire(site: str) -> List[FaultSpec]:
    """The hook production code calls at an injection site. No injector
    installed -> empty list (the common case, one global read)."""
    inj = _ACTIVE
    return inj.hits(site) if inj is not None else []


# -- file corruption payloads (used by the ckpt.shard_write site and by
# -- chaos tests that corrupt committed checkpoints post-hoc) ---------------


def truncate_file(path: str, keep_fraction: float = 0.5) -> None:
    """Torn write: keep only the leading ``keep_fraction`` of the file."""
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(1, int(size * keep_fraction)))


def flip_bit(path: str, rng: Optional[np.random.Generator] = None,
             skip_header: int = 128) -> int:
    """Silent corruption: flip one bit in the file's data region (past the
    ``.npy`` header) at a seeded offset. Returns the byte offset flipped."""
    rng = rng if rng is not None else np.random.default_rng(0)
    size = os.path.getsize(path)
    lo = min(skip_header, max(0, size - 1))
    off = int(rng.integers(lo, size))
    with open(path, "r+b") as f:
        f.seek(off)
        byte = f.read(1)
        f.seek(off)
        f.write(bytes([byte[0] ^ (1 << int(rng.integers(0, 8)))]))
    return off
