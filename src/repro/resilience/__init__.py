"""Fault-injection harness + supervised recovery.

Two halves, deliberately decoupled from the subsystems they protect (this
package imports nothing from checkpoint/train/serving, so every layer can
depend on it without cycles):

* :mod:`repro.resilience.faults` — a deterministic, seeded fault-injection
  harness. Production code calls :func:`faults.fire` at named injection
  sites (checkpoint shard writes/reads, the train step, the data pipeline,
  the serving page pool and step loop); with no injector installed the call
  is a no-op, under ``faults.inject(...)`` it returns the :class:`FaultSpec`
  list that matched the site's event counter. The chaos suite
  (``tests/test_resilience.py``) drives every fault class through it.
* :mod:`repro.resilience.recovery` — the typed error taxonomy
  (:class:`ShedError`, :class:`CheckpointCorruptionError`, ...) plus the
  bounded-retry/backoff helper the checkpoint I/O path uses. Every fault
  class is either recovered automatically or surfaced through one of these
  types — never a silent-corruption path.
"""
from repro.resilience.faults import (  # noqa: F401
    FaultInjector,
    FaultSpec,
    active,
    fire,
    flip_bit,
    inject,
    truncate_file,
)
from repro.resilience.recovery import (  # noqa: F401
    CheckpointCorruptionError,
    DataCorruptionError,
    HangError,
    InjectedFault,
    ShardCorruptionError,
    ShedError,
    TrainingDivergedError,
    retry_io,
)
