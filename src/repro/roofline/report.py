"""Generate the EXPERIMENTS.md §Roofline table from dry-run artifacts.

  PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]

Per (arch x shape), single-pod mesh: the three roofline terms, dominant
bottleneck, MODEL_FLOPS, usefulness ratio (MODEL_FLOPS / HLO_FLOPs), and a
one-line mitigation suggestion for the dominant term.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.config import SHAPES, get_config
from repro.roofline.analysis import HW


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    s = SHAPES[shape_name]
    if s.kind == "train":
        return 3.0 * cfg.flops_per_token(s.seq_len) * s.global_batch * s.seq_len
    if s.kind == "prefill":
        return 1.0 * cfg.flops_per_token(s.seq_len) * s.global_batch * s.seq_len
    # decode: one token; attention reads the full cache
    return 1.0 * cfg.flops_per_token(s.seq_len) * s.global_batch


def mitigation(rec: Dict) -> str:
    dom = rec["roofline"]["dominant"]
    shape = rec["shape"]
    if dom == "memory":
        if shape == "train_4k":
            return "cut remat re-reads / fp32 logits; fuse CE over vocab shards"
        if shape == "prefill_32k":
            return "smaller attention working set (larger KV blocks, bf16 acc)"
        return "shard cache/batch further; avoid replicated decode weights"
    if dom == "collective":
        return "fold more traffic onto ICI-local axis; a2a dispatcher; overlap"
    return "increase per-chip tile sizes / reduce padding waste"


def load(dir_: str, multi_pod: bool) -> List[Dict]:
    out = []
    tag = "2pod" if multi_pod else "1pod"
    for f in sorted(glob.glob(os.path.join(dir_, f"*_{tag}.json"))):
        out.append(json.load(open(f)))
    return out


def render(dir_: str = "experiments/dryrun") -> str:
    rows = []
    header = (
        "| arch | shape | mode | compute_s | memory_s | collective_s | dominant | "
        "MODEL_TF | HLO_TF/chip | useful% | mitigation |"
    )
    sep = "|" + "---|" * 11
    for rec in load(dir_, False):
        if rec["status"] == "skipped":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | — | skipped | — | — | — | {rec['reason'][:60]} |"
            )
            continue
        if rec["status"] != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | ERROR: {rec.get('error','')[:60]} |")
            continue
        r = rec["roofline"]
        mf = model_flops(rec["arch"], rec["shape"])
        chips = rec["chips"]
        useful = mf / chips / max(r["flops"], 1.0)
        mode = f"{rec['attn_mode']}/{rec['moe_mode'] or '-'}{'/fsdp' if rec.get('fsdp') else ''}"
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {mode} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| **{r['dominant']}** | {mf/1e12:.1f} | {r['flops']/1e12:.2f} "
            f"| {100*useful:.0f}% | {mitigation(rec)} |"
        )
    return "\n".join([header, sep] + rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    print(render(args.dir))


if __name__ == "__main__":
    main()
