from repro.roofline.analysis import (  # noqa: F401
    HW,
    HW_PROFILES,
    collective_bytes,
    hw_profile,
    roofline_terms,
)
