"""Re-score dry-run JSON artifacts from their saved gzipped HLO texts —
analyzer improvements don't require recompiling 80 combos.

  PYTHONPATH=src python -m repro.roofline.rescore \\
      [--json experiments/dryrun] [--hlo experiments/hlo]
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.roofline.analysis import roofline_from_hlo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="experiments/dryrun")
    ap.add_argument("--hlo", default="experiments/hlo")
    args = ap.parse_args()
    n = 0
    for jf in sorted(glob.glob(os.path.join(args.json, "*.json"))):
        rec = json.load(open(jf))
        if rec.get("status") != "ok":
            continue
        tag = os.path.basename(jf)[: -len(".json")]
        hf = os.path.join(args.hlo, tag + ".hlo.gz")
        if not os.path.exists(hf):
            continue
        hlo = gzip.open(hf, "rt").read()
        terms, coll = roofline_from_hlo(hlo, rec["chips"])
        rec["roofline"] = terms.as_dict()
        rec["collectives"] = coll
        json.dump(rec, open(jf, "w"), indent=1)
        n += 1
    print(f"re-scored {n} artifacts")


if __name__ == "__main__":
    main()
