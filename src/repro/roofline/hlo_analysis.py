"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count (verified empirically), which silently drops ~L x the FLOPs/bytes of a
scan-over-layers model. This module parses the compiled SPMD HLO text and
produces roofline inputs that respect loop structure:

* per-computation symbol tables (every def line carries its shape),
* while-loop trip counts (the comparison constant in the condition
  computation), propagated multiplicatively through nested scans,
* FLOPs from ``dot`` ops: 2 * prod(result_dims) * K, K from the lhs shape's
  contracting dims,
* HBM traffic proxy: for every fusion/materializing op, unique operand
  bytes + result bytes (fusions are XLA's memory-traffic units),
* collective wire bytes by kind with ring multipliers (all-reduce 2x).

Shapes in SPMD HLO are per-device shards, so all results are per-device.
This is an approximation (it ignores VMEM residency between fusions and
double-counts some small reused operands) but it is *structurally* correct
where the builtin analysis is wrong by a factor of num_layers.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_OP_NAME_RE = re.compile(r"([\w\-]+)\(")


def _parse_def(line: str):
    """Parse `%name = TYPE op-name(operands), attrs` robustly — tuple types
    may contain nested parens and `/*index=N*/` comments (which contain '=')."""
    line = _COMMENT_RE.sub("", line)
    stripped = line.strip()
    if not (stripped.startswith("%") or stripped.startswith("ROOT")):
        return None
    if "=" not in stripped:
        return None
    lhs, rhs = stripped.split("=", 1)
    name = lhs.replace("ROOT", "").strip().lstrip("%")
    if not name:
        return None
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        type_str, rest = rhs[: end + 1], rhs[end + 1 :].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp + 1 :].strip()
    m = _OP_NAME_RE.match(rest)
    if not m:
        return None
    return name, type_str, m.group(1), line
_CALLED_RE = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr]
    order: List[str]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        m = _COMP_RE.match(line.strip()) if ("->" in line and "{" in line) else None
        if m and not line.strip().startswith("%param"):
            cur = Computation(m.group(1), {}, [])
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        parsed = _parse_def(line)
        if parsed:
            name, type_str, op, clean = parsed
            cur.instrs[name] = Instr(name, type_str, op, clean)
            cur.order.append(name)
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition — our scans compare
    the induction variable against the static length."""
    best = 1
    for ins in cond.instrs.values():
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", ins.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, table: Dict[str, Instr]) -> float:
    ops = _OPERAND_RE.findall(ins.line.split("(", 1)[1])
    lhs = table.get(ops[0]) if ops else None
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    k = 1
    if lhs is not None and m is not None:
        dims = _shape_dims(lhs.type_str)
        if dims:
            shape = dims[0][1]
            for ci in (int(x) for x in m.group(1).split(",") if x):
                if ci < len(shape):
                    k *= shape[ci]
    out_elems = 0
    for _, dims in _shape_dims(ins.type_str):
        n = 1
        for d in dims:
            n *= d
        out_elems += n
    return 2.0 * out_elems * k


_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "scatter", "gather", "sort", "copy",
    "dynamic-slice", "dynamic-update-slice", "transpose", "reduce",
    "broadcast", "concatenate", "slice", "reshape", "pad", "iota",
    "convert", "select-and-scatter", "reverse",
}


def _operands(ins: Instr) -> List[str]:
    args = ins.line.split("(", 1)[1].split(")", 1)[0]
    return _OPERAND_RE.findall(args)


_SLICING = ("dynamic-slice", "slice", "gather")


def _fusion_param_bytes(comp: Computation, idx: int, full_bytes: float) -> float:
    """Bytes a fusion actually reads of parameter ``idx``: if every internal
    use is a slicing op, only the sliced window leaves HBM."""
    p_name = None
    for ins in comp.instrs.values():
        if ins.op == "parameter" and re.search(rf"parameter\({idx}\)", ins.line):
            p_name = ins.name
            break
    if p_name is None:
        return full_bytes

    def uses_of(name: str):
        pat = re.compile(rf"%{re.escape(name)}(?![\w\.])")
        return [
            u for u in comp.instrs.values()
            if u.name != name and pat.search(u.line.split("=", 1)[-1])
        ]

    # converts/bitcasts are views: a fusion that converts the stack and then
    # slices it only moves the sliced window through HBM on TPU.
    frontier = [p_name]
    uses: List[Instr] = []
    for _ in range(4):  # bounded transparency depth
        nxt = []
        for n in frontier:
            for u in uses_of(n):
                if u.op in ("convert", "bitcast", "reshape", "copy"):
                    nxt.append(u.name)
                else:
                    uses.append(u)
        if not nxt:
            break
        frontier = nxt
    if uses and all(u.op in _SLICING for u in uses):
        return float(max(_type_bytes(u.type_str) for u in uses))
    return full_bytes


def _dus_accumulator_bytes(comp: Computation) -> Optional[float]:
    """If the fusion is an in-place-update pattern — a dynamic-update-slice
    whose result is (modulo converts) the fusion root — the accumulator
    param and the result do NOT round-trip HBM on TPU (in-place DUS); only
    the update window does. XLA:CPU may wrap the DUS in full-tensor dtype
    converts; those are lowering artifacts, not HBM traffic on the target.
    Returns the update-window bytes, or None if not this pattern."""
    for ins in comp.instrs.values():
        if ins.op == "dynamic-update-slice":
            names = _operands(ins)
            if len(names) > 1 and names[1] in comp.instrs:
                return float(_type_bytes(comp.instrs[names[1]].type_str))
        if ins.op == "scatter":  # vmapped DUS lowers to scatter
            names = _operands(ins)
            if len(names) > 2 and names[2] in comp.instrs:
                return float(
                    _type_bytes(comp.instrs[names[2]].type_str)
                    + _type_bytes(comp.instrs[names[1]].type_str)
                )
    return None


def _instr_traffic(
    ins: Instr, table: Dict[str, Instr], comps: Optional[Dict[str, "Computation"]] = None
) -> float:
    if ins.op not in _TRAFFIC_OPS:
        return 0.0
    if ins.op == "reshape":  # bitcast in practice
        return 0.0
    result = float(_type_bytes(ins.type_str))
    # slicing ops touch only the sliced window, not the whole operand;
    # dynamic-update-slice reads+writes only the update window (in-place).
    if ins.op in _SLICING:
        return 2.0 * result
    if ins.op == "dynamic-update-slice":
        names = _operands(ins)
        upd = _type_bytes(table[names[1]].type_str) if len(names) > 1 and names[1] in table else result
        return 2.0 * upd
    if ins.op == "scatter":  # in-place on TPU: window read+write + indices
        names = _operands(ins)
        if len(names) > 2 and names[2] in table:
            upd = float(_type_bytes(table[names[2]].type_str))
            idx = float(_type_bytes(table[names[1]].type_str)) if names[1] in table else 0.0
            return 2.0 * upd + idx
    names = _operands(ins)
    callee = None
    if ins.op == "fusion" and comps is not None:
        m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
        callee = comps.get(m.group(1)) if m else None
    if callee is not None:
        acc = _dus_accumulator_bytes(callee)
        if acc is not None and result >= acc:
            # in-place update: result/accumulator stay resident; charge the
            # window twice (read+write) plus the small side inputs.
            side = 0.0
            for i, op_name in enumerate(names):
                if op_name in table:
                    b = float(_type_bytes(table[op_name].type_str))
                    if b < result:  # skip the accumulator itself
                        side += min(b, result)
            return 2.0 * acc + side
    total = result
    seen = set()
    for i, op_name in enumerate(names):
        if op_name in seen or op_name not in table:
            continue
        seen.add(op_name)
        full = float(_type_bytes(table[op_name].type_str))
        if callee is not None:
            full = _fusion_param_bytes(callee, i, full)
        total += full
    return total


@dataclasses.dataclass
class HLOCosts:
    flops: float
    hbm_bytes: float
    collective_bytes: Dict[str, float]

    @property
    def total_collective(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str, entry: Optional[str] = None) -> HLOCosts:
    comps = parse_hlo(text)
    if not comps:
        return HLOCosts(0.0, 0.0, {k: 0.0 for k in COLLECTIVES})
    # entry = computation not called by any other, or named like main
    called = set()
    callers: Dict[str, List[Tuple[str, str]]] = {}
    for c in comps.values():
        for ins in c.instrs.values():
            for callee in _CALLED_RE.findall(ins.line):
                called.add(callee)
                callers.setdefault(c.name, []).append((ins.name, callee))
    if entry is None:
        if "__entry__" in comps:
            entry = comps["__entry__"].name
        else:
            entries = [c for c in comps if c not in called and "main" in c]
            entries = entries or [c for c in comps if c not in called]
            entry = entries[0] if entries else next(iter(comps))

    memo: Dict[str, HLOCosts] = {}

    def visit(cname: str) -> HLOCosts:
        if cname in memo:
            return memo[cname]
        comp = comps.get(cname)
        if comp is None:
            return HLOCosts(0.0, 0.0, {k: 0.0 for k in COLLECTIVES})
        flops = 0.0
        hbm = 0.0
        coll = {k: 0.0 for k in COLLECTIVES}
        for ins in comp.instrs.values():
            base_op = ins.op.replace("-start", "")
            if base_op in COLLECTIVES:
                nb = _type_bytes(ins.type_str)
                coll[base_op] += 2.0 * nb if base_op == "all-reduce" else float(nb)
                hbm += 2.0 * _type_bytes(ins.type_str)
            elif ins.op == "dot":
                flops += _dot_flops(ins, comp.instrs)
                hbm += _instr_traffic(ins, comp.instrs, comps)
            elif ins.op == "fusion":
                # fused dots live in a nested computation via calls=
                hbm += _instr_traffic(ins, comp.instrs, comps)
                for callee in _CALLED_RE.findall(ins.line):
                    sub = visit(callee)
                    flops += sub.flops
                    for k in COLLECTIVES:
                        coll[k] += sub.collective_bytes[k]
            elif ins.op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                if mb:
                    body = visit(mb.group(1))
                trip = _trip_count(comps[mc.group(1)]) if mc and mc.group(1) in comps else 1
                if body:
                    flops += trip * body.flops
                    hbm += trip * body.hbm_bytes
                    for k in COLLECTIVES:
                        coll[k] += trip * body.collective_bytes[k]
            elif ins.op in ("call", "conditional", "async-start"):
                for callee in _CALLED_RE.findall(ins.line):
                    sub = visit(callee)
                    flops += sub.flops
                    hbm += sub.hbm_bytes
                    for k in COLLECTIVES:
                        coll[k] += sub.collective_bytes[k]
            else:
                hbm += _instr_traffic(ins, comp.instrs, comps)
        out = HLOCosts(flops, hbm, coll)
        memo[cname] = out
        return out

    return visit(entry)
