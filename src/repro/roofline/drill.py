"""Drill-down analysis for the perf loop: per-collective breakdown and the
top HBM-traffic instructions (with loop multipliers applied), given a
compiled HLO text. This is the 'profiler' of the dry-run world — §Perf
hypotheses are formed against its output."""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

from repro.roofline.hlo_analysis import (
    COLLECTIVES,
    _CALLED_RE,
    _instr_traffic,
    _trip_count,
    _type_bytes,
    parse_hlo,
)


def loop_multipliers(comps) -> Dict[str, int]:
    """computation name -> product of enclosing while trip counts."""
    mult: Dict[str, int] = {}
    entry = comps.get("__entry__")
    if entry is None:
        return {c: 1 for c in comps}

    def walk(cname: str, m: int):
        comp = comps.get(cname)
        if comp is None or mult.get(cname, 0) >= m and cname in mult:
            if cname in mult:
                return
        mult[cname] = max(mult.get(cname, 0), m)
        for ins in comp.instrs.values():
            if ins.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                trip = _trip_count(comps[mc.group(1)]) if mc and mc.group(1) in comps else 1
                if mb:
                    walk(mb.group(1), m * trip)
                if mc:
                    walk(mc.group(1), m)
            else:
                for callee in _CALLED_RE.findall(ins.line):
                    walk(callee, m)

    walk(entry.name, 1)
    return mult


def _fusion_bodies(comps):
    import re as _re

    bodies = set()
    for c in comps.values():
        for ins in c.instrs.values():
            if ins.op == "fusion":
                m = _re.search(r"calls=%?([\w\.\-]+)", ins.line)
                if m:
                    bodies.add(m.group(1))
    return bodies


def top_traffic(hlo_text: str, n: int = 20) -> List[Tuple[float, int, str, str, str]]:
    comps = parse_hlo(hlo_text)
    mult = loop_multipliers(comps)
    bodies = _fusion_bodies(comps)
    rows = []
    for cname, comp in comps.items():
        if cname == "__entry__" or cname in bodies:
            continue
        m = mult.get(cname, 1)
        for ins in comp.instrs.values():
            t = _instr_traffic(ins, comp.instrs, comps) * m
            if t > 0:
                meta = re.search(r'op_name="([^"]*)"', ins.line)
                rows.append((t, m, ins.op, ins.type_str[:48],
                             (meta.group(1)[-70:] if meta else cname[:40])))
    rows.sort(reverse=True)
    return rows[:n]


def collective_detail(hlo_text: str, n: int = 15) -> List[Tuple[float, int, str, str, str]]:
    comps = parse_hlo(hlo_text)
    mult = loop_multipliers(comps)
    rows = []
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 1)
        for ins in comp.instrs.values():
            op = ins.op.replace("-start", "")
            if op in COLLECTIVES:
                nb = _type_bytes(ins.type_str) * (2.0 if op == "all-reduce" else 1.0) * m
                meta = re.search(r'op_name="([^"]*)"', ins.line)
                rows.append((nb, m, op, ins.type_str[:48],
                             (meta.group(1)[-70:] if meta else cname[:40])))
    rows.sort(reverse=True)
    return rows[:n]


def print_drill(hlo_text: str, n: int = 18) -> None:
    print("== top HBM traffic (xloop) ==")
    for t, m, op, ty, src in top_traffic(hlo_text, n):
        print(f"{t/1e9:9.2f} GB x{m:3d} {op:12s} {ty:48s} {src}")
    print("== collectives (xloop) ==")
    for t, m, op, ty, src in collective_detail(hlo_text, n):
        print(f"{t/1e9:9.3f} GB x{m:3d} {op:18s} {ty:48s} {src}")


if __name__ == "__main__":
    import gzip
    import sys

    path = sys.argv[1]
    text = gzip.open(path, "rt").read() if path.endswith(".gz") else open(path).read()
    print_drill(text)
