"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs            / (chips * peak_FLOPs)
  memory     = HLO_bytes_accessed   / (chips * HBM_bw)
  collective = collective_bytes     / (chips * link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (whole-program,
all devices). collective_bytes is parsed from the compiled HLO text: we sum
the effective wire bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, using standard ring-algorithm multipliers
on the *per-device* shard sizes the SPMD partitioner printed.

Hardware model: selectable per-chip profiles (``HW_PROFILES``). The default
is TPU v5e-class, per the brief: 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. Set ``REPRO_HW_PROFILE=v5p`` (or ``cpu``) to re-cost
reports and the kernel autotuner for a different part, or pass ``hw=`` to
the entry points explicitly.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, Optional, Tuple

# Per-chip hardware profiles. ``vmem_bytes`` is the on-chip vector-memory
# budget the kernel autotuner filters tile candidates against (per-core
# VMEM on TPU; an L2-ish working-set proxy on cpu so interpret-mode runs
# exercise the same filter).
HW_PROFILES: Dict[str, Dict[str, float]] = {
    "v5e": {
        "peak_flops": 197e12,  # bf16 / chip
        "hbm_bw": 819e9,  # bytes/s / chip
        "ici_bw": 50e9,  # bytes/s / link
        "vmem_bytes": 16e6,
    },
    "v5p": {
        "peak_flops": 459e12,
        "hbm_bw": 2765e9,
        "ici_bw": 90e9,
        "vmem_bytes": 32e6,
    },
    "cpu": {
        "peak_flops": 1e12,
        "hbm_bw": 50e9,
        "ici_bw": 10e9,
        "vmem_bytes": 8e6,
    },
}

DEFAULT_HW_PROFILE = "v5e"


def hw_profile(name: Optional[str] = None) -> Dict[str, float]:
    """Resolve a hardware profile by name, falling back to the
    ``REPRO_HW_PROFILE`` env var and then the v5e default. The env var is
    read per call, so tests and the autotuner can switch profiles without
    re-importing."""
    name = name or os.environ.get("REPRO_HW_PROFILE") or DEFAULT_HW_PROFILE
    try:
        return HW_PROFILES[name]
    except KeyError:
        raise ValueError(
            f"unknown hardware profile {name!r}; expected one of "
            f"{sorted(HW_PROFILES)}"
        ) from None


# Import-compat name: consumers that read a static dict (roofline/report.py,
# benchmarks/table2_parallel.py) keep working; it honors REPRO_HW_PROFILE
# at import time. Call sites that must track the env per call use
# hw_profile() instead.
HW = hw_profile()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

def dtype_width(name: str) -> int:
    """Byte width of a config dtype string (``bfloat16`` -> 2, ``int8`` ->
    1, ...) without hardcoding a bf16 assumption anywhere downstream."""
    import jax.numpy as jnp

    return jnp.dtype(name).itemsize


def kv_entry_bytes(cfg) -> float:
    """HBM bytes per (token, kv-head) KV-cache entry, from the config.

    ``cfg.quant_kv == "int8"`` pages store an int8 head vector plus one f32
    per-token scale in the sidecar leaf; otherwise entries are
    ``cfg.dtype`` wide. Used by ``serving/kv_cache.kv_page_bytes`` and the
    bench bytes accounting so quantized dry-runs and residency numbers
    report honest bandwidth terms."""
    hd = cfg.head_dim_
    if getattr(cfg, "quant_kv", "none") == "int8":
        return hd * 1 + 4  # int8 payload + f32 scale sidecar per token-head
    return hd * dtype_width(cfg.dtype)


def weight_elem_bytes(cfg) -> float:
    """HBM bytes per expert-FFN weight element, from the config: 1 for
    int8-quantized weights (per-channel bf16 scales are amortized over the
    contraction dim — callers that know exact shapes add them explicitly,
    e.g. the kernel bench's bytes_per_row column), else the ``cfg.dtype``
    width."""
    if getattr(cfg, "quant_weights", "none") == "int8":
        return 1
    return dtype_width(cfg.dtype)


# result-shape pattern of an HLO op line: `%name = TYPE[d0,d1]{layout} op-name(`
_OP_RE = re.compile(
    r"=\s+(?:\()?((?:[a-z0-9]+\[[0-9,]*\][^ ]*(?:,\s*)?)+)(?:\))?\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"all-gather-start|all-reduce-start|collective-permute-start)\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Effective wire bytes per device, by collective kind. Multipliers:
    all-reduce 2x(N-1)/N ~ 2x, all-gather/reduce-scatter (N-1)/N ~ 1x,
    all-to-all (N-1)/N ~ 1x, collective-permute 1x. Shapes in SPMD HLO are
    already per-device shards."""
    out = {
        "all-gather": 0.0,
        "all-reduce": 0.0,
        "reduce-scatter": 0.0,
        "all-to-all": 0.0,
        "collective-permute": 0.0,
    }
    for m in _OP_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        nbytes = _shape_bytes(type_str)
        if op == "all-reduce":
            out[op] += 2.0 * nbytes
        else:
            out[op] += 1.0 * nbytes
    out["total"] = sum(out.values())
    return out


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    collective_bytes_per_device: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline lower bound: max of the three (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
        }


def roofline_terms(
    cost: Dict[str, float],
    hlo_text: str,
    chips: int,
    links_per_chip: float = 4.0,
    hw: Optional[Dict[str, float]] = None,
) -> RooflineTerms:
    """DEPRECATED builtin-cost path: XLA's cost_analysis counts while bodies
    once (wrong by ~num_layers for scan-over-layers models). Kept for
    comparison; use :func:`roofline_from_hlo`."""
    hw = hw or hw_profile()
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", cost.get("bytes accessed0{}", 0.0)))
    coll = collective_bytes(hlo_text)
    return RooflineTerms(
        compute_s=flops / hw["peak_flops"],
        memory_s=byts / hw["hbm_bw"],
        collective_s=coll["total"] / (hw["ici_bw"] * links_per_chip),
        flops=flops,
        bytes_accessed=byts,
        collective_bytes_per_device=coll["total"],
        chips=chips,
    )


def roofline_from_hlo(
    hlo_text: str, chips: int, links_per_chip: float = 4.0,
    hw: Optional[Dict[str, float]] = None,
) -> Tuple[RooflineTerms, Dict[str, float]]:
    """Trip-count-aware roofline terms (see roofline/hlo_analysis.py).
    Returns (terms, per-kind collective byte dict), all per-device."""
    from repro.roofline.hlo_analysis import analyze

    hw = hw or hw_profile()
    costs = analyze(hlo_text)
    terms = RooflineTerms(
        compute_s=costs.flops / hw["peak_flops"],
        memory_s=costs.hbm_bytes / hw["hbm_bw"],
        collective_s=costs.total_collective / (hw["ici_bw"] * links_per_chip),
        flops=costs.flops,
        bytes_accessed=costs.hbm_bytes,
        collective_bytes_per_device=costs.total_collective,
        chips=chips,
    )
    coll = dict(costs.collective_bytes)
    coll["total"] = costs.total_collective
    return terms, coll
