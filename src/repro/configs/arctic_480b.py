"""arctic-480b — 128-expert top-2 MoE with a dense residual FFN in parallel
with every MoE layer [hf:Snowflake/snowflake-arctic-base]. Already-MoE;
paper recipe applies. FSDP on (480B total)."""
from repro.config import ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        source="hf:Snowflake/snowflake-arctic-base",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        rope_theta=10000.0,
        moe=MoEConfig(num_experts=128, top_k=2, capacity_factor=2.0,
                      dense_residual=True, dispatcher="allgather"),
        fsdp=True,
        train_microbatches=8,
    )
