"""llama3.2-3b — small Llama-3 dense decoder [hf:meta-llama/Llama-3.2-3B]."""
from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        source="hf:meta-llama/Llama-3.2-1B (3B variant dims)",
        num_layers=28,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=500000.0,
        tie_embeddings=True,
        train_microbatches=2,
    )
