"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].
Attention-free; the paper's upcycling technique is INAPPLICABLE (no FFN,
d_ff=0) — documented in DESIGN.md §Arch-applicability. The architecture is
still fully supported (train/prefill/decode incl. long_500k via O(1) state)."""
from repro.config import ModelConfig, SSMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        source="arXiv:2405.21060 (Mamba-2 2.7B)",
        num_layers=64,
        d_model=2560,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        # chunk 128 (§Perf M3): SSD L-matrix traffic is linear in chunk size
        # (B*H*L*cs elements); 128 stays MXU-aligned while halving that term.
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=64, ngroups=8, chunk_size=128),
        train_microbatches=8,
    )
