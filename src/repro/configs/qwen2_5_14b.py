"""qwen2.5-14b — GQA dense decoder with QKV bias [hf:Qwen/Qwen2.5-14B]."""
from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b",
        family="dense",
        source="hf:Qwen/Qwen2.5-0.5B (14B dims)",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=13824,
        vocab_size=152064,
        rope_theta=1000000.0,
        qkv_bias=True,
        train_microbatches=2,
    )
