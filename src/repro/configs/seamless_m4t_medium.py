"""seamless-m4t-medium — speech/text encoder-decoder [arXiv:2308.11596].
The audio frontend (mel-spectrogram + conformer feature extractor) is the
documented stub: the encoder consumes precomputed frame embeddings
(B, Se, d_model). 12 encoder + 12 decoder layers; long_500k skipped
(full-attention enc-dec; see DESIGN.md)."""
from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="encdec",
        source="arXiv:2308.11596 (SeamlessM4T-medium)",
        num_layers=12,
        num_encoder_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        norm_type="layernorm",
        rope_theta=10000.0,
    )
