"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b]: LayerNorm, MHA (kv=32)."""
from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        source="hf:stabilityai/stablelm-2-1_6b",
        num_layers=24,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=5632,
        vocab_size=100352,
        norm_type="layernorm",
        rope_theta=10000.0,
        train_microbatches=2,
    )
