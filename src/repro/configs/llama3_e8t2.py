"""llama3-e8t2 — the paper's upcycled 8-Expert Top-2 MoE (§4.2): every FFN
becomes an 8-expert MoE initialized as copies of the dense FFN, Mixtral-type
router, CF=4, trained with EP8. On the production 2-D mesh the experts fall
back to expert-TP (8 does not divide 16); the paper-study 3-D mesh
('data','expert','model') gives true EP8 — see benchmarks/table2."""
from repro.config import ModelConfig, MoEConfig
from repro.configs.llama3_8b import get_config as dense_config
from repro.core.upcycle import upcycle_config


def get_config() -> ModelConfig:
    return upcycle_config(
        dense_config(),
        MoEConfig(num_experts=8, top_k=2, capacity_factor=4.0,
                  router_type="mixtral", dispatcher="alltoall"),
        name="llama3-e8t2",
    )
