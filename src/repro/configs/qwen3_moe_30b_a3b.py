"""qwen3-moe-30b-a3b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B].
Already-MoE; paper recipe (CF training, router order) applies. EP16 with 8
experts per device on the production mesh. CF=2 stands in for the released
model's dropless training (adaptation noted in DESIGN.md)."""
from repro.config import ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        source="hf:Qwen/Qwen3-30B-A3B",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        rope_theta=1000000.0,
        # CF=1 (§Perf Q4): the paper's Table-2 throughput choice — capacity
        # slots E*C = k*T exactly match the active token-assignments, halving
        # dispatch buffers and expert-GEMM slots vs CF=2 at a small quality
        # cost (paper Table 4).
        moe=MoEConfig(num_experts=128, top_k=8, capacity_factor=1.0,
                      dispatcher="allgather"),
        train_microbatches=4,
    )
