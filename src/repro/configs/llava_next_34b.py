"""llava-next-34b — VLM: Yi-34B-class dense decoder consuming an anyres
patch-embedding prefix [hf:llava-hf/llava-v1.6-34b-hf]. Vision tower +
projector are the documented stub: `embeds` (B, 2880, d_model) arrive
precomputed; 2880 = anyres max image tokens (4 tiles + base, 576 each)."""
from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="vlm",
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf (34B NH2-Yi backbone dims)",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64000,
        rope_theta=5000000.0,
        num_prefix_embeds=2880,
        train_microbatches=4,
    )
