"""minicpm3-4b — dense decoder with MLA (Multi-head Latent Attention)
[hf:openbmb/MiniCPM3-4B]. The compressed latent KV cache (kv_lora 256 +
rope 32 per token) makes long_500k decode in-scope."""
from repro.config import MLAConfig, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        source="hf:openbmb/MiniCPM3-4B",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        d_ff=6400,
        vocab_size=73448,
        rope_theta=10000.0,
        use_mla=True,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                      qk_rope_head_dim=32, v_head_dim=64),
        train_microbatches=4,
    )
