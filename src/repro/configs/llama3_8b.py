"""llama3-8b — the paper's dense base model (upcycling source)."""
from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b",
        family="dense",
        source="paper §4.2 / meta-llama/Meta-Llama-3-8B",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        rope_theta=500000.0,
    )
