"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 with MoE every other
layer, 16 experts top-2 [arXiv:2403.19887]. Already-MoE: the paper's
upcycling init is inapplicable, but its training recipe (CF, router order,
token dispatchers) and folding apply; EP16 on the 'model' axis. FSDP on —
TP/EP-sharded weights alone exceed a single chip's HBM."""
from repro.config import ModelConfig, MoEConfig, SSMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        source="arXiv:2403.19887 (Jamba-1.5-Large)",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        hybrid_pattern="MMMAMMMM",  # attention 1-of-8 (1:7)
        moe=MoEConfig(num_experts=16, top_k=2, capacity_factor=2.0,
                      moe_layer_freq=2, dispatcher="allgather"),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=64, ngroups=16,
                      chunk_size=256),
        fsdp=True,
        train_microbatches=16,
    )
