"""Explicit training state: ONE pytree carrying everything a run needs to
restart exactly — params, AdamW state, step counter, and the PRNG key the
loop splits per step. The host-side data-iterator state (a numpy
bit-generator snapshot, see ``data/pipeline.TrainIterator``) rides in the
checkpoint manifest's ``meta`` instead, since it is not a device array.

The checkpoint subsystem stores plain nested dicts; ``state_to_tree`` /
``tree_to_state`` define the stable on-disk structure::

    {"step": i32[], "rng": u32[2],
     "params": {...model params...},
     "opt": {"step": i32[], "master": {...}, "m": {...}, "v": {...}}}

``restore_train_state`` re-resolves shardings for the TARGET mesh from the
model's ParamDecls (``sharding/rules.py``), so a checkpoint saved under one
FoldingPlan (e.g. EP on the 3-D study mesh) restores onto a different one
(ETP on the production mesh) — elastic mesh reshaping.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, TrainConfig
from repro.optim.adamw import (
    AdamWState,
    adamw_init,
    opt_state_abstract,
    opt_state_shardings,
)
from repro.sharding.rules import (
    FoldingPlan,
    abstract_from_decls,
    init_from_decls,
    shardings_from_decls,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    """The recipe runtime's unit of progress; jit-carried and checkpointed."""

    step: jax.Array  # i32 scalar: optimizer updates applied == batches consumed
    params: Any  # bf16/compute params (pytree of dicts)
    opt_state: AdamWState
    rng: jax.Array  # per-run sampling key; split once per step inside the jit


def create_train_state(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    plan: Optional[FoldingPlan] = None,
    params: Optional[Any] = None,
) -> TrainState:
    """Fresh state: init (sharded when ``plan``) or adopt given ``params``."""
    from repro.models.model import model_decl

    decls = model_decl(cfg)
    key = jax.random.PRNGKey(tcfg.seed)
    if params is not None:
        # the jitted step donates its inputs; never consume the caller's
        # buffers (they may be the upcycling source checkpoint)
        params = jax.tree.map(jnp.array, params)
    elif plan is None:
        params = init_from_decls(decls, key)
    else:
        sh = shardings_from_decls(decls, plan)
        params = jax.jit(lambda k: init_from_decls(decls, k), out_shardings=sh)(key)
    if plan is None:
        opt_state = jax.jit(adamw_init)(params)
    else:
        opt_sh = opt_state_shardings(decls, plan, tcfg.zero1)
        opt_state = jax.jit(adamw_init, out_shardings=opt_sh)(params)
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=opt_state,
        rng=jax.random.PRNGKey(tcfg.seed + 1),
    )


def state_to_tree(state: TrainState) -> Dict[str, Any]:
    o = state.opt_state
    return {
        "step": state.step,
        "rng": state.rng,
        "params": state.params,
        "opt": {"step": o.step, "master": o.master, "m": o.m, "v": o.v},
    }


def tree_to_state(tree: Dict[str, Any]) -> TrainState:
    o = tree["opt"]
    return TrainState(
        step=tree["step"],
        params=tree["params"],
        opt_state=AdamWState(step=o["step"], master=o["master"], m=o["m"], v=o["v"]),
        rng=tree["rng"],
    )


def state_sharding_tree(decls, plan: Optional[FoldingPlan], zero1: bool = True):
    """Target shardings for a TrainState tree on ``plan``'s mesh (None on the
    host path — leaves then restore as plain committed arrays)."""
    if plan is None:
        return None
    rep = NamedSharding(plan.mesh, P())
    opt_sh = opt_state_shardings(decls, plan, zero1)
    return {
        "step": rep,
        "rng": rep,
        "params": shardings_from_decls(decls, plan),
        "opt": {
            "step": opt_sh.step,
            "master": opt_sh.master,
            "m": opt_sh.m,
            "v": opt_sh.v,
        },
    }


def _check_shapes(tree: Dict[str, Any], decls) -> None:
    from repro.checkpoint.sharded import flatten_tree

    abs_params = flatten_tree(
        jax.tree.map(lambda a: a.shape, abstract_from_decls(decls))
    )
    abs_opt = flatten_tree(
        jax.tree.map(lambda a: a.shape, opt_state_abstract(abstract_from_decls(decls)).master)
    )
    got_p = flatten_tree(jax.tree.map(lambda a: a.shape, tree["params"]))
    assert got_p == abs_params, (
        "checkpoint params do not match the model declaration — resuming a "
        "different config? missing/extra: "
        f"{sorted(set(got_p) ^ set(abs_params))[:8]} shape diffs: "
        f"{[k for k in got_p if k in abs_params and got_p[k] != abs_params[k]][:8]}"
    )
    got_m = flatten_tree(jax.tree.map(lambda a: a.shape, tree["opt"]["master"]))
    assert got_m == abs_opt, "checkpoint optimizer state does not match the model"


def restore_train_state(
    directory: str,
    cfg: ModelConfig,
    plan: Optional[FoldingPlan] = None,
    zero1: bool = True,
    step: Optional[int] = None,
) -> Tuple[TrainState, Dict[str, Any]]:
    """Restore the latest (or given) full-state checkpoint, resharded for the
    target ``plan``. Returns ``(state, manifest)``; the manifest's ``meta``
    carries the data-iterator snapshot and any provenance the run recorded.
    """
    from repro.checkpoint.manager import restore_tree
    from repro.models.model import model_decl

    decls = model_decl(cfg)
    target = state_sharding_tree(decls, plan, zero1)
    tree, manifest = restore_tree(directory, step=step, target=target)
    _check_shapes(tree, decls)
    return tree_to_state(tree), manifest
