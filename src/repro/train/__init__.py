from repro.train.trainer import Trainer, make_train_step  # noqa: F401
