from repro.train.callbacks import (  # noqa: F401
    Callback,
    CheckpointCallback,
    EvalCallback,
    LoggingCallback,
)
from repro.train.state import (  # noqa: F401
    TrainState,
    create_train_state,
    restore_train_state,
    state_sharding_tree,
    state_to_tree,
    tree_to_state,
)
from repro.train.trainer import Trainer, make_state_step, make_train_step  # noqa: F401
