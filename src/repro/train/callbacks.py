"""Composable recipe-loop callbacks. The ``Trainer`` loop is deliberately
tiny — fetch batch, run the jitted state step — and everything else
(logging/MFU, periodic eval, checkpointing) hangs off this interface, so the
train / dryrun / upcycle launchers share one runtime and tests can inject
instrumented callbacks.

Hook order per step: ``on_step_end(trainer, step, metrics, dt)`` with the
1-based GLOBAL step (resume-aware: a run restored at step k fires with
k+1, k+2, ...) and ``dt`` the host wall-time of that step's dispatch+wait.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np


class Callback:
    def on_run_begin(self, trainer) -> None:  # noqa: D102
        pass

    def on_step_end(self, trainer, step: int, metrics, dt: float) -> None:  # noqa: D102
        pass

    def on_run_end(self, trainer) -> None:  # noqa: D102
        pass


class LoggingCallback(Callback):
    """History records + throughput/MFU accounting.

    Step 1 of a fresh process pays jit compilation; folding it into a
    running average deflates reported steady-state throughput, so timing is
    split: ``ms_per_step_steady`` excludes the first (warmup) step of the
    run, ``wall_total_s`` is the honest end-to-end figure. ``sec_per_step``
    (kept for dashboard compat) is the steady value.
    """

    def __init__(self, log: Callable = print, log_every: int = 10):
        self.log, self.log_every = log, log_every
        self.durations: List[float] = []

    def on_run_begin(self, trainer):
        self.durations = []
        n_chips = 1 if trainer.plan is None else trainer.plan.mesh.devices.size
        tokens_per_step = trainer.tcfg.global_batch * trainer.tcfg.seq_len
        # MFU accounting: 3x = fwd + bwd (2x) model FLOPs, the paper's (and
        # Megatron's) convention. Recompute FLOPs are EXCLUDED: the Pallas
        # backward re-derives the SwiGLU gate/up projections and the flash
        # probability blocks instead of saving them, so the kernel path does
        # strictly more arithmetic than 3x — reported MFU is therefore a
        # slight *under*-estimate there, never inflated by recompute.
        self._flops_per_step = (
            3 * trainer.cfg.flops_per_token(trainer.tcfg.seq_len) * tokens_per_step
        )
        self._n_chips = n_chips

    def _steady(self) -> float:
        d = self.durations
        return float(np.mean(d[1:])) if len(d) > 1 else d[0]

    def on_step_end(self, trainer, step, metrics, dt):
        self.durations.append(dt)
        i = len(self.durations)  # run-local step index (1-based)
        if not (i == 1 or i % self.log_every == 0):
            return
        metrics = jax.device_get(metrics)
        steady = self._steady()
        rec = {
            "step": step,
            **{k: float(v) for k, v in metrics.items()},
            "sec_per_step": steady,
            "ms_per_step_steady": steady * 1e3,
            "wall_total_s": float(np.sum(self.durations)),
            "model_tflops_per_sec": self._flops_per_step / steady / 1e12 / self._n_chips,
        }
        trainer.history.append(rec)
        self.log(
            f"step {rec['step']:5d} loss {rec['loss']:.4f} ce {rec['ce']:.4f} "
            f"lr {rec['lr']:.2e} gnorm {rec['grad_norm']:.2f} "
            f"{rec['ms_per_step_steady']:.0f} ms/step (steady)"
        )


class EvalCallback(Callback):
    """Periodic held-out CE on the blend's eval stream (fresh sample_seed)."""

    def __init__(self, every: int, batches: int = 4, log: Callable = print):
        self.every, self.batches, self.log = every, batches, log

    def on_step_end(self, trainer, step, metrics, dt):
        if not self.every or step % self.every:
            return
        ce = trainer.eval_loss(batches=self.batches)
        trainer.history.append({"step": step, "eval_ce": ce})
        self.log(f"step {step:5d} eval ce {ce:.4f}")


class CheckpointCallback(Callback):
    """Full-state periodic checkpoints through the async manager.

    Captures params + optimizer + RNG + the data iterator's bit-generator
    snapshot (manifest meta), so a resumed run replays the exact batch and
    key sequence of an uninterrupted one. The save blocks the loop only for
    the host copy; file writes overlap the following steps.
    """

    def __init__(
        self,
        directory: str,
        every: int,
        keep_last: int = 3,
        async_save: bool = True,
        extra_meta: Optional[Dict] = None,
    ):
        from repro.checkpoint.manager import CheckpointManager

        self.every = every
        self.extra_meta = extra_meta or {}
        self.manager = CheckpointManager(directory, keep_last, async_save)
        self.blocked_s: List[float] = []

    def _meta(self, trainer) -> Dict:
        meta = dict(self.extra_meta)
        it = trainer.data_iter
        if it is not None and hasattr(it, "state"):
            meta["data_state"] = it.state()
        meta["wall_time"] = time.time()
        return meta

    def save_now(self, trainer, step: int, blocking: Optional[bool] = None):
        from repro.train.state import state_to_tree

        self.manager.save(
            state_to_tree(trainer.state), step, self._meta(trainer), blocking=blocking
        )
        self.blocked_s.append(self.manager.last_blocked_s)

    def on_step_end(self, trainer, step, metrics, dt):
        if self.every and step % self.every == 0:
            self.save_now(trainer, step)

    def on_run_end(self, trainer):
        self.manager.wait()
