"""Composable recipe-loop callbacks. The ``Trainer`` loop is deliberately
tiny — fetch batch, run the jitted state step — and everything else
(logging/MFU, periodic eval, checkpointing) hangs off this interface, so the
train / dryrun / upcycle launchers share one runtime and tests can inject
instrumented callbacks.

Hook order per step: ``on_step_end(trainer, step, metrics, dt)`` with the
1-based GLOBAL step (resume-aware: a run restored at step k fires with
k+1, k+2, ...) and ``dt`` the host wall-time of that step's dispatch+wait.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np


class Callback:
    def on_run_begin(self, trainer) -> None:  # noqa: D102
        pass

    def on_step_end(self, trainer, step: int, metrics, dt: float) -> None:  # noqa: D102
        pass

    def on_run_end(self, trainer) -> None:  # noqa: D102
        pass


class LoggingCallback(Callback):
    """History records + throughput/MFU accounting.

    Step 1 of a fresh process pays jit compilation; folding it into a
    running average deflates reported steady-state throughput, so timing is
    split: ``ms_per_step_steady`` excludes the first (warmup) step of the
    run, ``wall_total_s`` is the honest end-to-end figure. ``sec_per_step``
    (kept for dashboard compat) is the steady value.
    """

    def __init__(self, log: Callable = print, log_every: int = 10):
        self.log, self.log_every = log, log_every
        self.durations: List[float] = []

    def on_run_begin(self, trainer):
        self.durations = []
        n_chips = 1 if trainer.plan is None else trainer.plan.mesh.devices.size
        tokens_per_step = trainer.tcfg.global_batch * trainer.tcfg.seq_len
        # MFU accounting: 3x = fwd + bwd (2x) model FLOPs, the paper's (and
        # Megatron's) convention. Recompute FLOPs are EXCLUDED: the Pallas
        # backward re-derives the SwiGLU gate/up projections and the flash
        # probability blocks instead of saving them, so the kernel path does
        # strictly more arithmetic than 3x — reported MFU is therefore a
        # slight *under*-estimate there, never inflated by recompute.
        self._flops_per_step = (
            3 * trainer.cfg.flops_per_token(trainer.tcfg.seq_len) * tokens_per_step
        )
        self._n_chips = n_chips

    def _steady(self) -> float:
        d = self.durations
        return float(np.mean(d[1:])) if len(d) > 1 else d[0]

    def on_step_end(self, trainer, step, metrics, dt):
        self.durations.append(dt)
        i = len(self.durations)  # run-local step index (1-based)
        if not (i == 1 or i % self.log_every == 0):
            return
        metrics = jax.device_get(metrics)
        steady = self._steady()
        rec = {
            "step": step,
            **{k: float(v) for k, v in metrics.items()},
            "sec_per_step": steady,
            "ms_per_step_steady": steady * 1e3,
            "wall_total_s": float(np.sum(self.durations)),
            "model_tflops_per_sec": self._flops_per_step / steady / 1e12 / self._n_chips,
        }
        trainer.history.append(rec)
        self.log(
            f"step {rec['step']:5d} loss {rec['loss']:.4f} ce {rec['ce']:.4f} "
            f"lr {rec['lr']:.2e} gnorm {rec['grad_norm']:.2f} "
            f"{rec['ms_per_step_steady']:.0f} ms/step (steady)"
        )


class EvalCallback(Callback):
    """Periodic held-out CE on the blend's eval stream (fresh sample_seed)."""

    def __init__(self, every: int, batches: int = 4, log: Callable = print):
        self.every, self.batches, self.log = every, batches, log

    def on_step_end(self, trainer, step, metrics, dt):
        if not self.every or step % self.every:
            return
        ce = trainer.eval_loss(batches=self.batches)
        trainer.history.append({"step": step, "eval_ce": ce})
        self.log(f"step {step:5d} eval ce {ce:.4f}")


class AnomalySupervisor(Callback):
    """NaN/Inf + loss-spike supervisor over the in-jit anomaly guard.

    The guard itself lives inside the jitted step (``make_train_step``):
    it skips the optimizer update — params and optimizer state bitwise
    untouched — whenever the observed loss/grad-norm is non-finite or the
    loss exceeds ``trainer.loss_ceiling``. This callback closes the loop on
    the host side:

    * maintains an EMA + variance of the (healthy) loss and sets
      ``trainer.loss_ceiling = ema + z_threshold * std + min_spike`` once
      ``warmup_steps`` healthy steps have seeded the statistics, so a
      sudden spike trips the guard without hand-tuning a ceiling;
    * counts consecutive guarded (skipped) steps as strikes; after
      ``rollback_after`` strikes it rolls the TrainState *and* the data
      iterator back to the newest checkpoint at-or-before the last healthy
      step (checkpoints saved during the bad window are never trusted),
      falling back to older checkpoints if the newest candidate fails
      verification;
    * records every intervention (skips, rollbacks) in ``interventions``
      for the bench report, and raises
      :class:`~repro.resilience.recovery.TrainingDivergedError` when the
      strike limit hits with no restorable checkpoint — a run that cannot
      self-heal fails loudly instead of training on garbage.

    Order the supervisor AFTER the ``CheckpointCallback`` in the callback
    list so a rollback joins the manager's in-flight write first.
    """

    def __init__(
        self,
        ckpt: Optional["CheckpointCallback"] = None,
        rollback_after: int = 3,
        z_threshold: float = 6.0,
        ema_decay: float = 0.9,
        warmup_steps: int = 5,
        min_spike: float = 2.0,
        log: Callable = print,
    ):
        self.ckpt = ckpt
        self.rollback_after = rollback_after
        self.z_threshold = z_threshold
        self.ema_decay = ema_decay
        self.warmup_steps = warmup_steps
        self.min_spike = min_spike
        self.log = log
        self.strikes = 0
        self.skips = 0
        self.rollbacks = 0
        self.interventions: List[Dict] = []
        self._ema = 0.0
        self._var = 0.0
        self._healthy = 0
        self.last_good_step = 0

    def on_run_begin(self, trainer):
        self.strikes = 0
        self.last_good_step = int(jax.device_get(trainer.state.step))

    def _ceiling(self) -> float:
        if self._healthy < self.warmup_steps:
            return float("inf")
        return self._ema + self.z_threshold * float(np.sqrt(self._var)) + self.min_spike

    def on_step_end(self, trainer, step, metrics, dt):
        loss = float(jax.device_get(metrics["loss"]))
        skipped = bool(jax.device_get(metrics.get("skipped", 0.0)))
        if not skipped:
            self.strikes = 0
            self.last_good_step = step
            d = self.ema_decay if self._healthy else 0.0
            delta = loss - self._ema
            self._ema += (1.0 - d) * delta
            self._var = d * (self._var + (1.0 - d) * delta * delta)
            self._healthy += 1
            trainer.loss_ceiling = self._ceiling()
            return
        self.strikes += 1
        self.skips += 1
        self.interventions.append(
            {"step": step, "kind": "skip", "loss": loss, "strikes": self.strikes}
        )
        self.log(
            f"step {step:5d} ANOMALY loss {loss:.4g} > ceiling "
            f"{trainer.loss_ceiling:.4g} (or non-finite) — update skipped "
            f"[strike {self.strikes}/{self.rollback_after}]"
        )
        if self.strikes >= self.rollback_after:
            self._rollback(trainer, step)

    def _rollback(self, trainer, step: int):
        from repro.checkpoint.manager import list_steps
        from repro.resilience.recovery import (
            CheckpointCorruptionError,
            TrainingDivergedError,
        )
        from repro.train.state import restore_train_state

        if self.ckpt is None:
            raise TrainingDivergedError(
                f"{self.strikes} consecutive anomalous steps at step {step} "
                "and no CheckpointCallback to roll back through"
            )
        mgr = self.ckpt.manager
        mgr.wait()
        candidates = [
            s for s in list_steps(mgr.directory) if s <= self.last_good_step
        ]
        for s in reversed(candidates):
            try:
                state, manifest = restore_train_state(
                    mgr.directory, trainer.cfg, trainer.plan,
                    trainer.tcfg.zero1, step=s,
                )
            except CheckpointCorruptionError:
                continue
            trainer.state = state
            data_state = (manifest.get("meta") or {}).get("data_state")
            if data_state is not None and hasattr(trainer.data_iter, "restore"):
                trainer.data_iter.restore(data_state)
            self.strikes = 0
            self.rollbacks += 1
            self.interventions.append(
                {"step": step, "kind": "rollback", "to": s}
            )
            self.log(f"step {step:5d} ROLLBACK -> checkpoint step {s}")
            return
        raise TrainingDivergedError(
            f"{self.rollback_after} consecutive anomalous steps at step "
            f"{step} and no verified checkpoint at-or-before last good step "
            f"{self.last_good_step} under {mgr.directory}"
        )

    def summary(self) -> Dict:
        return {
            "skipped_updates": self.skips,
            "rollbacks": self.rollbacks,
            "interventions": self.interventions,
            "loss_ceiling": self._ceiling(),
        }


class CheckpointCallback(Callback):
    """Full-state periodic checkpoints through the async manager.

    Captures params + optimizer + RNG + the data iterator's bit-generator
    snapshot (manifest meta), so a resumed run replays the exact batch and
    key sequence of an uninterrupted one. The save blocks the loop only for
    the host copy; file writes overlap the following steps.
    """

    def __init__(
        self,
        directory: str,
        every: int,
        keep_last: int = 3,
        async_save: bool = True,
        extra_meta: Optional[Dict] = None,
    ):
        from repro.checkpoint.manager import CheckpointManager

        self.every = every
        self.extra_meta = extra_meta or {}
        self.manager = CheckpointManager(directory, keep_last, async_save)
        self.blocked_s: List[float] = []

    def _meta(self, trainer) -> Dict:
        meta = dict(self.extra_meta)
        it = trainer.data_iter
        if it is not None and hasattr(it, "state"):
            meta["data_state"] = it.state()
        meta["wall_time"] = time.time()
        return meta

    def save_now(self, trainer, step: int, blocking: Optional[bool] = None):
        from repro.train.state import state_to_tree

        self.manager.save(
            state_to_tree(trainer.state), step, self._meta(trainer), blocking=blocking
        )
        self.blocked_s.append(self.manager.last_blocked_s)

    def on_step_end(self, trainer, step, metrics, dt):
        if self.every and step % self.every == 0:
            self.save_now(trainer, step)

    def on_run_end(self, trainer):
        self.manager.wait()
