"""Training loop: jitted step (loss + grad + clip + AdamW/ZeRO-1 + schedule),
metrics, MFU accounting, periodic checkpointing.

The same ``make_train_step`` is what the multi-pod dry-run lowers — there is
no separate "dry-run model"; the production step function is the artifact
being compiled and analyzed.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig, with_dispatcher
from repro.models.model import loss_fn, model_decl
from repro.optim.adamw import AdamWState, adamw_init, adamw_update, opt_state_shardings
from repro.optim.schedule import cosine_schedule
from repro.sharding.rules import (
    FoldingPlan,
    init_from_decls,
    shardings_from_decls,
)


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    plan: Optional[FoldingPlan],
    use_kernel: bool = False,
    microbatches: Optional[int] = None,
):
    """Returns step(params, opt_state, batch, rng) -> (params, opt_state, metrics).

    With ``microbatches=m > 1`` the global batch is split into m sequential
    microbatches (lax.scan) whose fp32-accumulated grads feed ONE optimizer
    update — Megatron-style gradient accumulation, bounding per-microbatch
    activation memory to 1/m (§Perf M4)."""
    m = microbatches if microbatches is not None else cfg.train_microbatches

    def grad_of(params, batch, rng):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, plan, p, batch, rng, use_kernel), has_aux=True
        )(params)

    def step(params, opt_state: AdamWState, batch, rng):
        B = jax.tree.leaves(batch)[0].shape[0]
        # clamp to a divisor of the actual batch (smoke tests use tiny B)
        m_eff = max(1, min(m, B))
        while B % m_eff:
            m_eff -= 1
        if m_eff > 1:
            mb_batch = jax.tree.map(
                lambda x: x.reshape((m_eff, x.shape[0] // m_eff) + x.shape[1:]), batch
            )
            keys = jax.random.split(rng, m_eff)

            def body(acc, xs):
                g_acc, met_acc = acc
                mb, key = xs
                (_, met), g = grad_of(params, mb, key)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g
                )
                met_acc = jax.tree.map(lambda a, v: a + v, met_acc, met)
                return (g_acc, met_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            met0 = {
                k: jnp.zeros((), jnp.float32)
                for k in ("loss", "ce", "load_balance_loss", "z_loss")
            }
            (g_acc, met_acc), _ = jax.lax.scan(body, (g0, met0), (mb_batch, keys))
            grads = jax.tree.map(lambda g: g / m_eff, g_acc)
            metrics = jax.tree.map(lambda v: v / m_eff, met_acc)
        else:
            (_, metrics), grads = grad_of(params, batch, rng)
        lr = cosine_schedule(
            opt_state.step, tcfg.lr, tcfg.lr_min, tcfg.warmup_steps, tcfg.total_steps
        )
        new_params, new_opt = adamw_update(tcfg, grads, opt_state, lr)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        metrics = {**metrics, "lr": lr, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return step


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        plan: Optional[FoldingPlan] = None,
        params: Optional[Any] = None,
        data_iter: Optional[Iterator[Dict[str, np.ndarray]]] = None,
        use_kernel: bool = False,
        dispatcher: Optional[str] = None,
    ):
        cfg = with_dispatcher(cfg, dispatcher)
        self.cfg, self.tcfg, self.plan = cfg, tcfg, plan
        decls = model_decl(cfg)
        rng = jax.random.PRNGKey(tcfg.seed)
        if params is not None:
            # the jitted step donates its inputs; never consume the caller's
            # buffers (they may be the upcycling source checkpoint)
            params = jax.tree.map(jnp.array, params)
        if params is None:
            if plan is None:
                params = init_from_decls(decls, rng)
            else:
                sh = shardings_from_decls(decls, plan)
                params = jax.jit(
                    lambda k: init_from_decls(decls, k), out_shardings=sh
                )(rng)
        self.params = params
        if plan is None:
            self.opt_state = jax.jit(adamw_init)(params)
        else:
            opt_sh = opt_state_shardings(decls, plan, tcfg.zero1)
            self.opt_state = jax.jit(adamw_init, out_shardings=opt_sh)(params)
        step = make_train_step(cfg, tcfg, plan, use_kernel)
        self.step_fn = jax.jit(step, donate_argnums=(0, 1))
        self.data_iter = data_iter
        self.rng = jax.random.PRNGKey(tcfg.seed + 1)
        self.history: list = []

    def run(self, steps: int, log=print) -> Dict[str, list]:
        assert self.data_iter is not None
        n_chips = 1 if self.plan is None else self.plan.mesh.devices.size
        tokens_per_step = self.tcfg.global_batch * self.tcfg.seq_len
        # MFU accounting: 3x = fwd + bwd (2x) model FLOPs, the paper's (and
        # Megatron's) convention. Recompute FLOPs are EXCLUDED: the Pallas
        # backward re-derives the SwiGLU gate/up projections and the flash
        # probability blocks instead of saving them, so the kernel path does
        # strictly more arithmetic than 3x — reported MFU is therefore a
        # slight *under*-estimate there, never inflated by recompute.
        flops_per_step = 3 * self.cfg.flops_per_token(self.tcfg.seq_len) * tokens_per_step
        t0 = time.perf_counter()
        for i in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(self.data_iter).items()}
            self.rng, sk = jax.random.split(self.rng)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch, sk
            )
            if (i + 1) % self.tcfg.log_every == 0 or i == 0:
                metrics = jax.device_get(metrics)
                dt = (time.perf_counter() - t0) / (i + 1)
                rec = {
                    "step": i + 1,
                    **{k: float(v) for k, v in metrics.items()},
                    "sec_per_step": dt,
                    "model_tflops_per_sec": flops_per_step / dt / 1e12 / n_chips,
                }
                self.history.append(rec)
                log(
                    f"step {rec['step']:5d} loss {rec['loss']:.4f} ce {rec['ce']:.4f} "
                    f"lr {rec['lr']:.2e} gnorm {rec['grad_norm']:.2f} {dt*1e3:.0f} ms/step"
                )
            if self.tcfg.ckpt_every and (i + 1) % self.tcfg.ckpt_every == 0:
                from repro.checkpoint.ckpt import save_checkpoint

                save_checkpoint(self.tcfg.ckpt_dir, self.params, step=i + 1)
        return {"history": self.history}

    def eval_loss(self, batches: int = 8, seed: int = 999, data_seed: Optional[int] = None) -> float:
        """Held-out loss: SAME blend/language (data_seed, default the train
        seed) but a fresh sampling stream (seed)."""
        from repro.data.pipeline import make_train_iter

        extra = None
        if self.cfg.family == "vlm":
            extra = {
                "embeds": (self.tcfg.global_batch, self.cfg.num_prefix_embeds, self.cfg.d_model)
            }
        if self.cfg.family == "encdec":
            extra = {"frames": (self.tcfg.global_batch, self.tcfg.seq_len, self.cfg.d_model)}
        it = make_train_iter(
            self.cfg.vocab_size, self.tcfg.seq_len, self.tcfg.global_batch,
            self.tcfg.blend_ratio,
            data_seed if data_seed is not None else self.tcfg.seed,
            extra, sample_seed=seed,
        )
        fn = jax.jit(lambda p, b: loss_fn(self.cfg, self.plan, p, b)[1]["ce"])
        losses = []
        for _ in range(batches):
            b = {k: jnp.asarray(v) for k, v in next(it).items()}
            losses.append(float(fn(self.params, b)))
        return float(np.mean(losses))
