"""Training recipe loop: jitted TrainState step (loss + grad + clip +
AdamW/ZeRO-1 + schedule) driven by a tiny loop with composable callbacks
(logging/MFU, periodic eval, full-state async checkpointing — see
``train/callbacks.py``). The state itself (params, optimizer, step, RNG) is
the explicit :class:`repro.train.state.TrainState` pytree, so checkpointing
and exact resume are properties of the state, not of this loop.

The same ``make_train_step`` is what the multi-pod dry-run lowers — there is
no separate "dry-run model"; the production step function is the artifact
being compiled and analyzed.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig, with_dispatcher
from repro.models.model import loss_fn
from repro.optim.adamw import AdamWState, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.resilience import faults
from repro.resilience.recovery import HangError
from repro.sharding.rules import FoldingPlan
from repro.train.callbacks import Callback, CheckpointCallback, LoggingCallback
from repro.train.state import TrainState, create_train_state


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    plan: Optional[FoldingPlan],
    use_kernel: bool = False,
    microbatches: Optional[int] = None,
):
    """Returns step(params, opt_state, batch, rng, guard=None)
    -> (params, opt_state, metrics).

    With ``microbatches=m > 1`` the global batch is split into m sequential
    microbatches (lax.scan) whose fp32-accumulated grads feed ONE optimizer
    update — Megatron-style gradient accumulation, bounding per-microbatch
    activation memory to 1/m (§Perf M4).

    ``guard`` (optional dict of f32 scalars, traced — changing values never
    retraces) arms the in-jit anomaly guard: grads are scaled by
    ``grad_scale`` and the observed loss shifted by ``loss_shift`` (both
    identity by default; the fault harness uses them to inject NaN grads /
    loss spikes *inside* the jit), then the step is SKIPPED — params and
    optimizer state (including ``opt.step``) selected back to their inputs
    via ``jnp.where`` — when the observed loss or grad norm is non-finite
    or the loss exceeds ``loss_ceiling``. A skipped step is bitwise clean:
    no partially-applied update can leak. ``metrics["skipped"]`` reports
    the verdict; ``metrics["loss"]`` reports the *observed* (shifted) loss
    so the supervisor sees what tripped the guard."""
    m = microbatches if microbatches is not None else cfg.train_microbatches

    def grad_of(params, batch, rng):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, plan, p, batch, rng, use_kernel), has_aux=True
        )(params)

    def step(params, opt_state: AdamWState, batch, rng, guard=None):
        B = jax.tree.leaves(batch)[0].shape[0]
        # clamp to a divisor of the actual batch (smoke tests use tiny B)
        m_eff = max(1, min(m, B))
        while B % m_eff:
            m_eff -= 1
        if m_eff > 1:
            mb_batch = jax.tree.map(
                lambda x: x.reshape((m_eff, x.shape[0] // m_eff) + x.shape[1:]), batch
            )
            keys = jax.random.split(rng, m_eff)

            def body(acc, xs):
                g_acc, met_acc = acc
                mb, key = xs
                (_, met), g = grad_of(params, mb, key)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g
                )
                met_acc = jax.tree.map(lambda a, v: a + v, met_acc, met)
                return (g_acc, met_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            met0 = {
                k: jnp.zeros((), jnp.float32)
                for k in ("loss", "ce", "load_balance_loss", "z_loss")
            }
            (g_acc, met_acc), _ = jax.lax.scan(body, (g0, met0), (mb_batch, keys))
            grads = jax.tree.map(lambda g: g / m_eff, g_acc)
            metrics = jax.tree.map(lambda v: v / m_eff, met_acc)
        else:
            (_, metrics), grads = grad_of(params, batch, rng)
        if guard is not None:
            grads = jax.tree.map(
                lambda g: g * guard["grad_scale"].astype(g.dtype), grads
            )
        lr = cosine_schedule(
            opt_state.step, tcfg.lr, tcfg.lr_min, tcfg.warmup_steps, tcfg.total_steps
        )
        new_params, new_opt = adamw_update(tcfg, grads, opt_state, lr)
        # adamw_update types new params from the grads; microbatch-accumulated
        # grads are fp32, so pin the compute dtype back to the params' (no-op
        # when m_eff == 1) — otherwise step 2 retraces with fp32 params
        new_params = jax.tree.map(
            lambda n, p: n.astype(p.dtype), new_params, params
        )
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        metrics = {**metrics, "lr": lr, "grad_norm": gnorm}
        if guard is not None:
            loss_obs = metrics["loss"] + guard["loss_shift"]
            bad = (
                ~jnp.isfinite(loss_obs)
                | ~jnp.isfinite(gnorm)
                | (loss_obs > guard["loss_ceiling"])
            )
            new_params = jax.tree.map(
                lambda old, new: jnp.where(bad, old, new), params, new_params
            )
            new_opt = jax.tree.map(
                lambda old, new: jnp.where(bad, old, new), opt_state, new_opt
            )
            metrics = {
                **metrics, "loss": loss_obs, "skipped": bad.astype(jnp.float32)
            }
        return new_params, new_opt, metrics

    return step


def make_state_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    plan: Optional[FoldingPlan],
    use_kernel: bool = False,
    microbatches: Optional[int] = None,
):
    """TrainState-level step: ``step(state, batch, guard=None) -> (state, metrics)``.

    The per-step PRNG split happens INSIDE the jit from ``state.rng``, so
    the key sequence is a pure function of the checkpointed state — exact
    resume needs no host-side RNG bookkeeping. ``state.step`` counts batches
    consumed and always advances (as does the RNG); a guard-skipped step
    leaves only the *optimizer* clock (``opt_state.step``) untouched."""
    inner = make_train_step(cfg, tcfg, plan, use_kernel, microbatches)

    def step(state: TrainState, batch, guard=None):
        rng, sk = jax.random.split(state.rng)
        params, opt_state, metrics = inner(
            state.params, state.opt_state, batch, sk, guard
        )
        return TrainState(state.step + 1, params, opt_state, rng), metrics

    return step


class Trainer:
    """Recipe runtime: owns a TrainState + the jitted state step, and runs
    the loop under composable callbacks. Construct fresh (``params=None`` or
    a params pytree) or from a restored ``state=``
    (:func:`repro.train.state.restore_train_state`)."""

    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        plan: Optional[FoldingPlan] = None,
        params: Optional[Any] = None,
        data_iter: Optional[Iterator[Dict[str, np.ndarray]]] = None,
        use_kernel: bool = False,
        dispatcher: Optional[str] = None,
        state: Optional[TrainState] = None,
        callbacks: Optional[Sequence[Callback]] = None,
        step_timeout_s: Optional[float] = None,
    ):
        cfg = with_dispatcher(cfg, dispatcher)
        self.cfg, self.tcfg, self.plan = cfg, tcfg, plan
        if state is None:
            state = create_train_state(cfg, tcfg, plan, params=params)
        self.state = state
        self.step_fn = jax.jit(
            make_state_step(cfg, tcfg, plan, use_kernel), donate_argnums=(0,)
        )
        self.data_iter = data_iter
        self.callbacks = list(callbacks) if callbacks is not None else None
        self.history: list = []
        # anomaly-guard knobs: the loop always passes a guard dict (scalar
        # values — no retrace when the supervisor tightens the ceiling) and
        # an optional hung-step watchdog (None = disabled)
        self.loss_ceiling = float("inf")
        self.step_timeout_s = step_timeout_s

    # seed-era attribute access (tests, examples, benchmarks read these)
    @property
    def params(self):
        return self.state.params

    @params.setter
    def params(self, value):
        import dataclasses

        self.state = dataclasses.replace(self.state, params=value)

    @property
    def opt_state(self) -> AdamWState:
        return self.state.opt_state

    @property
    def rng(self):
        return self.state.rng

    def default_callbacks(self, log=print) -> List[Callback]:
        cbs: List[Callback] = [LoggingCallback(log=log, log_every=self.tcfg.log_every)]
        if self.tcfg.ckpt_every:
            cbs.append(
                CheckpointCallback(self.tcfg.ckpt_dir, every=self.tcfg.ckpt_every)
            )
        return cbs

    def run(
        self,
        steps: int,
        log=print,
        callbacks: Optional[Sequence[Callback]] = None,
    ) -> Dict[str, list]:
        """Run ``steps`` more steps. Global step numbering is read back from
        ``state.step`` each step (resume-aware, and a supervisor rollback
        rewinds it naturally); metrics/timing/checkpoints are the callbacks'
        business. Each step runs under the in-jit anomaly guard (see
        :func:`make_train_step`): the ``train.step`` fault site can inject
        NaN grads / loss spikes / a hang, and ``step_timeout_s`` (if set)
        raises :class:`HangError` when one step exceeds its wall budget."""
        assert self.data_iter is not None
        cbs = list(callbacks) if callbacks is not None else self.callbacks
        if cbs is None:
            cbs = self.default_callbacks(log)
        for cb in cbs:
            cb.on_run_begin(self)
        for _ in range(steps):
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in next(self.data_iter).items()}
            guard = {
                "loss_ceiling": jnp.float32(self.loss_ceiling),
                "grad_scale": jnp.float32(1.0),
                "loss_shift": jnp.float32(0.0),
            }
            for spec in faults.fire("train.step"):
                if spec.kind == "nan_grads":
                    guard["grad_scale"] = jnp.float32(float("nan"))
                elif spec.kind == "loss_spike":
                    guard["loss_shift"] = jnp.float32(spec.args.get("shift", 1e4))
                elif spec.kind == "hang":
                    time.sleep(
                        spec.args.get(
                            "seconds", 2.0 * (self.step_timeout_s or 0.05)
                        )
                    )
            self.state, metrics = self.step_fn(self.state, batch, guard)
            # sync on the (tiny) metrics so per-step wall times are honest;
            # the big state buffers stay on device and the checkpoint
            # writer thread still overlaps subsequent steps
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if self.step_timeout_s is not None and dt > self.step_timeout_s:
                raise HangError(
                    f"train step exceeded its {self.step_timeout_s:.3f}s wall "
                    f"budget ({dt:.3f}s) — hung collective or wedged host"
                )
            step_no = int(jax.device_get(self.state.step))
            for cb in cbs:
                cb.on_step_end(self, step_no, metrics, dt)
        for cb in cbs:
            cb.on_run_end(self)
        return {"history": self.history}

    def eval_loss(self, batches: int = 8, seed: int = 999, data_seed: Optional[int] = None) -> float:
        """Held-out loss: SAME blend/language (data_seed, default the train
        seed) but a fresh sampling stream (seed)."""
        from repro.data.pipeline import make_train_iter

        extra = None
        if self.cfg.family == "vlm":
            extra = {
                "embeds": (self.tcfg.global_batch, self.cfg.num_prefix_embeds, self.cfg.d_model)
            }
        if self.cfg.family == "encdec":
            extra = {"frames": (self.tcfg.global_batch, self.tcfg.seq_len, self.cfg.d_model)}
        it = make_train_iter(
            self.cfg.vocab_size, self.tcfg.seq_len, self.tcfg.global_batch,
            self.tcfg.blend_ratio,
            data_seed if data_seed is not None else self.tcfg.seed,
            extra, sample_seed=seed,
        )
        fn = jax.jit(lambda p, b: loss_fn(self.cfg, self.plan, p, b)[1]["ce"])
        losses = []
        for _ in range(batches):
            b = {k: jnp.asarray(v) for k, v in next(it).items()}
            losses.append(float(fn(self.params, b)))
        return float(np.mean(losses))
