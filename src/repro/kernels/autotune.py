"""Roofline-driven Pallas tile autotuner with a persistent cache.

The static ``_pick`` heuristics in ``expert_gemm`` / ``flash_attention`` /
``paged_attention`` choose one tile size per dimension from a fixed default.
That is robust but leaves performance on the table when the problem shape
makes a different lane split cheaper (e.g. small-D experts where a wider F
tile amortizes weight re-reads, or short KV pages where a sub-page block
fits VMEM better). This module searches the candidate tile space per
problem key and scores each candidate with the ``roofline/analysis.py``
hardware model:

* **measured** scoring: where a caller can provide a ``measure(blocks)``
  wall-time callable (a real accelerator backend), the tuner uses median
  wall time directly;
* **modeled** scoring (the default, and the only option on CPU/interpret
  runs): per-candidate HBM bytes and FLOPs from an analytic traffic model
  of the kernel's grid, turned into seconds via the active
  :func:`repro.roofline.analysis.hw_profile` (``max(flops/peak,
  bytes/bw)`` plus a per-grid-step launch overhead), with candidates whose
  working set exceeds the profile's VMEM budget filtered out.

Winners persist in a versioned JSON cache so tuning cost is paid once per
machine: ``~/.cache/repro_autotune.json`` (override with
``REPRO_AUTOTUNE_CACHE``), seeded from the repo-committed
``autotune_defaults.json`` next to this file. Cache entries whose version
does not match :data:`CACHE_VERSION` are discarded; every winner — fresh or
cached — is re-validated for lane alignment (last-dim tiles must divide the
dim into multiple-of-128 lanes, sublane tiles multiple-of-8) and dropped if
a stale/poisoned entry fails, falling back to a fresh search.

Tuning is **opt-in**: resolution order is ``--autotune`` CLI flag ->
``REPRO_AUTOTUNE=1`` env -> off. When off, :func:`get_blocks` returns the
caller's fallback (the existing static heuristic) untouched, so default
behavior is byte-identical to the pre-autotuner code path. ``_pick`` also
remains the in-kernel fallback on any cache miss with tuning disabled.

The module is importable without jax (scoring is pure arithmetic); only
the alignment validator lazily imports ``_pick``'s host module.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

CACHE_VERSION = 1

# Per-grid-step launch/bookkeeping overhead (seconds) in the modeled score:
# keeps the model from preferring degenerate many-tiny-tile grids that the
# pure bandwidth term would rate as free.
STEP_OVERHEAD_S = 5e-7

# Candidate tile sizes per tunable dim. Lane dims (last axis) must split
# into multiples of 128; sublane dims (rows, sequence, page tokens) into
# multiples of 8 — the small end exists for sub-page KV tiles.
LANE_CANDIDATES = (128, 256, 512, 1024)
SUBLANE_CANDIDATES = (8, 16, 32, 64, 128, 256, 512)

_stats = {"hits": 0, "misses": 0}
_memo: Dict[Tuple[str, str], Tuple[int, ...]] = {}
_cache_loaded: Optional[dict] = None


def reset() -> None:
    """Test hook: drop the in-memory memo/cache and zero the hit counters
    (the on-disk cache file is left alone)."""
    global _cache_loaded
    _memo.clear()
    _cache_loaded = None
    _stats["hits"] = 0
    _stats["misses"] = 0


def stats() -> Dict[str, int]:
    return dict(_stats)


def enabled() -> bool:
    """Autotuning is opt-in: off unless ``REPRO_AUTOTUNE`` is a truthy env
    value (the ``--autotune`` CLI flags set it). Read per call."""
    return os.environ.get("REPRO_AUTOTUNE", "").lower() in ("1", "true", "on")


def cache_path() -> str:
    p = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if p:
        return p
    return os.path.join(
        os.path.expanduser(os.environ.get("XDG_CACHE_HOME", "~/.cache")),
        "repro_autotune.json",
    )


def _defaults_path() -> str:
    return os.path.join(os.path.dirname(__file__), "autotune_defaults.json")


def make_key(
    kernel: str,
    *,
    E: int = 0,
    k: int = 0,
    D: int = 0,
    F: int = 0,
    page_size: int = 0,
    itemsize: int = 2,
    extra: str = "",
) -> str:
    """Canonical cache key: one winner per (kernel, problem dims, element
    width). ``extra`` carries kernel-specific dims (e.g. flash-attention
    sequence lengths)."""
    key = f"{kernel}|E{E}|k{k}|D{D}|F{F}|ps{page_size}|it{itemsize}"
    return f"{key}|{extra}" if extra else key


# ---------------------------------------------------------------------------
# Cache I/O
# ---------------------------------------------------------------------------


def _load_file(path: str) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(data, dict) or data.get("version") != CACHE_VERSION:
        return {}  # version mismatch -> invalidate wholesale
    profiles = data.get("profiles")
    return profiles if isinstance(profiles, dict) else {}


def _load_cache() -> dict:
    """Merged profiles dict {profile: {key: entry}}: the user cache wins
    over the repo-committed defaults."""
    global _cache_loaded
    if _cache_loaded is None:
        merged: dict = {}
        for path in (_defaults_path(), cache_path()):
            for prof, entries in _load_file(path).items():
                merged.setdefault(prof, {}).update(entries)
        _cache_loaded = merged
    return _cache_loaded


def _persist(profile: str, key: str, entry: dict) -> None:
    """Atomic read-modify-write of the user cache (tmp file + rename).
    Best-effort: an unwritable cache dir degrades to in-memory tuning."""
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        on_disk = {}
        try:
            with open(path) as f:
                data = json.load(f)
            if isinstance(data, dict) and data.get("version") == CACHE_VERSION:
                on_disk = data.get("profiles", {})
        except (OSError, ValueError):
            pass
        on_disk.setdefault(profile, {})[key] = entry
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
        with os.fdopen(fd, "w") as f:
            json.dump({"version": CACHE_VERSION, "profiles": on_disk}, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass


# ---------------------------------------------------------------------------
# Candidate generation + validation
# ---------------------------------------------------------------------------


def _legal_split(block: int, dim: int, align: int) -> bool:
    """``_pick``'s legality contract: the tile must divide the dim; lane
    dims (align >= 128) additionally require a multiple-of-128 tile unless
    the tile spans the whole (compiler-padded) dim; sublane dims accept any
    divisor (the compiler pads sublanes)."""
    if block <= 0 or block > dim or dim % block:
        return False
    if align >= 128:
        return block % align == 0 or block == dim
    return True


def validate_blocks(
    blocks: Sequence[int], dims: Sequence[int], aligns: Sequence[int]
) -> bool:
    """Lane-alignment check applied to *every* winner before use — fresh
    search results are asserted, cached entries failing it are treated as
    poisoned and dropped (version skew, hand-edited cache, different
    alignment rules)."""
    if len(blocks) != len(dims):
        return False
    for b, d, a in zip(blocks, dims, aligns):
        if not isinstance(b, int):
            return False
        if not _legal_split(b, d, a):
            return False
    return True


def candidates(
    dims: Sequence[int], aligns: Sequence[int],
    fixed: Sequence[Optional[int]] = (),
) -> Iterable[Tuple[int, ...]]:
    """Cartesian product of legal tile candidates per dim (pool entries
    preferring align-multiples; a whole-dim tile is always offered).
    ``fixed`` pins a dim to a single structural value (e.g. the sorted
    dispatcher's row_block, which is part of the buffer layout and not
    tunable)."""
    fixed = tuple(fixed) + (None,) * (len(dims) - len(fixed))
    per_dim = []
    for d, a, fx in zip(dims, aligns, fixed):
        if fx is not None:
            per_dim.append([fx])
            continue
        pool = LANE_CANDIDATES if a >= 128 else SUBLANE_CANDIDATES
        opts = {min(c, d) for c in pool}
        opts.add(d)  # whole-dim tile: always legal
        per_dim.append(sorted(o for o in opts if _legal_split(o, d, a)))
    out = [()]
    for opts in per_dim:
        out = [prev + (o,) for prev in out for o in opts]
    return out


# ---------------------------------------------------------------------------
# Scoring
# ---------------------------------------------------------------------------


def modeled_seconds(
    flops: float, bytes_hbm: float, steps: float, hw: Optional[dict] = None
) -> float:
    """Roofline score of one candidate: compute/memory lower bound plus a
    per-grid-step overhead term."""
    if hw is None:
        from repro.roofline.analysis import hw_profile

        hw = hw_profile()
    return max(flops / hw["peak_flops"], bytes_hbm / hw["hbm_bw"]) + steps * STEP_OVERHEAD_S


def _vmem_ok(vmem_bytes: float, hw: dict) -> bool:
    return vmem_bytes <= 0.7 * hw["vmem_bytes"]


def search(
    cands: Iterable[Tuple[int, ...]],
    cost: Callable[[Tuple[int, ...]], Dict[str, float]],
    measure: Optional[Callable[[Tuple[int, ...]], float]] = None,
    hw: Optional[dict] = None,
) -> Tuple[Tuple[int, ...], float, str]:
    """Pick the best candidate. ``cost(blocks)`` returns the analytic
    ``{"flops", "bytes", "steps", "vmem_bytes"}`` model of the kernel at
    that tiling; ``measure(blocks)`` (optional) returns measured wall
    seconds and takes precedence. Deterministic: ties break toward the
    lexicographically-smallest block tuple. Returns
    (blocks, score_s, source)."""
    if hw is None:
        from repro.roofline.analysis import hw_profile

        hw = hw_profile()
    best: Optional[Tuple[float, Tuple[int, ...]]] = None
    source = "measured" if measure is not None else "modeled"
    for blocks in sorted(cands):
        c = cost(blocks)
        if not _vmem_ok(c.get("vmem_bytes", 0.0), hw):
            continue
        if measure is not None:
            s = measure(blocks)
        else:
            s = modeled_seconds(c["flops"], c["bytes"], c.get("steps", 0.0), hw)
        if best is None or s < best[0]:
            best = (s, blocks)
    if best is None:
        raise ValueError("no candidate fits the VMEM budget")
    return best[1], best[0], source


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------


def get_blocks(
    kernel: str,
    key: str,
    fallback: Tuple[int, ...],
    dims: Sequence[int],
    aligns: Sequence[int],
    cost: Callable[[Tuple[int, ...]], Dict[str, float]],
    fixed: Sequence[Optional[int]] = (),
    measure: Optional[Callable[[Tuple[int, ...]], float]] = None,
) -> Tuple[int, ...]:
    """Resolve the tile config for one kernel call site.

    With tuning disabled (the default) this returns ``fallback`` — the
    static ``_pick`` heuristic's choice — unchanged. With tuning enabled it
    consults the in-memory memo, then the persistent cache (validating
    lane alignment and dropping poisoned entries), then runs the candidate
    search, persists the winner, and returns it. Shapes-only: safe to call
    under ``jit`` tracing since every input is static.
    """
    if not enabled():
        return tuple(fallback)
    from repro.roofline.analysis import hw_profile

    profile = os.environ.get("REPRO_HW_PROFILE") or "v5e"
    memo_key = (profile, key)
    if memo_key in _memo:
        _stats["hits"] += 1
        return _memo[memo_key]

    cached = _load_cache().get(profile, {}).get(key)
    if cached is not None:
        blocks = tuple(cached.get("blocks", ()))
        if validate_blocks(blocks, dims, aligns):
            _stats["hits"] += 1
            _memo[memo_key] = blocks
            return blocks
        # poisoned/stale entry: fall through to a fresh search

    _stats["misses"] += 1
    hw = hw_profile(profile)
    try:
        blocks, score, source = search(
            candidates(dims, aligns, fixed), cost, measure=measure, hw=hw
        )
    except ValueError:
        return tuple(fallback)
    assert validate_blocks(blocks, dims, aligns), (kernel, key, blocks)
    _memo[memo_key] = blocks
    _persist(profile, key, {
        "v": CACHE_VERSION,
        "blocks": list(blocks),
        "score_s": score,
        "source": source,
        "kernel": kernel,
    })
    return blocks
