"""jit'd public wrappers for the Pallas kernels.

On non-TPU backends (this container) the kernels execute in interpret mode
— the kernel body runs in Python on CPU for correctness validation; on TPU
they compile to Mosaic. The dispatch subsystem (``core/dispatch``) calls
``expert_gemm`` (padded layout) or ``grouped_gemm`` (sorted layout) when
``use_kernel=True``; ``models/attention.py`` calls ``flash_attention`` in
place of the blockwise XLA path. All three are differentiable
(``jax.custom_vjp`` with hand-written backward Pallas kernels and
activation recompute — see kernels/expert_gemm.py, kernels/
flash_attention.py), so ``Trainer(use_kernel=True)`` runs forward AND
backward on the kernel path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import expert_gemm as _eg
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def expert_gemm(xe, w_gate, w_up, w_down, blocks=_eg.DEFAULT_BLOCKS):
    """(..., E, C, D) x (E,D,F)x2 x (E,F,D) -> (..., E, C, D)."""
    lead = xe.shape[:-3]
    E, C, D = xe.shape[-3:]
    x3 = xe.reshape((-1, C, D)) if lead else xe
    if lead:
        G = x3.shape[0] // E if E else 1
        # fold leading group dims into the token dim per expert
        x3 = xe.reshape((-1, E, C, D)).transpose(1, 0, 2, 3).reshape(E, -1, D)
        y = _eg.expert_gemm(x3, w_gate, w_up, w_down, blocks=blocks, interpret=_interpret())
        y = y.reshape(E, -1, C, D).transpose(1, 0, 2, 3).reshape(lead + (E, C, D))
        return y
    return _eg.expert_gemm(xe, w_gate, w_up, w_down, blocks=blocks, interpret=_interpret())


def grouped_gemm(xs, w_gate, w_up, w_down, group_sizes, row_block=_eg.DEFAULT_BLOCKS[0]):
    """Group-size-aware grouped GEMM over the flat expert-sorted layout the
    sorted dispatcher produces: (N_pad, D) rows, each expert's region
    row_block-aligned, group_sizes (E,) valid rows per expert."""
    blocks = (row_block,) + _eg.DEFAULT_BLOCKS[1:]
    return _eg.grouped_gemm(
        xs, w_gate, w_up, w_down, group_sizes, blocks=blocks, interpret=_interpret()
    )


def expert_gemm_q8(xe, w_gate, w_up, w_down, s_gate, s_up, s_down,
                   blocks=_eg.DEFAULT_BLOCKS):
    """int8-weight padded expert FFN with dequant fused into the tile:
    weights int8 (core/quant.py layout), per-expert per-output-channel
    scales applied to the fp32 accumulator in the epilogue. Forward-only
    (serving); same leading-dim folding as :func:`expert_gemm`."""
    lead = xe.shape[:-3]
    E, C, D = xe.shape[-3:]
    if lead:
        x3 = xe.reshape((-1, E, C, D)).transpose(1, 0, 2, 3).reshape(E, -1, D)
        y = _eg.expert_gemm_q8(
            x3, w_gate, w_up, w_down, s_gate, s_up, s_down,
            blocks=blocks, interpret=_interpret(),
        )
        return y.reshape(E, -1, C, D).transpose(1, 0, 2, 3).reshape(lead + (E, C, D))
    return _eg.expert_gemm_q8(
        xe, w_gate, w_up, w_down, s_gate, s_up, s_down,
        blocks=blocks, interpret=_interpret(),
    )


def grouped_gemm_q8(xs, w_gate, w_up, w_down, s_gate, s_up, s_down,
                    group_sizes, row_block=_eg.DEFAULT_BLOCKS[0]):
    """int8-weight grouped GEMM over the sorted layout (fused dequant,
    fp32 accumulate, SwiGLU epilogue unchanged). Forward-only."""
    blocks = (row_block,) + _eg.DEFAULT_BLOCKS[1:]
    return _eg.grouped_gemm_q8(
        xs, w_gate, w_up, w_down, s_gate, s_up, s_down, group_sizes,
        blocks=blocks, interpret=_interpret(),
    )


def grouped_gemm_xla(xs, w_gate, w_up, w_down, group_sizes):
    """XLA path for the sorted layout (compact buffer, row_block=1):
    ``lax.ragged_dot`` is the native grouped GEMM; falls back to the
    per-expert masked reference when unavailable."""
    if not hasattr(jax.lax, "ragged_dot"):
        from repro.kernels.ref import grouped_gemm_ref

        return grouped_gemm_ref(xs, w_gate, w_up, w_down, group_sizes)
    g = jax.lax.ragged_dot(xs, w_gate, group_sizes)
    u = jax.lax.ragged_dot(xs, w_up, group_sizes)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xs.dtype) * u
    return jax.lax.ragged_dot(h, w_down, group_sizes)


def flash_attention(
    q, k, v, causal: bool = True, window: Optional[int] = None,
    scale: Optional[float] = None, blocks=_fa.DEFAULT_BLOCKS,
):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, scale=scale, blocks=blocks,
        interpret=_interpret(),
    )


def paged_attention(
    q, k_pool, v_pool, block_table, seq_lens,
    window: Optional[int] = None, scale: Optional[float] = None,
):
    """Single-token decode against the block-table KV pool: q (B,H,d),
    pools (num_pages, page_size, KV, d), block_table (B, max_pages) int32
    (-1 = unassigned), seq_lens (B,). The page gather happens inside the
    kernel via scalar-prefetched block tables."""
    return _pa.paged_attention(
        q, k_pool, v_pool, block_table, seq_lens, window=window, scale=scale,
        interpret=_interpret(),
    )


def paged_attention_q8(
    q, k_pool, v_pool, k_scale, v_scale, block_table, seq_lens,
    window: Optional[int] = None, scale: Optional[float] = None,
):
    """int8-KV decode: pools are int8 with per-token/kv-head f32 scale
    sidecars shaped (num_pages, page_size, KV, 1); the kernel dequantizes
    each page tile in VMEM after the scalar-prefetched block-table DMA."""
    return _pa.paged_attention_q8(
        q, k_pool, v_pool, k_scale, v_scale, block_table, seq_lens,
        window=window, scale=scale, interpret=_interpret(),
    )
