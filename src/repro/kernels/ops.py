"""jit'd public wrappers for the Pallas kernels.

On non-TPU backends (this container) the kernels execute in interpret mode
— the kernel body runs in Python on CPU for correctness validation; on TPU
they compile to Mosaic. The dispatch subsystem (``core/dispatch``) calls
``expert_gemm`` (padded layout) or ``grouped_gemm`` (sorted layout) when
``use_kernel=True``; ``models/attention.py`` calls ``flash_attention`` in
place of the blockwise XLA path. All three are differentiable
(``jax.custom_vjp`` with hand-written backward Pallas kernels and
activation recompute — see kernels/expert_gemm.py, kernels/
flash_attention.py), so ``Trainer(use_kernel=True)`` runs forward AND
backward on the kernel path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune as _at
from repro.kernels import expert_gemm as _eg
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Autotune hooks: analytic per-candidate traffic models handed to
# kernels/autotune.get_blocks. Everything here is shapes-only (static under
# jit tracing); with REPRO_AUTOTUNE off, get_blocks returns the static
# heuristic fallback untouched.
# ---------------------------------------------------------------------------

_GG_NOMINAL_ROWS = 4096  # nominal sorted-buffer rows for the traffic model


def _gg_cost(E: int, D: int, F: int, bc: int, w_it: int):
    """Traffic model of the two grouped-GEMM Pallas kernels at tiling
    (bc, bf, bd): expert weights are re-read once per row tile, x once per
    F tile, h written once and re-read once per D tile."""
    N = _GG_NOMINAL_ROWS

    def cost(blocks):
        bf, bd = blocks
        nf, nd, nt = F // bf, D // bd, max(N // bc, 1)
        gate_up_vmem = (
            bc * bd * 2.0 + 2.0 * bd * bf * w_it + 2.0 * bc * bf * 4.0
            + bc * bf * 2.0
        )
        down_vmem = bc * bf * 2.0 + bf * bd * w_it + bc * bd * 4.0 + bc * bd * 2.0
        return {
            "flops": 6.0 * N * D * F,
            "bytes": (
                nt * 3.0 * D * F * w_it
                + nf * N * D * 2.0
                + (1.0 + nd) * N * F * 2.0
                + N * D * 2.0
            ),
            "steps": 2.0 * nt * nf * nd,
            "vmem_bytes": max(gate_up_vmem, down_vmem),
        }

    return cost


def _tuned_ffn_blocks(kernel: str, E: int, D: int, F: int, row_block: int,
                      itemsize: int):
    """Resolve (row_block, bf, bd) for the grouped/fused expert kernels:
    row_block is structural (it is the sorted buffer's alignment, not a
    free tile), so only the lane tiles (bf, bd) are tuned."""
    fallback = tuple(
        _eg._pick(b, d) for b, d in zip(_eg.DEFAULT_BLOCKS[1:], (F, D))
    )
    bf, bd = _at.get_blocks(
        kernel,
        _at.make_key(kernel, E=E, D=D, F=F, itemsize=itemsize,
                     extra=f"bc{row_block}"),
        fallback,
        dims=(F, D),
        aligns=(128, 128),
        cost=_gg_cost(E, D, F, row_block, itemsize),
    )
    return (row_block, bf, bd)


def expert_gemm(xe, w_gate, w_up, w_down, blocks=_eg.DEFAULT_BLOCKS):
    """(..., E, C, D) x (E,D,F)x2 x (E,F,D) -> (..., E, C, D)."""
    lead = xe.shape[:-3]
    E, C, D = xe.shape[-3:]
    F = w_gate.shape[-1]
    blocks = (blocks[0],) + _tuned_ffn_blocks(
        "expert_gemm", E, D, F, blocks[0], itemsize=2
    )[1:]
    x3 = xe.reshape((-1, C, D)) if lead else xe
    if lead:
        G = x3.shape[0] // E if E else 1
        # fold leading group dims into the token dim per expert
        x3 = xe.reshape((-1, E, C, D)).transpose(1, 0, 2, 3).reshape(E, -1, D)
        y = _eg.expert_gemm(x3, w_gate, w_up, w_down, blocks=blocks, interpret=_interpret())
        y = y.reshape(E, -1, C, D).transpose(1, 0, 2, 3).reshape(lead + (E, C, D))
        return y
    return _eg.expert_gemm(xe, w_gate, w_up, w_down, blocks=blocks, interpret=_interpret())


def grouped_gemm(xs, w_gate, w_up, w_down, group_sizes, row_block=_eg.DEFAULT_BLOCKS[0]):
    """Group-size-aware grouped GEMM over the flat expert-sorted layout the
    sorted dispatcher produces: (N_pad, D) rows, each expert's region
    row_block-aligned, group_sizes (E,) valid rows per expert."""
    E, D = w_gate.shape[0], w_gate.shape[1]
    blocks = _tuned_ffn_blocks(
        "grouped_gemm", E, D, w_gate.shape[2], row_block, itemsize=2
    )
    return _eg.grouped_gemm(
        xs, w_gate, w_up, w_down, group_sizes, blocks=blocks, interpret=_interpret()
    )


def grouped_gemm_fused(x, w_gate, w_up, w_down, group_sizes, token, dest,
                       slot, gate_sorted, row_block=_eg.DEFAULT_BLOCKS[0]):
    """Dispatch-in-kernel sorted MoE FFN (token-major (T, D) in and out):
    the scalar-prefetched ``token``/``dest`` row indices resolve the gather
    in the gate/up prologue and the gate-weighted combine in the down
    epilogue — see kernels/expert_gemm.grouped_gemm_fused."""
    E, D = w_gate.shape[0], w_gate.shape[1]
    blocks = _tuned_ffn_blocks(
        "grouped_gemm_fused", E, D, w_gate.shape[2], row_block, itemsize=2
    )
    return _eg.grouped_gemm_fused(
        x, w_gate, w_up, w_down, group_sizes, token, dest, slot, gate_sorted,
        blocks=blocks, interpret=_interpret(),
    )


def grouped_gemm_fused_q8(x, w_gate, w_up, w_down, s_gate, s_up, s_down,
                          group_sizes, token, dest, slot, gate_sorted,
                          row_block=_eg.DEFAULT_BLOCKS[0]):
    """int8-weight fused-dispatch sorted MoE FFN (fused dequant; serving,
    forward-only)."""
    E, D = w_gate.shape[0], w_gate.shape[1]
    blocks = _tuned_ffn_blocks(
        "grouped_gemm_fused_q8", E, D, w_gate.shape[2], row_block, itemsize=1
    )
    return _eg.grouped_gemm_fused_q8(
        x, w_gate, w_up, w_down, s_gate, s_up, s_down, group_sizes,
        token, dest, slot, gate_sorted, blocks=blocks, interpret=_interpret(),
    )


def expert_gemm_q8(xe, w_gate, w_up, w_down, s_gate, s_up, s_down,
                   blocks=_eg.DEFAULT_BLOCKS):
    """int8-weight padded expert FFN with dequant fused into the tile:
    weights int8 (core/quant.py layout), per-expert per-output-channel
    scales applied to the fp32 accumulator in the epilogue. Forward-only
    (serving); same leading-dim folding as :func:`expert_gemm`."""
    lead = xe.shape[:-3]
    E, C, D = xe.shape[-3:]
    blocks = (blocks[0],) + _tuned_ffn_blocks(
        "expert_gemm_q8", E, D, w_gate.shape[-1], blocks[0], itemsize=1
    )[1:]
    if lead:
        x3 = xe.reshape((-1, E, C, D)).transpose(1, 0, 2, 3).reshape(E, -1, D)
        y = _eg.expert_gemm_q8(
            x3, w_gate, w_up, w_down, s_gate, s_up, s_down,
            blocks=blocks, interpret=_interpret(),
        )
        return y.reshape(E, -1, C, D).transpose(1, 0, 2, 3).reshape(lead + (E, C, D))
    return _eg.expert_gemm_q8(
        xe, w_gate, w_up, w_down, s_gate, s_up, s_down,
        blocks=blocks, interpret=_interpret(),
    )


def grouped_gemm_q8(xs, w_gate, w_up, w_down, s_gate, s_up, s_down,
                    group_sizes, row_block=_eg.DEFAULT_BLOCKS[0]):
    """int8-weight grouped GEMM over the sorted layout (fused dequant,
    fp32 accumulate, SwiGLU epilogue unchanged). Forward-only."""
    E, D = w_gate.shape[0], w_gate.shape[1]
    blocks = _tuned_ffn_blocks(
        "grouped_gemm_q8", E, D, w_gate.shape[2], row_block, itemsize=1
    )
    return _eg.grouped_gemm_q8(
        xs, w_gate, w_up, w_down, s_gate, s_up, s_down, group_sizes,
        blocks=blocks, interpret=_interpret(),
    )


def grouped_gemm_xla(xs, w_gate, w_up, w_down, group_sizes):
    """XLA path for the sorted layout (compact buffer, row_block=1):
    ``lax.ragged_dot`` is the native grouped GEMM; falls back to the
    per-expert masked reference when unavailable."""
    if not hasattr(jax.lax, "ragged_dot"):
        from repro.kernels.ref import grouped_gemm_ref

        return grouped_gemm_ref(xs, w_gate, w_up, w_down, group_sizes)
    g = jax.lax.ragged_dot(xs, w_gate, group_sizes)
    u = jax.lax.ragged_dot(xs, w_up, group_sizes)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xs.dtype) * u
    return jax.lax.ragged_dot(h, w_down, group_sizes)


def _fa_cost(B: int, H: int, KV: int, Sq: int, Sk: int, d: int):
    """Flash-attention traffic model at (bq, bk): q/out read+written once
    per head, K/V re-read once per query tile, score tile in fp32 VMEM."""

    def cost(blocks):
        bq, bk = blocks
        nq, nk = Sq // bq, Sk // bk
        return {
            "flops": 4.0 * B * H * Sq * Sk * d,
            "bytes": (
                B * H * Sq * d * 2.0 * 2.0      # q in, out
                + B * KV * nq * Sk * d * 2.0 * 2.0  # k+v per q tile
            ),
            "steps": float(B * H * nq * nk),
            "vmem_bytes": (
                bq * d * 2.0 + 2.0 * bk * d * 2.0 + bq * d * 4.0
                + bq * bk * 4.0 + 2.0 * bq * 4.0
            ),
        }

    return cost


def flash_attention(
    q, k, v, causal: bool = True, window: Optional[int] = None,
    scale: Optional[float] = None, blocks=_fa.DEFAULT_BLOCKS,
):
    B, Sq, H, d = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    blocks = _at.get_blocks(
        "flash_attention",
        _at.make_key("flash_attention", D=d, itemsize=q.dtype.itemsize,
                     extra=f"Sq{Sq}xSk{Sk}"),
        _fa._tiling(Sq, Sk, blocks),
        dims=(Sq, Sk),
        aligns=(8, 8),
        cost=_fa_cost(B, H, KV, Sq, Sk, d),
    )
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, scale=scale, blocks=blocks,
        interpret=_interpret(),
    )


def _pa_cost(B: int, KV: int, G: int, maxP: int, ps: int, d: int, it: int):
    """Paged-decode traffic model at sub-page tile (bps,): total KV bytes
    are tiling-invariant (every live row is read once); the tile size
    trades grid-step overhead against VMEM footprint."""

    def cost(blocks):
        (bps,) = blocks
        nsub = ps // bps
        kv_bytes = B * KV * maxP * ps * d * float(it) * 2.0
        scale_bytes = (B * KV * maxP * ps * 4.0 * 2.0) if it == 1 else 0.0
        return {
            "flops": 4.0 * B * KV * G * maxP * ps * d,
            "bytes": kv_bytes + scale_bytes + B * KV * G * d * 2.0 * 2.0,
            "steps": float(B * KV * maxP * nsub),
            "vmem_bytes": (
                2.0 * bps * d * float(it) + G * d * 2.0 + G * d * 4.0
                + 2.0 * G * 4.0 + (2.0 * bps * 4.0 if it == 1 else 0.0)
            ),
        }

    return cost


def _pa_page_block(kernel: str, q, k_pool, block_table, itemsize: int):
    B, H, d = q.shape
    _, ps, KV, _ = k_pool.shape
    maxP = block_table.shape[1]
    (bps,) = _at.get_blocks(
        kernel,
        _at.make_key(kernel, k=KV, D=d, page_size=ps, itemsize=itemsize,
                     extra=f"G{H // KV}"),
        (ps,),
        dims=(ps,),
        aligns=(8,),
        cost=_pa_cost(B, KV, H // KV, maxP, ps, d, itemsize),
    )
    return bps


def paged_attention(
    q, k_pool, v_pool, block_table, seq_lens,
    window: Optional[int] = None, scale: Optional[float] = None,
):
    """Single-token decode against the block-table KV pool: q (B,H,d),
    pools (num_pages, page_size, KV, d), block_table (B, max_pages) int32
    (-1 = unassigned), seq_lens (B,). The page gather happens inside the
    kernel via scalar-prefetched block tables."""
    bps = _pa_page_block("paged_attention", q, k_pool, block_table,
                         k_pool.dtype.itemsize)
    return _pa.paged_attention(
        q, k_pool, v_pool, block_table, seq_lens, window=window, scale=scale,
        interpret=_interpret(), page_block=bps,
    )


def paged_attention_q8(
    q, k_pool, v_pool, k_scale, v_scale, block_table, seq_lens,
    window: Optional[int] = None, scale: Optional[float] = None,
):
    """int8-KV decode: pools are int8 with per-token/kv-head f32 scale
    sidecars shaped (num_pages, page_size, KV, 1); the kernel dequantizes
    each page tile in VMEM after the scalar-prefetched block-table DMA."""
    bps = _pa_page_block("paged_attention_q8", q, k_pool, block_table, 1)
    return _pa.paged_attention_q8(
        q, k_pool, v_pool, k_scale, v_scale, block_table, seq_lens,
        window=window, scale=scale, interpret=_interpret(), page_block=bps,
    )
