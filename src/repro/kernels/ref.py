"""Pure-jnp oracles for the Pallas kernels. Each kernel's tests sweep
shapes/dtypes and assert_allclose against these."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def expert_gemm_ref(
    xe: jax.Array,  # (E, C, D) tokens per expert
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
) -> jax.Array:
    """Fused SwiGLU expert FFN: silu(x@wg) * (x@wu) @ wd, batched over E."""
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xe, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(xe.dtype)
    return jnp.einsum(
        "ecf,efd->ecd", h, w_down, preferred_element_type=jnp.float32
    ).astype(xe.dtype)


def grouped_gemm_ref(
    xs: jax.Array,  # (N, D) expert-sorted rows (may be tile-align padded)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    group_sizes: jax.Array,  # (E,) valid rows per expert
    row_block: int = 1,
) -> jax.Array:
    """Group-size-aware fused SwiGLU FFN over the flat expert-sorted layout.
    Each expert's region starts at its (row_block-aligned) offset; rows past
    ``group_sizes[e]`` produce zeros. O(E) python loop — oracle only."""
    N, D = xs.shape
    E = w_gate.shape[0]
    b = row_block
    padded = ((group_sizes + b - 1) // b) * b
    starts = jnp.cumsum(padded) - padded
    row = jnp.arange(N)
    out = jnp.zeros((N, w_down.shape[-1]), jnp.float32)
    for e in range(E):
        g = jnp.dot(xs, w_gate[e], preferred_element_type=jnp.float32)
        u = jnp.dot(xs, w_up[e], preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(xs.dtype)
        y = jnp.dot(h, w_down[e], preferred_element_type=jnp.float32)
        mine = (row >= starts[e]) & (row < starts[e] + group_sizes[e])
        out = jnp.where(mine[:, None], y, out)
    return out.astype(xs.dtype)


def flash_attention_ref(
    q: jax.Array,  # (B, Sq, H, d)
    k: jax.Array,  # (B, Sk, H, d)  (kv heads pre-broadcast to H)
    v: jax.Array,  # (B, Sk, H, d)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Sq, H, d = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else d**-0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    qp = jnp.arange(Sq)[:, None] + (Sk - Sq)  # right-aligned positions
    kp = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhqk,bkhd->bqhd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    ).astype(v.dtype)
