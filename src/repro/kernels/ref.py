"""Pure-jnp oracles for the Pallas kernels. Each kernel's tests sweep
shapes/dtypes and assert_allclose against these."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def expert_gemm_ref(
    xe: jax.Array,  # (E, C, D) tokens per expert
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
) -> jax.Array:
    """Fused SwiGLU expert FFN: silu(x@wg) * (x@wu) @ wd, batched over E."""
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xe, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(xe.dtype)
    return jnp.einsum(
        "ecf,efd->ecd", h, w_down, preferred_element_type=jnp.float32
    ).astype(xe.dtype)


def grouped_gemm_ref(
    xs: jax.Array,  # (N, D) expert-sorted rows (may be tile-align padded)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    group_sizes: jax.Array,  # (E,) valid rows per expert
    row_block: int = 1,
) -> jax.Array:
    """Group-size-aware fused SwiGLU FFN over the flat expert-sorted layout.
    Each expert's region starts at its (row_block-aligned) offset; rows past
    ``group_sizes[e]`` produce zeros. O(E) python loop — oracle only."""
    N, D = xs.shape
    E = w_gate.shape[0]
    b = row_block
    padded = ((group_sizes + b - 1) // b) * b
    starts = jnp.cumsum(padded) - padded
    row = jnp.arange(N)
    out = jnp.zeros((N, w_down.shape[-1]), jnp.float32)
    for e in range(E):
        g = jnp.dot(xs, w_gate[e], preferred_element_type=jnp.float32)
        u = jnp.dot(xs, w_up[e], preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(xs.dtype)
        y = jnp.dot(h, w_down[e], preferred_element_type=jnp.float32)
        mine = (row >= starts[e]) & (row < starts[e] + group_sizes[e])
        out = jnp.where(mine[:, None], y, out)
    return out.astype(xs.dtype)


def expert_gemm_q8_ref(
    xe: jax.Array,  # (E, C, D)
    w_gate: jax.Array,  # (E, D, F) int8
    w_up: jax.Array,  # (E, D, F) int8
    w_down: jax.Array,  # (E, F, D) int8
    s_gate: jax.Array,  # (E, F) per-output-channel scales
    s_up: jax.Array,  # (E, F)
    s_down: jax.Array,  # (E, D)
) -> jax.Array:
    """Oracle for the fused-dequant int8 expert FFN: int8 weights cast to
    the activation dtype for the matmul (exact — |q| <= 127), fp32
    accumulate, scale applied to the accumulator (per-output-channel
    scales commute with the contraction). Mirrors the kernel math."""
    wdt = xe.dtype
    g = jnp.einsum(
        "ecd,edf->ecf", xe, w_gate.astype(wdt), preferred_element_type=jnp.float32
    ) * s_gate[:, None, :].astype(jnp.float32)
    u = jnp.einsum(
        "ecd,edf->ecf", xe, w_up.astype(wdt), preferred_element_type=jnp.float32
    ) * s_up[:, None, :].astype(jnp.float32)
    h = (jax.nn.silu(g) * u).astype(xe.dtype)
    y = jnp.einsum(
        "ecf,efd->ecd", h, w_down.astype(wdt), preferred_element_type=jnp.float32
    ) * s_down[:, None, :].astype(jnp.float32)
    return y.astype(xe.dtype)


def grouped_gemm_q8_ref(
    xs: jax.Array,  # (N, D) expert-sorted rows (may be tile-align padded)
    w_gate: jax.Array,  # (E, D, F) int8
    w_up: jax.Array,  # (E, D, F) int8
    w_down: jax.Array,  # (E, F, D) int8
    s_gate: jax.Array,  # (E, F)
    s_up: jax.Array,  # (E, F)
    s_down: jax.Array,  # (E, D)
    group_sizes: jax.Array,  # (E,) valid rows per expert
    row_block: int = 1,
) -> jax.Array:
    """int8 grouped-GEMM oracle over the sorted layout; same region/mask
    logic as :func:`grouped_gemm_ref`, kernel-mirroring dequant math."""
    N, D = xs.shape
    E = w_gate.shape[0]
    b = row_block
    padded = ((group_sizes + b - 1) // b) * b
    starts = jnp.cumsum(padded) - padded
    row = jnp.arange(N)
    out = jnp.zeros((N, w_down.shape[-1]), jnp.float32)
    wdt = xs.dtype
    for e in range(E):
        g = jnp.dot(
            xs, w_gate[e].astype(wdt), preferred_element_type=jnp.float32
        ) * s_gate[e].astype(jnp.float32)
        u = jnp.dot(
            xs, w_up[e].astype(wdt), preferred_element_type=jnp.float32
        ) * s_up[e].astype(jnp.float32)
        h = (jax.nn.silu(g) * u).astype(xs.dtype)
        y = jnp.dot(
            h, w_down[e].astype(wdt), preferred_element_type=jnp.float32
        ) * s_down[e].astype(jnp.float32)
        mine = (row >= starts[e]) & (row < starts[e] + group_sizes[e])
        out = jnp.where(mine[:, None], y, out)
    return out.astype(xs.dtype)


def paged_attention_ref(
    q: jax.Array,  # (B, H, d) one query token per sequence
    k_pool: jax.Array,  # (num_pages, page_size, KV, d) shared page pool
    v_pool: jax.Array,  # (num_pages, page_size, KV, d)
    block_table: jax.Array,  # (B, max_pages) int32 page ids, -1 = unassigned
    seq_lens: jax.Array,  # (B,) int32 tokens valid per sequence (incl. current)
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """XLA gather oracle for the Pallas paged-attention decode kernel.

    Logical KV slot ``j`` of sequence ``b`` lives at
    ``pool[block_table[b, j // page_size], j % page_size]`` (identity
    position mapping — pages never wrap). Slots with ``j >= seq_lens[b]``
    or an unassigned page are masked. Returns (B, H, d)."""
    B, H, d = q.shape
    _, ps, KV, _ = k_pool.shape
    G = H // KV
    scale = scale if scale is not None else d**-0.5
    bt = jnp.maximum(block_table, 0)
    kg = k_pool[bt].reshape(B, -1, KV, d)  # (B, maxP*ps, KV, d)
    vg = v_pool[bt].reshape(B, -1, KV, d)
    S = kg.shape[1]
    kpos = jnp.arange(S, dtype=jnp.int32)[None, :]
    valid = (kpos < seq_lens[:, None]) & (block_table >= 0)[
        :, jnp.arange(S) // ps
    ]
    if window is not None:
        valid &= kpos > (seq_lens[:, None] - 1) - window
    qg = q.reshape(B, KV, G, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kg, preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", p.astype(vg.dtype), vg, preferred_element_type=jnp.float32
    )
    # fully-masked sequences (e.g. an idle batch slot) emit zeros, not the
    # uniform-softmax average of garbage — keeps the kernel parity exact
    out = jnp.where(valid.any(-1)[:, None, None, None], out, 0.0)
    return out.reshape(B, H, d).astype(v_pool.dtype)


def paged_attention_q8_ref(
    q: jax.Array,  # (B, H, d)
    k_pool: jax.Array,  # (num_pages, page_size, KV, d) int8
    v_pool: jax.Array,  # (num_pages, page_size, KV, d) int8
    k_scale: jax.Array,  # (num_pages, page_size, KV, 1) per-token scales
    v_scale: jax.Array,  # (num_pages, page_size, KV, 1)
    block_table: jax.Array,  # (B, max_pages) int32 page ids, -1 = unassigned
    seq_lens: jax.Array,  # (B,) int32
    window=None,
    scale=None,
) -> jax.Array:
    """int8-KV oracle: dequantize the pools (per-token, per-kv-head
    sidecar scales) in f32 and run the bf16 paged-attention oracle on the
    result. Returns q.dtype."""
    kd = k_pool.astype(jnp.float32) * k_scale.astype(jnp.float32)
    vd = v_pool.astype(jnp.float32) * v_scale.astype(jnp.float32)
    out = paged_attention_ref(
        q.astype(jnp.float32), kd, vd, block_table, seq_lens,
        window=window, scale=scale,
    )
    return out.astype(q.dtype)


def flash_attention_ref(
    q: jax.Array,  # (B, Sq, H, d)
    k: jax.Array,  # (B, Sk, H, d)  (kv heads pre-broadcast to H)
    v: jax.Array,  # (B, Sk, H, d)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Sq, H, d = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else d**-0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    qp = jnp.arange(Sq)[:, None] + (Sk - Sq)  # right-aligned positions
    kp = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhqk,bkhd->bqhd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    ).astype(v.dtype)
