"""Pallas TPU flash attention (causal / sliding-window), GQA-aware.

Online-softmax over KV blocks with fp32 m/l/acc carried in VMEM scratch —
the TPU-tiled version of the blockwise XLA path in models/attention.py.
GQA reads the shared KV head via the BlockSpec index map (kv = h // group)
instead of materializing a broadcast copy in HBM.

Block sizes (bq, bk) default to (128, 512): q tile (128 x d) and kv tiles
(512 x d) sit in VMEM alongside the fp32 acc (128 x d) — ~1.2 MB at
d=128, far under the ~16 MB VMEM budget, leaving room for double-buffered
pipelining of the kv stream from HBM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCKS = (128, 512)
NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int],
    bq: int, bk: int, nk: int, q_offset: int,
):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

    qpos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
    m_scr[...] = m_new
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(kb == nk - 1)
    def _write():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "blocks", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, d)
    k: jax.Array,  # (B, Sk, KV, d), H % KV == 0
    v: jax.Array,  # (B, Sk, KV, d)
    causal: bool = True,
    window: Optional[int] = None,
    blocks: Tuple[int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, d = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = d**-0.5
    bq = min(blocks[0], Sq)
    while Sq % bq:
        bq //= 2
    bk = min(blocks[1], Sk)
    while Sk % bk:
        bk //= 2
    nq, nk = Sq // bq, Sk // bk

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, nk=nk, q_offset=Sk - Sq,
        ),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
