"""Pallas TPU flash attention (causal / sliding-window), GQA-aware,
differentiable.

Forward: online-softmax over KV blocks with fp32 m/l/acc carried in VMEM
scratch — the TPU-tiled version of the blockwise XLA path in
models/attention.py. GQA reads the shared KV head via the BlockSpec index
map (kv = h // group) instead of materializing a broadcast copy in HBM. The
forward also emits the logsumexp (B*H, Sq) — the only extra residual the
backward needs.

Backward: the standard two-pass flash schedule behind ``jax.custom_vjp``.
Residuals are (q, k, v, out, lse); the (Sq, Sk) probability blocks are
RECOMPUTED per tile from ``lse``, never stored:

* dq kernel — grid (B*H, nq, nk): p = exp(s - lse), dp = do @ v^T,
  ds = p * (dp - delta) * scale, dq += ds @ k, accumulated over KV blocks
  in fp32 scratch.
* dk/dv kernel — grid (B*KV, nk, G, nq): same recompute per (q-block,
  group-head) pair; dk/dv accumulate over the G query heads sharing the KV
  head and over q blocks in fp32 scratch (inner grid dims), so GQA needs no
  (B*H, Sk, d) staging buffer.

``delta = rowsum(do * out)`` (the softmax Jacobian diagonal) is computed
outside the kernels — it is O(N*d) elementwise.

Block sizes (bq, bk) default to (128, 512): q tile (128 x d) and kv tiles
(512 x d) sit in VMEM alongside the fp32 acc (128 x d) — ~1.2 MB at
d=128, far under the ~16 MB VMEM budget, leaving room for double-buffered
pipelining of the kv stream from HBM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCKS = (128, 512)
NEG_INF = -1e30


def _positions(qi, ki, bq: int, bk: int, q_offset: int):
    """(bq, bk) query/key position grids for the (qi, ki) tile."""
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    return qpos, kpos


def _tile_mask(qpos, kpos, causal: bool, window: Optional[int]):
    mask = jnp.ones(qpos.shape, jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def _tile_relevant(qi, ki, bq: int, bk: int, q_offset: int,
                   causal: bool, window: Optional[int]):
    """Traced predicate: does the (qi, ki) tile contain ANY unmasked entry?
    Fully-masked tiles contribute nothing (p == 0 everywhere) and are
    skipped — under causal masking that halves fwd/bwd attention FLOPs.
    Returns None when every tile is live (no mask)."""
    rel = None
    if causal:  # some kpos <= qpos: min kpos vs max qpos
        rel = qi * bq + bq - 1 + q_offset >= ki * bk
    if window is not None:  # some kpos > qpos - window: max kpos vs min qpos
        w = ki * bk + bk - 1 > qi * bq + q_offset - window
        rel = w if rel is None else jnp.logical_and(rel, w)
    return rel


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: Optional[int],
    bq: int, bk: int, nk: int, q_offset: int,
):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qi = pl.program_id(1)

    def _compute():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

        qpos, kpos = _positions(qi, kb, bq, bk, q_offset)
        s = jnp.where(_tile_mask(qpos, kpos, causal, window), s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
        )

    rel = _tile_relevant(qi, kb, bq, bk, q_offset, causal, window)
    if rel is None:
        _compute()
    else:
        pl.when(rel)(_compute)

    @pl.when(kb == nk - 1)
    def _write():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_scr[...] + jnp.log(l)


def _fa_bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_acc,
    *, scale: float, causal: bool, window: Optional[int],
    bq: int, bk: int, nk: int, q_offset: int,
):
    kb, qi = pl.program_id(2), pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    def _compute():
        q, k, v, do = q_ref[0], k_ref[0], v_ref[0], do_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos, kpos = _positions(qi, kb, bq, bk, q_offset)
        s = jnp.where(_tile_mask(qpos, kpos, causal, window), s, NEG_INF)
        p = jnp.exp(s - lse_ref[0][:, None])  # (bq, bk), masked entries -> 0
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None]) * scale
        dq_acc[...] += jnp.dot(
            ds, k.astype(jnp.float32), preferred_element_type=jnp.float32
        )

    rel = _tile_relevant(qi, kb, bq, bk, q_offset, causal, window)
    if rel is None:
        _compute()
    else:
        pl.when(rel)(_compute)

    @pl.when(kb == nk - 1)
    def _write():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def _fa_bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_acc, dv_acc,
    *, scale: float, causal: bool, window: Optional[int],
    bq: int, bk: int, nq: int, G: int, q_offset: int,
):
    ki, g, qi = pl.program_id(1), pl.program_id(2), pl.program_id(3)

    @pl.when(jnp.logical_and(g == 0, qi == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    def _compute():
        q, k, v, do = q_ref[0], k_ref[0], v_ref[0], do_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos, kpos = _positions(qi, ki, bq, bk, q_offset)
        s = jnp.where(_tile_mask(qpos, kpos, causal, window), s, NEG_INF)
        p = jnp.exp(s - lse_ref[0][:, None])  # (bq, bk)
        dv_acc[...] += jax.lax.dot_general(  # p^T @ do -> (bk, d)
            p, do.astype(jnp.float32), (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(  # ds^T @ q -> (bk, d)
            ds, q.astype(jnp.float32), (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    rel = _tile_relevant(qi, ki, bq, bk, q_offset, causal, window)
    if rel is None:
        _compute()
    else:
        pl.when(rel)(_compute)

    @pl.when(jnp.logical_and(g == G - 1, qi == nq - 1))
    def _write():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _tiling(Sq: int, Sk: int, blocks: Tuple[int, int]):
    """Static tile heuristic (the autotuner's cache-miss fallback): largest
    sublane-aligned divisors <= the requested blocks via the shared
    ``_pick``, replacing the old power-of-two halving loop that could land
    on needlessly small tiles for non-power-of-two sequence lengths."""
    from repro.kernels.expert_gemm import _pick

    bq = _pick(blocks[0], Sq, align=8)
    bk = _pick(blocks[1], Sk, align=8)
    return bq, bk


def _fa_call(q, k, v, causal, window, scale, blocks, interpret):
    """Shared forward: returns (out in the public layout, lse (B*H, Sq))."""
    B, Sq, H, d = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq, bk = _tiling(Sq, Sk, blocks)
    nq, nk = Sq // bq, Sk // bk

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)

    o_h, lse = pl.pallas_call(
        functools.partial(
            _fa_kernel, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, nk=nk, q_offset=Sk - Sq,
        ),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bq), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
            jax.ShapeDtypeStruct((B * H, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = o_h.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _fa_p(q, k, v, causal, window, scale, blocks, interpret):
    out, _ = _fa_call(q, k, v, causal, window, scale, blocks, interpret)
    return out


def _fa_fwd(q, k, v, causal, window, scale, blocks, interpret):
    out, lse = _fa_call(q, k, v, causal, window, scale, blocks, interpret)
    # residuals stay in the caller's layout: q/k/v/out are alive in the
    # autodiff graph anyway, so this saves nothing extra but the lse —
    # the head-major transposes are recomputed (cheap) in the backward
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, scale, blocks, interpret, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, d = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    BH, BKV, G = B * H, B * KV, H // KV
    bq, bk = _tiling(Sq, Sk, blocks)
    nq, nk = Sq // bq, Sk // bk
    q_offset = Sk - Sq

    qh = q.transpose(0, 2, 1, 3).reshape(BH, Sq, d)
    kh = k.transpose(0, 2, 1, 3).reshape(BKV, Sk, d)
    vh = v.transpose(0, 2, 1, 3).reshape(BKV, Sk, d)
    do_h = dout.transpose(0, 2, 1, 3).reshape(BH, Sq, d)
    # softmax Jacobian diagonal, O(N*d) elementwise — no kernel needed
    delta = (
        jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
        .transpose(0, 2, 1)
        .reshape(BH, Sq)
    )

    dq_h = pl.pallas_call(
        functools.partial(
            _fa_bwd_dq_kernel, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, nk=nk, q_offset=q_offset,
        ),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bq), lambda bh, qi, ki: (bh, qi)),
            pl.BlockSpec((1, bq), lambda bh, qi, ki: (bh, qi)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), qh.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(qh, kh, vh, do_h, lse, delta)

    dk_h, dv_h = pl.pallas_call(
        functools.partial(
            _fa_bwd_dkv_kernel, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, nq=nq, G=G, q_offset=q_offset,
        ),
        grid=(BKV, nk, G, nq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bkv, ki, g, qi, G=G: (bkv * G + g, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bkv, ki, g, qi: (bkv, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bkv, ki, g, qi: (bkv, ki, 0)),
            pl.BlockSpec((1, bq, d), lambda bkv, ki, g, qi, G=G: (bkv * G + g, qi, 0)),
            pl.BlockSpec((1, bq), lambda bkv, ki, g, qi, G=G: (bkv * G + g, qi)),
            pl.BlockSpec((1, bq), lambda bkv, ki, g, qi, G=G: (bkv * G + g, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda bkv, ki, g, qi: (bkv, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bkv, ki, g, qi: (bkv, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BKV, Sk, d), kh.dtype),
            jax.ShapeDtypeStruct((BKV, Sk, d), vh.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh, do_h, lse, delta)

    dq = dq_h.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
    dk = dk_h.reshape(B, KV, Sk, d).transpose(0, 2, 1, 3)
    dv = dv_h.reshape(B, KV, Sk, d).transpose(0, 2, 1, 3)
    return dq, dk, dv


_fa_p.defvjp(_fa_fwd, _fa_bwd)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "blocks", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, d)
    k: jax.Array,  # (B, Sk, KV, d), H % KV == 0
    v: jax.Array,  # (B, Sk, KV, d)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    blocks: Tuple[int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    d = q.shape[-1]
    scale = float(scale) if scale is not None else d**-0.5
    return _fa_p(q, k, v, causal, window, scale, tuple(blocks), interpret)
