"""Pallas TPU grouped expert GEMM with fused SwiGLU epilogue.

The MoE expert FFN is the paper's dominant compute hot-spot (it is what the
46.8%-MFU engineering in Table 2 is about). On H100 Megatron uses a CUTLASS
grouped GEMM; the TPU adaptation re-tiles for the MXU and the HBM->VMEM
hierarchy:

* kernel 1 (``gate_up``): h = silu(x @ w_gate) * (x @ w_up). Both gemms
  share the same x tile (one HBM read), accumulate in fp32 VMEM scratch over
  the D-contraction grid dim, and the SwiGLU epilogue runs in VMEM — the
  (E,C,F) gate/up intermediates NEVER round-trip to HBM (the fusion win:
  saves 2*E*C*F bf16 writes + reads per layer vs. the XLA path).
* kernel 2 (``down``): y = h @ w_down, a plain k-blocked grouped matmul.

Tiles default to (bc, bf, bd) = (128, 512, 512) — MXU-aligned multiples of
128, VMEM footprint ~= bc*bd + 2*bd*bf + 2*bc*bf(fp32) ~= 3.3 MB at bf16.
Expert-parallel composition: the kernel sees the *local* expert shard
(E_loc, ...); dispatch/combine collectives live a level up in core/moe.py.

Validated in interpret mode against kernels/ref.py over shape/dtype sweeps
(tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCKS = (128, 512, 512)  # (bc, bf, bd)


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _gate_up_kernel(x_ref, wg_ref, wu_ref, h_ref, g_acc, u_acc, *, nd: int):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        u_acc[...] = jnp.zeros_like(u_acc)

    x = x_ref[0]
    g_acc[...] += jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    u_acc[...] += jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _epilogue():
        h_ref[0] = (_silu(g_acc[...]) * u_acc[...]).astype(h_ref.dtype)


def _down_kernel(h_ref, wd_ref, y_ref, acc, *, nf: int):
    f = pl.program_id(3)

    @pl.when(f == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(h_ref[0], wd_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f == nf - 1)
    def _write():
        y_ref[0] = acc[...].astype(y_ref.dtype)


def _pick(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b //= 2
    return max(b, 1)


@functools.partial(
    jax.jit, static_argnames=("blocks", "interpret")
)
def expert_gemm(
    xe: jax.Array,  # (E, C, D)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    blocks: Tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    E, C, D = xe.shape
    F = w_gate.shape[-1]
    bc, bf, bd = (_pick(b, d) for b, d in zip(blocks, (C, F, D)))
    nc, nf, nd = C // bc, F // bf, D // bd

    h = pl.pallas_call(
        functools.partial(_gate_up_kernel, nd=nd),
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, bd, bf), lambda e, c, f, d: (e, d, f)),
            pl.BlockSpec((1, bd, bf), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), xe.dtype),
        scratch_shapes=[
            pltpu.VMEM((bc, bf), jnp.float32),
            pltpu.VMEM((bc, bf), jnp.float32),
        ],
        interpret=interpret,
    )(xe, w_gate, w_up)

    y = pl.pallas_call(
        functools.partial(_down_kernel, nf=nf),
        grid=(E, nc, nd, nf),
        in_specs=[
            pl.BlockSpec((1, bc, bf), lambda e, c, d, f: (e, c, f)),
            pl.BlockSpec((1, bf, bd), lambda e, c, d, f: (e, f, d)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd), lambda e, c, d, f: (e, c, d)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), xe.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        interpret=interpret,
    )(h, w_down)
    return y
