"""Pallas TPU grouped expert GEMM with fused SwiGLU epilogue.

The MoE expert FFN is the paper's dominant compute hot-spot (it is what the
46.8%-MFU engineering in Table 2 is about). On H100 Megatron uses a CUTLASS
grouped GEMM; the TPU adaptation re-tiles for the MXU and the HBM->VMEM
hierarchy. Two layouts, matching the two dispatcher families
(core/dispatch/):

Padded layout (``expert_gemm``, allgather/alltoall dispatchers): dense
(E, C, D) buffer, one grid slice per expert.

* kernel 1 (``gate_up``): h = silu(x @ w_gate) * (x @ w_up). Both gemms
  share the same x tile (one HBM read), accumulate in fp32 VMEM scratch over
  the D-contraction grid dim, and the SwiGLU epilogue runs in VMEM — the
  (E,C,F) gate/up intermediates NEVER round-trip to HBM (the fusion win:
  saves 2*E*C*F bf16 writes + reads per layer vs. the XLA path).
* kernel 2 (``down``): y = h @ w_down, a plain k-blocked grouped matmul.

Sorted layout (``grouped_gemm``, sorted dropless dispatcher): flat (N, D)
expert-sorted buffer with per-expert ``group_sizes``, each expert's region
aligned to the row-tile size. Per-row-tile expert ids and valid-row counts
are scalar-prefetched (PrefetchScalarGridSpec) so each tile loads exactly
its expert's weight block; rows past the expert's count are masked in the
epilogue and fully-empty tiles skip the MXU work entirely — the
group-size-aware part that makes dropless cost scale with T*k instead of
E*C. fp32 accumulation and the fused SwiGLU epilogue are identical to the
padded kernels.

Tiles default to (bc, bf, bd) = (128, 512, 512) — MXU-aligned multiples of
128, VMEM footprint ~= bc*bd + 2*bd*bf + 2*bc*bf(fp32) ~= 3.3 MB at bf16.
Expert-parallel composition: the kernel sees the *local* expert shard
(E_loc, ...); dispatch/combine collectives live a level up in
core/dispatch/.

Validated in interpret mode against kernels/ref.py over shape/dtype sweeps
(tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCKS = (128, 512, 512)  # (bc, bf, bd)


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _gate_up_kernel(x_ref, wg_ref, wu_ref, h_ref, g_acc, u_acc, *, nd: int):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        u_acc[...] = jnp.zeros_like(u_acc)

    x = x_ref[0]
    g_acc[...] += jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    u_acc[...] += jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _epilogue():
        h_ref[0] = (_silu(g_acc[...]) * u_acc[...]).astype(h_ref.dtype)


def _down_kernel(h_ref, wd_ref, y_ref, acc, *, nf: int):
    f = pl.program_id(3)

    @pl.when(f == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(h_ref[0], wd_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f == nf - 1)
    def _write():
        y_ref[0] = acc[...].astype(y_ref.dtype)


def _pick(block: int, dim: int) -> int:
    b = min(block, dim)
    while dim % b:
        b //= 2
    return max(b, 1)


@functools.partial(
    jax.jit, static_argnames=("blocks", "interpret")
)
def expert_gemm(
    xe: jax.Array,  # (E, C, D)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    blocks: Tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    E, C, D = xe.shape
    F = w_gate.shape[-1]
    bc, bf, bd = (_pick(b, d) for b, d in zip(blocks, (C, F, D)))
    nc, nf, nd = C // bc, F // bf, D // bd

    h = pl.pallas_call(
        functools.partial(_gate_up_kernel, nd=nd),
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, bd, bf), lambda e, c, f, d: (e, d, f)),
            pl.BlockSpec((1, bd, bf), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), xe.dtype),
        scratch_shapes=[
            pltpu.VMEM((bc, bf), jnp.float32),
            pltpu.VMEM((bc, bf), jnp.float32),
        ],
        interpret=interpret,
    )(xe, w_gate, w_up)

    y = pl.pallas_call(
        functools.partial(_down_kernel, nf=nf),
        grid=(E, nc, nd, nf),
        in_specs=[
            pl.BlockSpec((1, bc, bf), lambda e, c, d, f: (e, c, f)),
            pl.BlockSpec((1, bf, bd), lambda e, c, d, f: (e, f, d)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd), lambda e, c, d, f: (e, c, d)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), xe.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        interpret=interpret,
    )(h, w_down)
    return y


# ---------------------------------------------------------------------------
# Group-size-aware grouped GEMM over the flat expert-sorted layout
# ---------------------------------------------------------------------------


def _grouped_gate_up_kernel(
    tg_ref, tr_ref, x_ref, wg_ref, wu_ref, h_ref, g_acc, u_acc, *, nd: int,
    bc: int, bf: int,
):
    t, d = pl.program_id(0), pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        u_acc[...] = jnp.zeros_like(u_acc)

    valid = tr_ref[t]

    @pl.when(valid > 0)  # fully-empty tiles (group padding) skip the MXU
    def _compute():
        x = x_ref[...]
        g_acc[...] += jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
        u_acc[...] += jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _epilogue():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bc, bf), 0)
        h = _silu(g_acc[...]) * u_acc[...]
        h_ref[...] = jnp.where(rows < valid, h, 0.0).astype(h_ref.dtype)


def _grouped_down_kernel(
    tg_ref, tr_ref, h_ref, wd_ref, y_ref, acc, *, nf: int, bc: int, bd: int
):
    t, f = pl.program_id(0), pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    valid = tr_ref[t]

    @pl.when(valid > 0)
    def _compute():
        acc[...] += jnp.dot(h_ref[...], wd_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f == nf - 1)
    def _write():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bc, bd), 0)
        y_ref[...] = jnp.where(rows < valid, acc[...], 0.0).astype(y_ref.dtype)


def group_tiling(group_sizes: jax.Array, num_tiles: int, bc: int):
    """Per-row-tile metadata for the tile-aligned expert-sorted buffer:
    (tile_group (nt,) expert id, tile_rows (nt,) valid rows in [0, bc]).
    Tiles past the last group get tile_rows 0 (skipped + masked)."""
    E = group_sizes.shape[0]
    padded = ((group_sizes + bc - 1) // bc) * bc
    ends_pad = jnp.cumsum(padded)
    starts_pad = ends_pad - padded
    tile_start = jnp.arange(num_tiles, dtype=jnp.int32) * bc
    tg = jnp.searchsorted(ends_pad, tile_start, side="right")
    tg = jnp.clip(tg, 0, E - 1).astype(jnp.int32)
    tr = jnp.clip(group_sizes[tg] - (tile_start - starts_pad[tg]), 0, bc)
    return tg, tr.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def grouped_gemm(
    xs: jax.Array,  # (N_pad, D) expert-sorted rows, groups row-tile aligned
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    group_sizes: jax.Array,  # (E,) int32 valid rows per expert
    blocks: Tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    N_pad, D = xs.shape
    E, _, F = w_gate.shape
    bc = blocks[0]
    assert N_pad % bc == 0, (N_pad, bc)
    bf, bd = (_pick(b, d) for b, d in zip(blocks[1:], (F, D)))
    nt, nf, nd = N_pad // bc, F // bf, D // bd
    tg, tr = group_tiling(group_sizes, nt, bc)

    h = pl.pallas_call(
        functools.partial(_grouped_gate_up_kernel, nd=nd, bc=bc, bf=bf),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nt, nf, nd),
            in_specs=[
                pl.BlockSpec((bc, bd), lambda t, f, d, tg, tr: (t, d)),
                pl.BlockSpec((1, bd, bf), lambda t, f, d, tg, tr: (tg[t], d, f)),
                pl.BlockSpec((1, bd, bf), lambda t, f, d, tg, tr: (tg[t], d, f)),
            ],
            out_specs=pl.BlockSpec((bc, bf), lambda t, f, d, tg, tr: (t, f)),
            scratch_shapes=[
                pltpu.VMEM((bc, bf), jnp.float32),
                pltpu.VMEM((bc, bf), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((N_pad, F), xs.dtype),
        interpret=interpret,
    )(tg, tr, xs, w_gate, w_up)

    y = pl.pallas_call(
        functools.partial(_grouped_down_kernel, nf=nf, bc=bc, bd=bd),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nt, nd, nf),
            in_specs=[
                pl.BlockSpec((bc, bf), lambda t, d, f, tg, tr: (t, f)),
                pl.BlockSpec((1, bf, bd), lambda t, d, f, tg, tr: (tg[t], f, d)),
            ],
            out_specs=pl.BlockSpec((bc, bd), lambda t, d, f, tg, tr: (t, d)),
            scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((N_pad, D), xs.dtype),
        interpret=interpret,
    )(tg, tr, h, w_down)
    return y
