"""Pallas TPU grouped expert GEMM with fused SwiGLU epilogue.

The MoE expert FFN is the paper's dominant compute hot-spot (it is what the
46.8%-MFU engineering in Table 2 is about). On H100 Megatron uses a CUTLASS
grouped GEMM; the TPU adaptation re-tiles for the MXU and the HBM->VMEM
hierarchy. Two layouts, matching the two dispatcher families
(core/dispatch/):

Padded layout (``expert_gemm``, allgather/alltoall dispatchers): dense
(E, C, D) buffer, one grid slice per expert.

* kernel 1 (``gate_up``): h = silu(x @ w_gate) * (x @ w_up). Both gemms
  share the same x tile (one HBM read), accumulate in fp32 VMEM scratch over
  the D-contraction grid dim, and the SwiGLU epilogue runs in VMEM — the
  (E,C,F) gate/up intermediates NEVER round-trip to HBM (the fusion win:
  saves 2*E*C*F bf16 writes + reads per layer vs. the XLA path).
* kernel 2 (``down``): y = h @ w_down, a plain k-blocked grouped matmul.

Sorted layout (``grouped_gemm``, sorted dropless dispatcher): flat (N, D)
expert-sorted buffer with per-expert ``group_sizes``, each expert's region
aligned to the row-tile size. Per-row-tile expert ids and valid-row counts
are scalar-prefetched (PrefetchScalarGridSpec) so each tile loads exactly
its expert's weight block; rows past the expert's count are masked in the
epilogue and fully-empty tiles skip the MXU work entirely — the
group-size-aware part that makes dropless cost scale with T*k instead of
E*C. fp32 accumulation and the fused SwiGLU epilogue are identical to the
padded kernels.

Tiles default to (bc, bf, bd) = (128, 512, 512) — MXU-aligned multiples of
128, VMEM footprint ~= bc*bd + 2*bd*bf + 2*bc*bf(fp32) ~= 3.3 MB at bf16.
Expert-parallel composition: the kernel sees the *local* expert shard
(E_loc, ...); dispatch/combine collectives live a level up in
core/dispatch/.

Both entry points are differentiable: ``expert_gemm`` and ``grouped_gemm``
carry a ``jax.custom_vjp`` whose backward pass is three more Pallas grouped
kernels over the same scalar-prefetched per-tile expert-id machinery:

* dgrad 1 (``_grouped_bwd_dh_kernel``): recomputes the gate/up projections
  from ``x`` (one extra D-contraction pass), fuses ``dh = dy @ w_down^T``
  into the same grid, and applies the SwiGLU backward in the epilogue —
  emitting ``h``, ``dg``, ``du`` as *backward-transient* buffers.
* dgrad 2 (``_grouped_bwd_dx_kernel``): ``dx = dg @ w_gate^T + du @
  w_up^T``, one fused k-blocked pass over F.
* wgrad (``_grouped_bwd_wgrad_kernel``): ``dw_gate[e] = x_e^T @ dg_e`` etc.
  over the transposed ragged layout — row tiles are the *minor* grid dim so
  each expert's fp32 output block is revisited consecutively and
  accumulated in VMEM, initialized on expert change (group boundaries are
  contiguous in the sorted layout by construction).

Because the backward RECOMPUTES the SwiGLU intermediates, the forward saves
only ``(x, weights, group_sizes)`` as residuals: activation memory per MoE
layer drops from O(N*F) (gate/up/h saved by autodiff) to O(N*D). The padded
``expert_gemm`` backward reuses the grouped kernels by viewing ``(E, C, D)``
as an exactly-tile-aligned sorted buffer with ``group_sizes == C``.

Validated in interpret mode against kernels/ref.py over shape/dtype sweeps,
forward and backward (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCKS = (128, 512, 512)  # (bc, bf, bd)


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _gate_up_kernel(x_ref, wg_ref, wu_ref, h_ref, g_acc, u_acc, *, nd: int):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        u_acc[...] = jnp.zeros_like(u_acc)

    x = x_ref[0]
    g_acc[...] += jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
    u_acc[...] += jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _epilogue():
        h_ref[0] = (_silu(g_acc[...]) * u_acc[...]).astype(h_ref.dtype)


def _down_kernel(h_ref, wd_ref, y_ref, acc, *, nf: int):
    f = pl.program_id(3)

    @pl.when(f == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(h_ref[0], wd_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f == nf - 1)
    def _write():
        y_ref[0] = acc[...].astype(y_ref.dtype)


def _pick(block: int, dim: int, align: int = 128, itemsize: int = 2) -> int:
    """Largest tile <= ``block`` that divides ``dim``, ``align``-aligned.

    ``align=128`` (lane dims F/D): the tile is the largest multiple-of-128
    divisor (the old halving loop could land on lane-misaligned sizes like
    96 or 192 for non-power-of-two dims); dims with no such divisor are
    only legal as a single whole-dim tile (the compiler pads it), so any
    smaller split asserts. ``align=8`` (the sublane/row dim C): prefer a
    multiple-of-8 tile but fall back to the largest divisor — arbitrary
    capacities (e.g. C=282 from a CF ceil) stay legal as they always were.

    ``itemsize`` is the element byte width of the tensor streamed along
    this dim; the ``block`` budget is calibrated in bf16-equivalent
    elements (itemsize=2), so int8 operands (itemsize=1) get twice the
    rows at the same VMEM byte budget and f32 half. The scaled tile goes
    through the same divisor search, so lane alignment still holds (the
    ``align>=128`` assert below fires on any misaligned split).
    """
    assert itemsize in (1, 2, 4), itemsize
    block = max(1, (block * 2) // itemsize)
    b = min(block, dim)
    for cand in range(b - b % align, 0, -align):
        if dim % cand == 0:
            return cand
    if align >= 128:
        # lane dims: a misaligned tile is only safe when it spans the whole
        # (compiler-padded) dim; any other split straddles lane boundaries
        assert b == dim, (
            f"no {align}-aligned tile <= {block} divides {dim}; pad the dim "
            f"to a multiple of {align} or use a whole-dim block"
        )
        return b
    # row/sublane dim: the compiler pads sublanes, so any divisor is legal
    # (arbitrary capacities like C=282 must not crash) — take the largest
    for cand in range(b, 0, -1):
        if dim % cand == 0:
            return cand
    return 1


def _dot_nt(a, b):
    """(m, k) x (n, k) -> (m, n): contract the last dims (B^T without an
    explicit in-VMEM transpose)."""
    return jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def _dot_tn(a, b):
    """(k, m) x (k, n) -> (m, n): contract the first (row) dims."""
    return jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _expert_fwd_impl(
    xe: jax.Array,  # (E, C, D)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    blocks: Tuple[int, int, int],
    interpret: bool,
) -> jax.Array:
    E, C, D = xe.shape
    F = w_gate.shape[-1]
    bc = _pick(blocks[0], C, align=8)  # row dim: sublane alignment suffices
    bf, bd = (_pick(b, d) for b, d in zip(blocks[1:], (F, D)))
    nc, nf, nd = C // bc, F // bf, D // bd

    h = pl.pallas_call(
        functools.partial(_gate_up_kernel, nd=nd),
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, bd, bf), lambda e, c, f, d: (e, d, f)),
            pl.BlockSpec((1, bd, bf), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), xe.dtype),
        scratch_shapes=[
            pltpu.VMEM((bc, bf), jnp.float32),
            pltpu.VMEM((bc, bf), jnp.float32),
        ],
        interpret=interpret,
    )(xe, w_gate, w_up)

    y = pl.pallas_call(
        functools.partial(_down_kernel, nf=nf),
        grid=(E, nc, nd, nf),
        in_specs=[
            pl.BlockSpec((1, bc, bf), lambda e, c, d, f: (e, c, f)),
            pl.BlockSpec((1, bf, bd), lambda e, c, d, f: (e, f, d)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd), lambda e, c, d, f: (e, c, d)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), xe.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        interpret=interpret,
    )(h, w_down)
    return y


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _expert_gemm_p(xe, w_gate, w_up, w_down, blocks, interpret):
    return _expert_fwd_impl(xe, w_gate, w_up, w_down, blocks, interpret)


def _expert_gemm_fwd(xe, w_gate, w_up, w_down, blocks, interpret):
    y = _expert_fwd_impl(xe, w_gate, w_up, w_down, blocks, interpret)
    # recompute contract: no (E, C, F) SwiGLU intermediate is saved
    return y, (xe, w_gate, w_up, w_down)


def _expert_gemm_bwd(blocks, interpret, res, dy):
    xe, w_gate, w_up, w_down = res
    E, C, D = xe.shape
    # the dense padded buffer IS an exactly-tile-aligned sorted buffer with
    # group_sizes == C; reuse the grouped backward kernels on the flat view
    bc = _pick(blocks[0], C, align=8)
    gs = jnp.full((E,), C, jnp.int32)
    dxs, dwg, dwu, dwd = _grouped_bwd_impl(
        xe.reshape(E * C, D), dy.reshape(E * C, D), w_gate, w_up, w_down,
        gs, (bc,) + tuple(blocks[1:]), interpret,
    )
    return dxs.reshape(E, C, D), dwg, dwu, dwd


_expert_gemm_p.defvjp(_expert_gemm_fwd, _expert_gemm_bwd)


@functools.partial(
    jax.jit, static_argnames=("blocks", "interpret")
)
def expert_gemm(
    xe: jax.Array,  # (E, C, D)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    blocks: Tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    return _expert_gemm_p(xe, w_gate, w_up, w_down, tuple(blocks), interpret)


# ---------------------------------------------------------------------------
# Group-size-aware grouped GEMM over the flat expert-sorted layout
# ---------------------------------------------------------------------------


def _grouped_gate_up_kernel(
    tg_ref, tr_ref, x_ref, wg_ref, wu_ref, h_ref, g_acc, u_acc, *, nd: int,
    bc: int, bf: int,
):
    t, d = pl.program_id(0), pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        u_acc[...] = jnp.zeros_like(u_acc)

    valid = tr_ref[t]

    @pl.when(valid > 0)  # fully-empty tiles (group padding) skip the MXU
    def _compute():
        x = x_ref[...]
        g_acc[...] += jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
        u_acc[...] += jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _epilogue():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bc, bf), 0)
        h = _silu(g_acc[...]) * u_acc[...]
        h_ref[...] = jnp.where(rows < valid, h, 0.0).astype(h_ref.dtype)


def _grouped_down_kernel(
    tg_ref, tr_ref, h_ref, wd_ref, y_ref, acc, *, nf: int, bc: int, bd: int
):
    t, f = pl.program_id(0), pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    valid = tr_ref[t]

    @pl.when(valid > 0)
    def _compute():
        acc[...] += jnp.dot(h_ref[...], wd_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f == nf - 1)
    def _write():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bc, bd), 0)
        y_ref[...] = jnp.where(rows < valid, acc[...], 0.0).astype(y_ref.dtype)


def group_tiling(group_sizes: jax.Array, num_tiles: int, bc: int):
    """Per-row-tile metadata for the tile-aligned expert-sorted buffer:
    (tile_group (nt,) expert id, tile_rows (nt,) valid rows in [0, bc]).
    Tiles past the last group get tile_rows 0 (skipped + masked)."""
    E = group_sizes.shape[0]
    padded = ((group_sizes + bc - 1) // bc) * bc
    ends_pad = jnp.cumsum(padded)
    starts_pad = ends_pad - padded
    tile_start = jnp.arange(num_tiles, dtype=jnp.int32) * bc
    tg = jnp.searchsorted(ends_pad, tile_start, side="right")
    tg = jnp.clip(tg, 0, E - 1).astype(jnp.int32)
    tr = jnp.clip(group_sizes[tg] - (tile_start - starts_pad[tg]), 0, bc)
    return tg, tr.astype(jnp.int32)


def _grouped_fwd_impl(
    xs: jax.Array,  # (N_pad, D) expert-sorted rows, groups row-tile aligned
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    group_sizes: jax.Array,  # (E,) int32 valid rows per expert
    blocks: Tuple[int, int, int],
    interpret: bool,
) -> jax.Array:
    N_pad, D = xs.shape
    E, _, F = w_gate.shape
    bc = blocks[0]
    assert N_pad % bc == 0, (N_pad, bc)
    bf, bd = (_pick(b, d) for b, d in zip(blocks[1:], (F, D)))
    nt, nf, nd = N_pad // bc, F // bf, D // bd
    tg, tr = group_tiling(group_sizes, nt, bc)

    h = pl.pallas_call(
        functools.partial(_grouped_gate_up_kernel, nd=nd, bc=bc, bf=bf),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nt, nf, nd),
            in_specs=[
                pl.BlockSpec((bc, bd), lambda t, f, d, tg, tr: (t, d)),
                pl.BlockSpec((1, bd, bf), lambda t, f, d, tg, tr: (tg[t], d, f)),
                pl.BlockSpec((1, bd, bf), lambda t, f, d, tg, tr: (tg[t], d, f)),
            ],
            out_specs=pl.BlockSpec((bc, bf), lambda t, f, d, tg, tr: (t, f)),
            scratch_shapes=[
                pltpu.VMEM((bc, bf), jnp.float32),
                pltpu.VMEM((bc, bf), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((N_pad, F), xs.dtype),
        interpret=interpret,
    )(tg, tr, xs, w_gate, w_up)

    y = pl.pallas_call(
        functools.partial(_grouped_down_kernel, nf=nf, bc=bc, bd=bd),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nt, nd, nf),
            in_specs=[
                pl.BlockSpec((bc, bf), lambda t, d, f, tg, tr: (t, f)),
                pl.BlockSpec((1, bf, bd), lambda t, d, f, tg, tr: (tg[t], f, d)),
            ],
            out_specs=pl.BlockSpec((bc, bd), lambda t, d, f, tg, tr: (t, d)),
            scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((N_pad, D), xs.dtype),
        interpret=interpret,
    )(tg, tr, h, w_down)
    return y


# ---------------------------------------------------------------------------
# Backward grouped kernels (shared by grouped_gemm and expert_gemm VJPs)
# ---------------------------------------------------------------------------


def _grouped_bwd_dh_kernel(
    tg_ref, tr_ref, x_ref, dy_ref, wg_ref, wu_ref, wd_ref,
    h_ref, dg_ref, du_ref, g_acc, u_acc, dh_acc, *, nd: int, bc: int, bf: int,
):
    """Pass 1: recompute gate/up from x and fuse dh = dy @ w_down^T into the
    same D-contraction grid; the epilogue applies the SwiGLU backward.
    Emits h (for the down wgrad), dg, du — backward transients, never
    forward residuals."""
    t, d = pl.program_id(0), pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        u_acc[...] = jnp.zeros_like(u_acc)
        dh_acc[...] = jnp.zeros_like(dh_acc)

    valid = tr_ref[t]

    @pl.when(valid > 0)
    def _compute():
        x = x_ref[...]
        g_acc[...] += jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
        u_acc[...] += jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
        dh_acc[...] += _dot_nt(dy_ref[...], wd_ref[0])

    @pl.when(d == nd - 1)
    def _epilogue():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bc, bf), 0)
        keep = rows < valid
        g, u, dh = g_acc[...], u_acc[...], dh_acc[...]
        sig = jax.nn.sigmoid(g)
        silu = g * sig
        dsilu = sig * (1.0 + g * (1.0 - sig))
        h_ref[...] = jnp.where(keep, silu * u, 0.0).astype(h_ref.dtype)
        dg_ref[...] = jnp.where(keep, dh * u * dsilu, 0.0).astype(dg_ref.dtype)
        du_ref[...] = jnp.where(keep, dh * silu, 0.0).astype(du_ref.dtype)


def _grouped_bwd_dx_kernel(
    tg_ref, tr_ref, dg_ref, du_ref, wg_ref, wu_ref, dx_ref, acc,
    *, nf: int, bc: int, bd: int,
):
    """Pass 2: dx = dg @ w_gate^T + du @ w_up^T, fused F-contraction."""
    t, f = pl.program_id(0), pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    valid = tr_ref[t]

    @pl.when(valid > 0)
    def _compute():
        acc[...] += _dot_nt(dg_ref[...], wg_ref[0])
        acc[...] += _dot_nt(du_ref[...], wu_ref[0])

    @pl.when(f == nf - 1)
    def _write():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bc, bd), 0)
        dx_ref[...] = jnp.where(rows < valid, acc[...], 0.0).astype(dx_ref.dtype)


def _grouped_bwd_wgrad_kernel(
    tg_ref, tr_ref, x_ref, dy_ref, h_ref, dg_ref, du_ref,
    dwg_ref, dwu_ref, dwd_ref,
):
    """Pass 3: wgrad over the transposed ragged layout. Row tiles are the
    minor grid dim, so each expert's fp32 output block is revisited
    consecutively; it is zero-initialized on expert change and accumulated
    in place (group regions are contiguous in t by construction). Rows past
    a group's valid count contribute nothing because dg/du/h are masked to
    zero in pass 1."""
    t = pl.program_id(2)
    tg_t = tg_ref[t]
    first = jnp.logical_or(t == 0, tg_ref[jnp.maximum(t - 1, 0)] != tg_t)

    @pl.when(first)
    def _init():
        dwg_ref[...] = jnp.zeros_like(dwg_ref)
        dwu_ref[...] = jnp.zeros_like(dwu_ref)
        dwd_ref[...] = jnp.zeros_like(dwd_ref)

    valid = tr_ref[t]

    @pl.when(valid > 0)
    def _compute():
        x, dy = x_ref[...], dy_ref[...]
        dwg_ref[0] += _dot_tn(x, dg_ref[...])   # (bd, bf)
        dwu_ref[0] += _dot_tn(x, du_ref[...])   # (bd, bf)
        dwd_ref[0] += _dot_tn(h_ref[...], dy)   # (bf, bd)


def _grouped_bwd_impl(
    xs: jax.Array,  # (N_pad, D)
    dy: jax.Array,  # (N_pad, D) cotangent of the output
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    group_sizes: jax.Array,  # (E,)
    blocks: Tuple[int, int, int],
    interpret: bool,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    N_pad, D = xs.shape
    E, _, F = w_gate.shape
    bc = blocks[0]
    assert N_pad % bc == 0, (N_pad, bc)
    bf, bd = (_pick(b, d) for b, d in zip(blocks[1:], (F, D)))
    nt, nf, nd = N_pad // bc, F // bf, D // bd
    tg, tr = group_tiling(group_sizes, nt, bc)

    # pass 1: SwiGLU recompute + dh, one fused D-contraction grid
    h, dg, du = pl.pallas_call(
        functools.partial(_grouped_bwd_dh_kernel, nd=nd, bc=bc, bf=bf),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nt, nf, nd),
            in_specs=[
                pl.BlockSpec((bc, bd), lambda t, f, d, tg, tr: (t, d)),
                pl.BlockSpec((bc, bd), lambda t, f, d, tg, tr: (t, d)),
                pl.BlockSpec((1, bd, bf), lambda t, f, d, tg, tr: (tg[t], d, f)),
                pl.BlockSpec((1, bd, bf), lambda t, f, d, tg, tr: (tg[t], d, f)),
                pl.BlockSpec((1, bf, bd), lambda t, f, d, tg, tr: (tg[t], f, d)),
            ],
            out_specs=[
                pl.BlockSpec((bc, bf), lambda t, f, d, tg, tr: (t, f)),
                pl.BlockSpec((bc, bf), lambda t, f, d, tg, tr: (t, f)),
                pl.BlockSpec((bc, bf), lambda t, f, d, tg, tr: (t, f)),
            ],
            scratch_shapes=[
                pltpu.VMEM((bc, bf), jnp.float32),
                pltpu.VMEM((bc, bf), jnp.float32),
                pltpu.VMEM((bc, bf), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((N_pad, F), xs.dtype),
            jax.ShapeDtypeStruct((N_pad, F), xs.dtype),
            jax.ShapeDtypeStruct((N_pad, F), xs.dtype),
        ],
        interpret=interpret,
    )(tg, tr, xs, dy, w_gate, w_up, w_down)

    # pass 2: dx
    dx = pl.pallas_call(
        functools.partial(_grouped_bwd_dx_kernel, nf=nf, bc=bc, bd=bd),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nt, nd, nf),
            in_specs=[
                pl.BlockSpec((bc, bf), lambda t, d, f, tg, tr: (t, f)),
                pl.BlockSpec((bc, bf), lambda t, d, f, tg, tr: (t, f)),
                pl.BlockSpec((1, bd, bf), lambda t, d, f, tg, tr: (tg[t], d, f)),
                pl.BlockSpec((1, bd, bf), lambda t, d, f, tg, tr: (tg[t], d, f)),
            ],
            out_specs=pl.BlockSpec((bc, bd), lambda t, d, f, tg, tr: (t, d)),
            scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((N_pad, D), xs.dtype),
        interpret=interpret,
    )(tg, tr, dg, du, w_gate, w_up)

    # pass 3: wgrad, fp32 accumulation directly in the per-expert out blocks
    dwg, dwu, dwd = pl.pallas_call(
        _grouped_bwd_wgrad_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nd, nf, nt),
            in_specs=[
                pl.BlockSpec((bc, bd), lambda d, f, t, tg, tr: (t, d)),
                pl.BlockSpec((bc, bd), lambda d, f, t, tg, tr: (t, d)),
                pl.BlockSpec((bc, bf), lambda d, f, t, tg, tr: (t, f)),
                pl.BlockSpec((bc, bf), lambda d, f, t, tg, tr: (t, f)),
                pl.BlockSpec((bc, bf), lambda d, f, t, tg, tr: (t, f)),
            ],
            out_specs=[
                pl.BlockSpec((1, bd, bf), lambda d, f, t, tg, tr: (tg[t], d, f)),
                pl.BlockSpec((1, bd, bf), lambda d, f, t, tg, tr: (tg[t], d, f)),
                pl.BlockSpec((1, bf, bd), lambda d, f, t, tg, tr: (tg[t], f, d)),
            ],
            scratch_shapes=[],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((E, D, F), jnp.float32),
            jax.ShapeDtypeStruct((E, D, F), jnp.float32),
            jax.ShapeDtypeStruct((E, F, D), jnp.float32),
        ],
        interpret=interpret,
    )(tg, tr, xs, dy, h, dg, du)

    # experts with zero rows own no tile: their output blocks were never
    # visited (HBM garbage) and their true wgrad is zero — mask them
    live = (group_sizes > 0)[:, None, None]
    dwg = jnp.where(live, dwg, 0.0).astype(w_gate.dtype)
    dwu = jnp.where(live, dwu, 0.0).astype(w_up.dtype)
    dwd = jnp.where(live, dwd, 0.0).astype(w_down.dtype)
    return dx, dwg, dwu, dwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _grouped_gemm_p(xs, w_gate, w_up, w_down, group_sizes, blocks, interpret):
    return _grouped_fwd_impl(xs, w_gate, w_up, w_down, group_sizes, blocks, interpret)


def _grouped_gemm_fwd(xs, w_gate, w_up, w_down, group_sizes, blocks, interpret):
    y = _grouped_fwd_impl(xs, w_gate, w_up, w_down, group_sizes, blocks, interpret)
    # recompute contract: residuals are O(N*D) inputs only — the (N, F)
    # gate/up/h intermediates are rebuilt inside the backward kernels
    return y, (xs, w_gate, w_up, w_down, group_sizes)


def _grouped_gemm_bwd(blocks, interpret, res, dy):
    xs, w_gate, w_up, w_down, group_sizes = res
    dx, dwg, dwu, dwd = _grouped_bwd_impl(
        xs, dy, w_gate, w_up, w_down, group_sizes, blocks, interpret
    )
    return dx, dwg, dwu, dwd, None  # int group_sizes: zero cotangent


_grouped_gemm_p.defvjp(_grouped_gemm_fwd, _grouped_gemm_bwd)


def grouped_gemm_residuals(xs, w_gate, w_up, w_down, group_sizes,
                           blocks: Tuple[int, int, int] = DEFAULT_BLOCKS):
    """Shape-only view of what the VJP forward saves for backward (the
    recompute contract checked by tests and the kernel bench): inputs only,
    never an (N, F) intermediate."""
    res = jax.eval_shape(
        lambda *a: _grouped_gemm_fwd(*a, tuple(blocks), True)[1],
        xs, w_gate, w_up, w_down, group_sizes,
    )
    return jax.tree.leaves(res)


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def grouped_gemm(
    xs: jax.Array,  # (N_pad, D) expert-sorted rows, groups row-tile aligned
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    group_sizes: jax.Array,  # (E,) int32 valid rows per expert
    blocks: Tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    return _grouped_gemm_p(
        xs, w_gate, w_up, w_down, group_sizes, tuple(blocks), interpret
    )


# ---------------------------------------------------------------------------
# int8 weights with dequant fused into the tile (serving/inference only)
#
# Weights are symmetric per-expert per-output-channel int8 (core/quant.py):
# gate/up scales over F, down over D. The scale is constant along the
# contraction dim, so dequant commutes with the matmul — the kernels load
# int8 tiles (half the HBM traffic of bf16), cast to the activation dtype
# for the MXU (int8 values are exact in bf16), accumulate in fp32, and
# multiply by the scale tile once in the epilogue. Mathematically identical
# to dequantize-then-matmul; fused SwiGLU and row masking are unchanged
# from the bf16 kernels above. The int8 contraction dim gets a 2x-rows
# weight tile at the same VMEM byte budget via _pick(itemsize=1); fp32
# accumulator tiles keep their bf16-path sizes. Forward-only: the PR 2
# custom_vjp backward kernels stay bf16.
# ---------------------------------------------------------------------------


def _gate_up_kernel_q8(
    x_ref, wg_ref, wu_ref, sg_ref, su_ref, h_ref, g_acc, u_acc, *, nd: int
):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        u_acc[...] = jnp.zeros_like(u_acc)

    x = x_ref[0]
    g_acc[...] += jnp.dot(x, wg_ref[0].astype(x.dtype), preferred_element_type=jnp.float32)
    u_acc[...] += jnp.dot(x, wu_ref[0].astype(x.dtype), preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _epilogue():
        g = g_acc[...] * sg_ref[0].astype(jnp.float32)
        u = u_acc[...] * su_ref[0].astype(jnp.float32)
        h_ref[0] = (_silu(g) * u).astype(h_ref.dtype)


def _down_kernel_q8(h_ref, wd_ref, sd_ref, y_ref, acc, *, nf: int):
    f = pl.program_id(3)

    @pl.when(f == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(
        h_ref[0], wd_ref[0].astype(h_ref.dtype), preferred_element_type=jnp.float32
    )

    @pl.when(f == nf - 1)
    def _write():
        y_ref[0] = (acc[...] * sd_ref[0].astype(jnp.float32)).astype(y_ref.dtype)


def _expert_fwd_q8_impl(
    xe: jax.Array,  # (E, C, D)
    w_gate: jax.Array,  # (E, D, F) int8
    w_up: jax.Array,  # (E, D, F) int8
    w_down: jax.Array,  # (E, F, D) int8
    s_gate: jax.Array,  # (E, F) bf16 per-output-channel scales
    s_up: jax.Array,  # (E, F)
    s_down: jax.Array,  # (E, D)
    blocks: Tuple[int, int, int],
    interpret: bool,
) -> jax.Array:
    E, C, D = xe.shape
    F = w_gate.shape[-1]
    bc = _pick(blocks[0], C, align=8)
    # output tiles size the fp32 accumulators -> bf16-equivalent budget;
    # int8 contraction tiles stream 2x the rows at the same byte budget
    bf_o, bd_o = _pick(blocks[1], F), _pick(blocks[2], D)
    bd_c = _pick(blocks[2], D, itemsize=1)
    bf_c = _pick(blocks[1], F, itemsize=1)
    nc = C // bc

    h = pl.pallas_call(
        functools.partial(_gate_up_kernel_q8, nd=D // bd_c),
        grid=(E, nc, F // bf_o, D // bd_c),
        in_specs=[
            pl.BlockSpec((1, bc, bd_c), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, bd_c, bf_o), lambda e, c, f, d: (e, d, f)),
            pl.BlockSpec((1, bd_c, bf_o), lambda e, c, f, d: (e, d, f)),
            pl.BlockSpec((1, bf_o), lambda e, c, f, d: (e, f)),
            pl.BlockSpec((1, bf_o), lambda e, c, f, d: (e, f)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf_o), lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), xe.dtype),
        scratch_shapes=[
            pltpu.VMEM((bc, bf_o), jnp.float32),
            pltpu.VMEM((bc, bf_o), jnp.float32),
        ],
        interpret=interpret,
    )(xe, w_gate, w_up, s_gate, s_up)

    y = pl.pallas_call(
        functools.partial(_down_kernel_q8, nf=F // bf_c),
        grid=(E, nc, D // bd_o, F // bf_c),
        in_specs=[
            pl.BlockSpec((1, bc, bf_c), lambda e, c, d, f: (e, c, f)),
            pl.BlockSpec((1, bf_c, bd_o), lambda e, c, d, f: (e, f, d)),
            pl.BlockSpec((1, bd_o), lambda e, c, d, f: (e, d)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd_o), lambda e, c, d, f: (e, c, d)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), xe.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd_o), jnp.float32)],
        interpret=interpret,
    )(h, w_down, s_down)
    return y


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def expert_gemm_q8(
    xe: jax.Array,  # (E, C, D)
    w_gate: jax.Array,  # (E, D, F) int8
    w_up: jax.Array,  # (E, D, F) int8
    w_down: jax.Array,  # (E, F, D) int8
    s_gate: jax.Array,  # (E, F)
    s_up: jax.Array,  # (E, F)
    s_down: jax.Array,  # (E, D)
    blocks: Tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    return _expert_fwd_q8_impl(
        xe, w_gate, w_up, w_down, s_gate, s_up, s_down, tuple(blocks), interpret
    )


def _grouped_gate_up_kernel_q8(
    tg_ref, tr_ref, x_ref, wg_ref, wu_ref, sg_ref, su_ref, h_ref, g_acc, u_acc,
    *, nd: int, bc: int, bf: int,
):
    t, d = pl.program_id(0), pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        u_acc[...] = jnp.zeros_like(u_acc)

    valid = tr_ref[t]

    @pl.when(valid > 0)
    def _compute():
        x = x_ref[...]
        g_acc[...] += jnp.dot(x, wg_ref[0].astype(x.dtype), preferred_element_type=jnp.float32)
        u_acc[...] += jnp.dot(x, wu_ref[0].astype(x.dtype), preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _epilogue():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bc, bf), 0)
        g = g_acc[...] * sg_ref[0].astype(jnp.float32)
        u = u_acc[...] * su_ref[0].astype(jnp.float32)
        h = _silu(g) * u
        h_ref[...] = jnp.where(rows < valid, h, 0.0).astype(h_ref.dtype)


def _grouped_down_kernel_q8(
    tg_ref, tr_ref, h_ref, wd_ref, sd_ref, y_ref, acc, *, nf: int, bc: int, bd: int
):
    t, f = pl.program_id(0), pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    valid = tr_ref[t]

    @pl.when(valid > 0)
    def _compute():
        acc[...] += jnp.dot(
            h_ref[...], wd_ref[0].astype(h_ref.dtype), preferred_element_type=jnp.float32
        )

    @pl.when(f == nf - 1)
    def _write():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bc, bd), 0)
        y = acc[...] * sd_ref[0].astype(jnp.float32)
        y_ref[...] = jnp.where(rows < valid, y, 0.0).astype(y_ref.dtype)


def _grouped_fwd_q8_impl(
    xs: jax.Array,  # (N_pad, D) expert-sorted rows, groups row-tile aligned
    w_gate: jax.Array,  # (E, D, F) int8
    w_up: jax.Array,  # (E, D, F) int8
    w_down: jax.Array,  # (E, F, D) int8
    s_gate: jax.Array,  # (E, F)
    s_up: jax.Array,  # (E, F)
    s_down: jax.Array,  # (E, D)
    group_sizes: jax.Array,  # (E,) int32 valid rows per expert
    blocks: Tuple[int, int, int],
    interpret: bool,
) -> jax.Array:
    N_pad, D = xs.shape
    E, _, F = w_gate.shape
    bc = blocks[0]
    assert N_pad % bc == 0, (N_pad, bc)
    bf_o, bd_o = _pick(blocks[1], F), _pick(blocks[2], D)
    bd_c = _pick(blocks[2], D, itemsize=1)
    bf_c = _pick(blocks[1], F, itemsize=1)
    nt = N_pad // bc
    tg, tr = group_tiling(group_sizes, nt, bc)

    h = pl.pallas_call(
        functools.partial(
            _grouped_gate_up_kernel_q8, nd=D // bd_c, bc=bc, bf=bf_o
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nt, F // bf_o, D // bd_c),
            in_specs=[
                pl.BlockSpec((bc, bd_c), lambda t, f, d, tg, tr: (t, d)),
                pl.BlockSpec((1, bd_c, bf_o), lambda t, f, d, tg, tr: (tg[t], d, f)),
                pl.BlockSpec((1, bd_c, bf_o), lambda t, f, d, tg, tr: (tg[t], d, f)),
                pl.BlockSpec((1, bf_o), lambda t, f, d, tg, tr: (tg[t], f)),
                pl.BlockSpec((1, bf_o), lambda t, f, d, tg, tr: (tg[t], f)),
            ],
            out_specs=pl.BlockSpec((bc, bf_o), lambda t, f, d, tg, tr: (t, f)),
            scratch_shapes=[
                pltpu.VMEM((bc, bf_o), jnp.float32),
                pltpu.VMEM((bc, bf_o), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((N_pad, F), xs.dtype),
        interpret=interpret,
    )(tg, tr, xs, w_gate, w_up, s_gate, s_up)

    y = pl.pallas_call(
        functools.partial(_grouped_down_kernel_q8, nf=F // bf_c, bc=bc, bd=bd_o),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(nt, D // bd_o, F // bf_c),
            in_specs=[
                pl.BlockSpec((bc, bf_c), lambda t, d, f, tg, tr: (t, f)),
                pl.BlockSpec((1, bf_c, bd_o), lambda t, d, f, tg, tr: (tg[t], f, d)),
                pl.BlockSpec((1, bd_o), lambda t, d, f, tg, tr: (tg[t], d)),
            ],
            out_specs=pl.BlockSpec((bc, bd_o), lambda t, d, f, tg, tr: (t, d)),
            scratch_shapes=[pltpu.VMEM((bc, bd_o), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((N_pad, D), xs.dtype),
        interpret=interpret,
    )(tg, tr, h, w_down, s_down)
    return y


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def grouped_gemm_q8(
    xs: jax.Array,  # (N_pad, D) expert-sorted rows, groups row-tile aligned
    w_gate: jax.Array,  # (E, D, F) int8
    w_up: jax.Array,  # (E, D, F) int8
    w_down: jax.Array,  # (E, F, D) int8
    s_gate: jax.Array,  # (E, F)
    s_up: jax.Array,  # (E, F)
    s_down: jax.Array,  # (E, D)
    group_sizes: jax.Array,  # (E,) int32 valid rows per expert
    blocks: Tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    return _grouped_fwd_q8_impl(
        xs, w_gate, w_up, w_down, s_gate, s_up, s_down, group_sizes,
        tuple(blocks), interpret,
    )


# ---------------------------------------------------------------------------
# Fused dispatch: gather + combine folded into the grouped-GEMM grid
#
# The sorted dispatcher's dispatch -> grouped_gemm -> combine pipeline costs
# two extra HBM round-trips per MoE layer: the permuted (N_pad, D) scatter
# buffer before the GEMM and the (N, D) gathered/gate-weighted output after
# it. The fused kernels absorb both, the same scalar-prefetch block-table
# trick as paged_attention:
#
# * prologue gather: the per-row token ids (``tok``) are scalar-prefetched
#   and resolved in the x BlockSpec index map — an extra innermost grid dim
#   ``r`` stages one (1, bd) row of the token-major x per step into a
#   (bc, bd) VMEM scratch, and the gate/up dot fires once per (t, f, d) at
#   r == bc-1. HBM read traffic equals the unfused kernel's reads of the
#   materialized buffer; the buffer's write+read round trip disappears.
# * epilogue combine: the down kernel writes each row gate-weighted (fp32
#   multiply) straight to a slot-partials output shaped (k*T + 1, D) at
#   scalar-prefetched ``row_out`` = slot*T + token. Each (token, slot) pair
#   is unique in the top-k assignment list, so every partials row is
#   written exactly once — a race-free scatter with no atomics; padding
#   rows and non-final-f grid steps land on the trash row k*T. The k slot
#   planes are summed in fp32 outside the kernel (the per-token k-way
#   combine), matching the fp32-accum convention.
#
# Backward: custom_vjp with inputs-only residuals. The cotangent is pulled
# through ``jax.vjp`` of the UNFUSED composition (scatter -> grouped_gemm,
# whose own VJP recomputes SwiGLU -> gather/gate/scatter-add), so fused
# gradients agree with the unfused sorted dispatcher by construction and
# nothing O(N*F) — and no (N_pad, D) buffer — is saved across fwd/bwd.
# ---------------------------------------------------------------------------

_TRASH = -1  # sentinel resolved to the k*T trash row at call sites


def _fused_gate_up_kernel(
    tg_ref, tr_ref, tok_ref, x_ref, wg_ref, wu_ref, h_ref, x_scr, g_acc, u_acc,
    *, nd: int, bc: int, bf: int,
):
    """Gather prologue + gate/up: grid (nt, nf, nd, bc). Each r-step DMAs
    row tok[t*bc + r] of the token-major x (resolved in the BlockSpec index
    map) into the staging scratch; the MXU work runs once per (t, f, d)."""
    t, d, r = pl.program_id(0), pl.program_id(2), pl.program_id(3)
    x_scr[pl.ds(r, 1), :] = x_ref[0][None]
    last = r == bc - 1

    @pl.when(jnp.logical_and(last, d == 0))
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        u_acc[...] = jnp.zeros_like(u_acc)

    valid = tr_ref[t]

    @pl.when(jnp.logical_and(last, valid > 0))
    def _compute():
        x = x_scr[...]
        g_acc[...] += jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
        u_acc[...] += jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(last, d == nd - 1))
    def _epilogue():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bc, bf), 0)
        h = _silu(g_acc[...]) * u_acc[...]
        h_ref[...] = jnp.where(rows < valid, h, 0.0).astype(h_ref.dtype)


def _fused_down_kernel(
    tg_ref, tr_ref, row_ref, gate_ref, h_ref, wd_ref, o_ref, acc,
    *, nf: int, bc: int,
):
    """Down GEMM + combine epilogue: grid (nt, nd, nf, bc). The F
    contraction accumulates once per (t, d, f) at r == 0; at f == nf-1
    every r-step emits one gate-weighted row to its slot-partials slot
    (the out BlockSpec routes non-final-f steps and padding rows to the
    trash row)."""
    t, f, r = pl.program_id(0), pl.program_id(2), pl.program_id(3)

    @pl.when(jnp.logical_and(r == 0, f == 0))
    def _init():
        acc[...] = jnp.zeros_like(acc)

    valid = tr_ref[t]

    @pl.when(jnp.logical_and(r == 0, valid > 0))
    def _compute():
        acc[...] += jnp.dot(h_ref[...], wd_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f == nf - 1)
    def _write():
        g = gate_ref[t * bc + r]  # f32 scalar from SMEM
        row = acc[pl.ds(r, 1), :][0] * g
        o_ref[0] = jnp.where(r < valid, row, 0.0).astype(o_ref.dtype)


def _fused_down_kernel_q8(
    tg_ref, tr_ref, row_ref, gate_ref, h_ref, wd_ref, sd_ref, o_ref, acc,
    *, nf: int, bc: int,
):
    t, f, r = pl.program_id(0), pl.program_id(2), pl.program_id(3)

    @pl.when(jnp.logical_and(r == 0, f == 0))
    def _init():
        acc[...] = jnp.zeros_like(acc)

    valid = tr_ref[t]

    @pl.when(jnp.logical_and(r == 0, valid > 0))
    def _compute():
        acc[...] += jnp.dot(
            h_ref[...], wd_ref[0].astype(h_ref.dtype),
            preferred_element_type=jnp.float32,
        )

    @pl.when(f == nf - 1)
    def _write():
        g = gate_ref[t * bc + r]
        row = acc[pl.ds(r, 1), :][0] * sd_ref[0].astype(jnp.float32) * g
        o_ref[0] = jnp.where(r < valid, row, 0.0).astype(o_ref.dtype)


def _fused_gate_up_kernel_q8(
    tg_ref, tr_ref, tok_ref, x_ref, wg_ref, wu_ref, sg_ref, su_ref, h_ref,
    x_scr, g_acc, u_acc, *, nd: int, bc: int, bf: int,
):
    t, d, r = pl.program_id(0), pl.program_id(2), pl.program_id(3)
    x_scr[pl.ds(r, 1), :] = x_ref[0][None]
    last = r == bc - 1

    @pl.when(jnp.logical_and(last, d == 0))
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        u_acc[...] = jnp.zeros_like(u_acc)

    valid = tr_ref[t]

    @pl.when(jnp.logical_and(last, valid > 0))
    def _compute():
        x = x_scr[...]
        g_acc[...] += jnp.dot(x, wg_ref[0].astype(x.dtype), preferred_element_type=jnp.float32)
        u_acc[...] += jnp.dot(x, wu_ref[0].astype(x.dtype), preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(last, d == nd - 1))
    def _epilogue():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bc, bf), 0)
        g = g_acc[...] * sg_ref[0].astype(jnp.float32)
        u = u_acc[...] * su_ref[0].astype(jnp.float32)
        h = _silu(g) * u
        h_ref[...] = jnp.where(rows < valid, h, 0.0).astype(h_ref.dtype)


def _aligned_rows(N: int, E: int, row_block: int) -> int:
    """Static worst-case rows of the (never materialized) sorted buffer —
    mirrors core.dispatch.sorted.aligned_rows without importing the
    dispatch subsystem into the kernel layer."""
    if row_block <= 1:
        return N
    return -(-(N + E * (row_block - 1)) // row_block) * row_block


def _fused_prefetch(token, dest, slot, gate_sorted, T, N_pad):
    """Scalar-prefetch vectors indexed by buffer row: source token id
    (padding rows -> 0, masked by tr), slot-partials destination row
    (padding rows -> the k*T trash row), and f32 gate per row."""
    N = token.shape[0]
    k = N // T
    tok_pad = jnp.zeros((N_pad,), jnp.int32).at[dest].set(token.astype(jnp.int32))
    row_out = jnp.full((N_pad,), k * T, jnp.int32).at[dest].set(
        slot.astype(jnp.int32) * T + token.astype(jnp.int32)
    )
    gate_pad = jnp.zeros((N_pad,), jnp.float32).at[dest].set(
        gate_sorted.astype(jnp.float32)
    )
    return tok_pad, row_out, gate_pad


def _fused_fwd_impl(
    x: jax.Array,  # (T, D) token-major model activations
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    group_sizes: jax.Array,  # (E,)
    token: jax.Array,  # (N,) source token per sorted row
    dest: jax.Array,  # (N,) buffer row per sorted assignment
    slot: jax.Array,  # (N,) top-k slot per sorted row (order % k)
    gate_sorted: jax.Array,  # (N,) combine gate per sorted row
    blocks: Tuple[int, int, int],
    interpret: bool,
) -> jax.Array:
    T, D = x.shape
    E, _, F = w_gate.shape
    N = token.shape[0]
    assert N % T == 0, (N, T)
    k = N // T
    bc = blocks[0]
    N_pad = _aligned_rows(N, E, bc)
    bf, bd = (_pick(b, d) for b, d in zip(blocks[1:], (F, D)))
    nt, nf, nd = N_pad // bc, F // bf, D // bd
    tg, tr = group_tiling(group_sizes, nt, bc)
    tok_pad, row_out, gate_pad = _fused_prefetch(token, dest, slot, gate_sorted, T, N_pad)

    h = pl.pallas_call(
        functools.partial(_fused_gate_up_kernel, nd=nd, bc=bc, bf=bf),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(nt, nf, nd, bc),
            in_specs=[
                pl.BlockSpec(
                    (1, bd), lambda t, f, d, r, tg, tr, tok: (tok[t * bc + r], d)
                ),
                pl.BlockSpec((1, bd, bf), lambda t, f, d, r, tg, tr, tok: (tg[t], d, f)),
                pl.BlockSpec((1, bd, bf), lambda t, f, d, r, tg, tr, tok: (tg[t], d, f)),
            ],
            out_specs=pl.BlockSpec((bc, bf), lambda t, f, d, r, tg, tr, tok: (t, f)),
            scratch_shapes=[
                pltpu.VMEM((bc, bd), x.dtype),
                pltpu.VMEM((bc, bf), jnp.float32),
                pltpu.VMEM((bc, bf), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((N_pad, F), x.dtype),
        interpret=interpret,
    )(tg, tr, tok_pad, x, w_gate, w_up)

    trash = k * T
    partials = pl.pallas_call(
        functools.partial(_fused_down_kernel, nf=nf, bc=bc),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(nt, nd, nf, bc),
            in_specs=[
                pl.BlockSpec((bc, bf), lambda t, d, f, r, tg, tr, ro, ga: (t, f)),
                pl.BlockSpec(
                    (1, bf, bd), lambda t, d, f, r, tg, tr, ro, ga: (tg[t], f, d)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, bd),
                lambda t, d, f, r, tg, tr, ro, ga: (
                    jnp.where(f == nf - 1, ro[t * bc + r], trash), d
                ),
            ),
            scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((k * T + 1, D), x.dtype),
        interpret=interpret,
    )(tg, tr, row_out, gate_pad, h, w_down)

    # k-way per-token combine: fp32 sum over the slot planes, cast once
    y = jnp.sum(partials[: k * T].reshape(k, T, D).astype(jnp.float32), axis=0)
    return y.astype(x.dtype)


def _fused_unfused_ref(
    x, w_gate, w_up, w_down, group_sizes, token, dest, slot, gate_sorted,
    blocks, interpret,
):
    """The unfused sorted-dispatcher composition the fused path replaces:
    scatter into the tile-aligned buffer -> grouped_gemm (Pallas custom_vjp
    with SwiGLU recompute) -> gather + fp32 gate-weighted scatter-add. Used
    as the backward graph so fused grads match the unfused path exactly."""
    T, D = x.shape
    E = w_gate.shape[0]
    N = token.shape[0]
    N_pad = _aligned_rows(N, E, blocks[0])
    xs = jnp.zeros((N_pad, D), x.dtype).at[dest].set(x[token])
    ys = _grouped_gemm_p(xs, w_gate, w_up, w_down, group_sizes, blocks, interpret)
    yv = ys[dest].astype(jnp.float32) * gate_sorted.astype(jnp.float32)[:, None]
    return jnp.zeros((T, D), jnp.float32).at[token].add(yv).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(9, 10))
def _fused_moe_p(
    x, w_gate, w_up, w_down, group_sizes, token, dest, slot, gate_sorted,
    blocks, interpret,
):
    return _fused_fwd_impl(
        x, w_gate, w_up, w_down, group_sizes, token, dest, slot, gate_sorted,
        blocks, interpret,
    )


def _fused_moe_fwd(
    x, w_gate, w_up, w_down, group_sizes, token, dest, slot, gate_sorted,
    blocks, interpret,
):
    y = _fused_fwd_impl(
        x, w_gate, w_up, w_down, group_sizes, token, dest, slot, gate_sorted,
        blocks, interpret,
    )
    # inputs-only residuals: no (N_pad, D) buffer, no (N, F) intermediate
    return y, (x, w_gate, w_up, w_down, group_sizes, token, dest, slot, gate_sorted)


def _fused_moe_bwd(blocks, interpret, res, dy):
    x, w_gate, w_up, w_down, group_sizes, token, dest, slot, gate_sorted = res
    _, vjp = jax.vjp(
        lambda x, wg, wu, wd, g: _fused_unfused_ref(
            x, wg, wu, wd, group_sizes, token, dest, slot, g, blocks, interpret
        ),
        x, w_gate, w_up, w_down, gate_sorted,
    )
    dx, dwg, dwu, dwd, dgate = vjp(dy)
    return dx, dwg, dwu, dwd, None, None, None, None, dgate


_fused_moe_p.defvjp(_fused_moe_fwd, _fused_moe_bwd)


def fused_moe_residuals(x, w_gate, w_up, w_down, group_sizes, token, dest,
                        slot, gate_sorted,
                        blocks: Tuple[int, int, int] = DEFAULT_BLOCKS):
    """Shape-only view of the fused VJP residuals (the bench/test contract):
    token-major inputs and O(N) index vectors only — never the (N_pad, D)
    dispatch buffer or an (N, F) intermediate."""
    res = jax.eval_shape(
        lambda *a: _fused_moe_fwd(*a, tuple(blocks), True)[1],
        x, w_gate, w_up, w_down, group_sizes, token, dest, slot, gate_sorted,
    )
    return jax.tree.leaves(res)


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def grouped_gemm_fused(
    x: jax.Array,  # (T, D) token-major activations (pre-dispatch)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    group_sizes: jax.Array,  # (E,) valid rows per expert
    token: jax.Array,  # (N,) source token id per sorted row (order // k)
    dest: jax.Array,  # (N,) tile-aligned buffer row per sorted row
    slot: jax.Array,  # (N,) top-k slot per sorted row (order % k)
    gate_sorted: jax.Array,  # (N,) combine gate per sorted row
    blocks: Tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    """Dispatch-in-kernel sorted MoE FFN: (T, D) -> (T, D) with the token
    gather in the prologue and the gate-weighted combine in the epilogue —
    the permuted (N_pad, D) buffer and the (N, D) gathered output never
    exist in HBM. Differentiable (fused fwd, unfused-recompute bwd)."""
    return _fused_moe_p(
        x, w_gate, w_up, w_down, group_sizes, token, dest, slot, gate_sorted,
        tuple(blocks), interpret,
    )


def _fused_fwd_q8_impl(
    x, w_gate, w_up, w_down, s_gate, s_up, s_down, group_sizes,
    token, dest, slot, gate_sorted, blocks, interpret,
):
    T, D = x.shape
    E, _, F = w_gate.shape
    N = token.shape[0]
    assert N % T == 0, (N, T)
    k = N // T
    bc = blocks[0]
    N_pad = _aligned_rows(N, E, bc)
    bf_o, bd_o = _pick(blocks[1], F), _pick(blocks[2], D)
    bd_c = _pick(blocks[2], D, itemsize=1)
    bf_c = _pick(blocks[1], F, itemsize=1)
    nt = N_pad // bc
    tg, tr = group_tiling(group_sizes, nt, bc)
    tok_pad, row_out, gate_pad = _fused_prefetch(token, dest, slot, gate_sorted, T, N_pad)

    h = pl.pallas_call(
        functools.partial(
            _fused_gate_up_kernel_q8, nd=D // bd_c, bc=bc, bf=bf_o
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(nt, F // bf_o, D // bd_c, bc),
            in_specs=[
                pl.BlockSpec(
                    (1, bd_c), lambda t, f, d, r, tg, tr, tok: (tok[t * bc + r], d)
                ),
                pl.BlockSpec((1, bd_c, bf_o), lambda t, f, d, r, tg, tr, tok: (tg[t], d, f)),
                pl.BlockSpec((1, bd_c, bf_o), lambda t, f, d, r, tg, tr, tok: (tg[t], d, f)),
                pl.BlockSpec((1, bf_o), lambda t, f, d, r, tg, tr, tok: (tg[t], f)),
                pl.BlockSpec((1, bf_o), lambda t, f, d, r, tg, tr, tok: (tg[t], f)),
            ],
            out_specs=pl.BlockSpec((bc, bf_o), lambda t, f, d, r, tg, tr, tok: (t, f)),
            scratch_shapes=[
                pltpu.VMEM((bc, bd_c), x.dtype),
                pltpu.VMEM((bc, bf_o), jnp.float32),
                pltpu.VMEM((bc, bf_o), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((N_pad, F), x.dtype),
        interpret=interpret,
    )(tg, tr, tok_pad, x, w_gate, w_up, s_gate, s_up)

    trash = k * T
    nf_c = F // bf_c
    partials = pl.pallas_call(
        functools.partial(_fused_down_kernel_q8, nf=nf_c, bc=bc),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(nt, D // bd_o, nf_c, bc),
            in_specs=[
                pl.BlockSpec((bc, bf_c), lambda t, d, f, r, tg, tr, ro, ga: (t, f)),
                pl.BlockSpec(
                    (1, bf_c, bd_o), lambda t, d, f, r, tg, tr, ro, ga: (tg[t], f, d)
                ),
                pl.BlockSpec((1, bd_o), lambda t, d, f, r, tg, tr, ro, ga: (tg[t], d)),
            ],
            out_specs=pl.BlockSpec(
                (1, bd_o),
                lambda t, d, f, r, tg, tr, ro, ga: (
                    jnp.where(f == nf_c - 1, ro[t * bc + r], trash), d
                ),
            ),
            scratch_shapes=[pltpu.VMEM((bc, bd_o), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((k * T + 1, D), x.dtype),
        interpret=interpret,
    )(tg, tr, row_out, gate_pad, h, w_down, s_down)

    y = jnp.sum(partials[: k * T].reshape(k, T, D).astype(jnp.float32), axis=0)
    return y.astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def grouped_gemm_fused_q8(
    x: jax.Array,  # (T, D) token-major activations (pre-dispatch)
    w_gate: jax.Array,  # (E, D, F) int8
    w_up: jax.Array,  # (E, D, F) int8
    w_down: jax.Array,  # (E, F, D) int8
    s_gate: jax.Array,  # (E, F)
    s_up: jax.Array,  # (E, F)
    s_down: jax.Array,  # (E, D)
    group_sizes: jax.Array,  # (E,)
    token: jax.Array,  # (N,)
    dest: jax.Array,  # (N,)
    slot: jax.Array,  # (N,)
    gate_sorted: jax.Array,  # (N,)
    blocks: Tuple[int, int, int] = DEFAULT_BLOCKS,
    interpret: bool = False,
) -> jax.Array:
    """int8-weight fused-dispatch sorted MoE FFN (serving): fused dequant,
    gather prologue, gate-weighted combine epilogue. Forward-only, like
    :func:`grouped_gemm_q8`."""
    return _fused_fwd_q8_impl(
        x, w_gate, w_up, w_down, s_gate, s_up, s_down, group_sizes,
        token, dest, slot, gate_sorted, tuple(blocks), interpret,
    )
