"""Pallas TPU paged-attention decode kernel.

Single-token decode against a block-table KV cache: the KV pool lives in HBM
as ``(num_pages, page_size, KV, d)`` shared by every sequence, and each
sequence owns an ordered page list ``block_table[b]`` (logical slot ``j``
maps to ``pool[block_table[b, j // page_size], j % page_size]`` — identity
position mapping, pages never wrap).

The gather happens *inside* the kernel: ``block_table`` and ``seq_lens`` are
scalar-prefetched (``PrefetchScalarGridSpec``) so the BlockSpec index map
resolves the physical page for grid step ``(b, kv, j)`` before the body
runs, and the pipeline DMAs exactly one ``(page_size, d)`` KV tile per step
— no ``(B, max_pages * page_size, KV, d)`` gathered copy is ever
materialized in HBM (the XLA reference path in ``kernels/ref.py`` does
materialize it; that is the memory trade this kernel exists to avoid).

GQA: the grid iterates KV heads and each step computes all ``G = H // KV``
query heads that share the KV head, so the pool is read once per KV head.
Softmax is the standard logsumexp-stable online update with fp32 ``m/l/acc``
carried in VMEM scratch across the page dimension (innermost grid axis).

Dead pages are skipped: ``block_table`` entries of -1 (unallocated, or
released because a sliding window moved past them) and pages at or past
``seq_lens[b]`` cost no compute or DMA-decode bandwidth beyond the (tiny)
scalar test.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(
    bt_ref, len_ref,  # scalar-prefetched: (B, maxP) page ids, (B,) lengths
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, ps: int, maxP: int, bps: int, nsub: int,
    window: Optional[int], scale: float,
):
    # innermost grid axis walks sub-page tiles: step j covers rows
    # [js*bps, (js+1)*bps) of page jp (bps == ps -> one step per page)
    b, j = pl.program_id(0), pl.program_id(2)
    jp, js = j // nsub, j % nsub
    start = jp * ps + js * bps  # logical position of the tile's first row

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    page = bt_ref[b, jp]
    n = len_ref[b]  # valid tokens incl. the current one; query pos = n - 1
    live = jnp.logical_and(page >= 0, start < n)
    if window is not None:
        # whole tile below the window start contributes nothing
        live = jnp.logical_and(live, start + bps - 1 > n - 1 - window)

    def _compute():
        q = q_ref[0, 0]  # (G, d)
        k = k_ref[0, :, 0]  # (bps, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, bps)
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, (1, bps), 1)
        mask = kpos < n
        if window is not None:
            mask = jnp.logical_and(mask, kpos > n - 1 - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0, :, 0], preferred_element_type=jnp.float32
        )

    pl.when(live)(_compute)

    @pl.when(j == maxP * nsub - 1)
    def _write():
        # fully-masked sequences (l == 0) emit zeros, matching the oracle
        l = l_scr[...]
        safe = jnp.maximum(l, 1e-30)
        o_ref[0, 0] = jnp.where(
            (l > 0)[:, None], acc_scr[...] / safe[:, None], 0.0
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "interpret", "page_block")
)
def paged_attention(
    q: jax.Array,  # (B, H, d) one query token per sequence
    k_pool: jax.Array,  # (num_pages, page_size, KV, d)
    v_pool: jax.Array,  # (num_pages, page_size, KV, d)
    block_table: jax.Array,  # (B, max_pages) int32, -1 = unassigned
    seq_lens: jax.Array,  # (B,) int32 valid tokens (incl. current)
    window: Optional[int] = None,
    scale: Optional[float] = None,
    interpret: bool = False,
    page_block: Optional[int] = None,
) -> jax.Array:
    B, H, d = q.shape
    num_pages, ps, KV, _ = k_pool.shape
    maxP = block_table.shape[1]
    G = H // KV
    assert H % KV == 0, (H, KV)
    scale = float(scale) if scale is not None else d**-0.5
    # sub-page KV tile (autotunable): bps rows DMA'd per grid step. The
    # default — one whole page per step — preserves the original schedule.
    bps = int(page_block) if page_block else ps
    assert ps % bps == 0, (ps, bps)
    nsub = ps // bps

    qg = q.reshape(B, KV, G, d)
    bt = block_table.astype(jnp.int32)
    sl = seq_lens.astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(
            _pa_kernel, ps=ps, maxP=maxP, bps=bps, nsub=nsub,
            window=window, scale=scale,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, maxP * nsub),
            in_specs=[
                pl.BlockSpec((1, 1, G, d), lambda b, kv, j, bt, sl: (b, kv, 0, 0)),
                pl.BlockSpec(
                    (1, bps, 1, d),
                    lambda b, kv, j, bt, sl: (
                        jnp.maximum(bt[b, j // nsub], 0), j % nsub, kv, 0
                    ),
                ),
                pl.BlockSpec(
                    (1, bps, 1, d),
                    lambda b, kv, j, bt, sl: (
                        jnp.maximum(bt[b, j // nsub], 0), j % nsub, kv, 0
                    ),
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, G, d), lambda b, kv, j, bt, sl: (b, kv, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, d), q.dtype),
        interpret=interpret,
    )(bt, sl, qg, k_pool, v_pool)
    return out.reshape(B, H, d)


# ---------------------------------------------------------------------------
# int8 KV pages: dequant fused into the per-page tile
#
# Pages hold int8 KV with a per-token, per-kv-head f32 scale sidecar shaped
# like the page payload with the head dim collapsed to 1 (serving/kv_cache
# keeps the sidecar leaves in the same pool tree so COW/defrag/DP-sharding
# move scales with their pages). The kernel resolves pages through the same
# scalar-prefetched block tables and dequantizes each (page_size, d) tile in
# VMEM right after the DMA: k/v int8 loads halve the HBM stream, scores and
# the weighted-value accumulation run in f32 (int8 values are exact in f32,
# so parity vs the dequantize-then-attend oracle is accumulation-order only).
# ---------------------------------------------------------------------------


def _pa_kernel_q8(
    bt_ref, len_ref,
    q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr,
    *, ps: int, maxP: int, bps: int, nsub: int,
    window: Optional[int], scale: float,
):
    b, j = pl.program_id(0), pl.program_id(2)
    jp, js = j // nsub, j % nsub
    start = jp * ps + js * bps

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    page = bt_ref[b, jp]
    n = len_ref[b]
    live = jnp.logical_and(page >= 0, start < n)
    if window is not None:
        live = jnp.logical_and(live, start + bps - 1 > n - 1 - window)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, d)
        k = k_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0]  # (bps, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, (1, bps), 1)
        mask = kpos < n
        if window is not None:
            mask = jnp.logical_and(mask, kpos > n - 1 - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        v = v_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0]  # (bps, d)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )

    pl.when(live)(_compute)

    @pl.when(j == maxP * nsub - 1)
    def _write():
        l = l_scr[...]
        safe = jnp.maximum(l, 1e-30)
        o_ref[0, 0] = jnp.where(
            (l > 0)[:, None], acc_scr[...] / safe[:, None], 0.0
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "interpret", "page_block")
)
def paged_attention_q8(
    q: jax.Array,  # (B, H, d) one query token per sequence
    k_pool: jax.Array,  # (num_pages, page_size, KV, d) int8
    v_pool: jax.Array,  # (num_pages, page_size, KV, d) int8
    k_scale: jax.Array,  # (num_pages, page_size, KV, 1) f32 sidecar
    v_scale: jax.Array,  # (num_pages, page_size, KV, 1)
    block_table: jax.Array,  # (B, max_pages) int32, -1 = unassigned
    seq_lens: jax.Array,  # (B,) int32 valid tokens (incl. current)
    window: Optional[int] = None,
    scale: Optional[float] = None,
    interpret: bool = False,
    page_block: Optional[int] = None,
) -> jax.Array:
    B, H, d = q.shape
    num_pages, ps, KV, _ = k_pool.shape
    maxP = block_table.shape[1]
    G = H // KV
    assert H % KV == 0, (H, KV)
    assert k_scale.shape == (num_pages, ps, KV, 1), k_scale.shape
    scale = float(scale) if scale is not None else d**-0.5
    bps = int(page_block) if page_block else ps
    assert ps % bps == 0, (ps, bps)
    nsub = ps // bps

    qg = q.reshape(B, KV, G, d)
    bt = block_table.astype(jnp.int32)
    sl = seq_lens.astype(jnp.int32)
    _page = lambda b, kv, j, bt, sl: (
        jnp.maximum(bt[b, j // nsub], 0), j % nsub, kv, 0
    )

    out = pl.pallas_call(
        functools.partial(
            _pa_kernel_q8, ps=ps, maxP=maxP, bps=bps, nsub=nsub,
            window=window, scale=scale,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, maxP * nsub),
            in_specs=[
                pl.BlockSpec((1, 1, G, d), lambda b, kv, j, bt, sl: (b, kv, 0, 0)),
                pl.BlockSpec((1, bps, 1, d), _page),
                pl.BlockSpec((1, bps, 1, d), _page),
                pl.BlockSpec((1, bps, 1, 1), _page),
                pl.BlockSpec((1, bps, 1, 1), _page),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, G, d), lambda b, kv, j, bt, sl: (b, kv, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, d), q.dtype),
        interpret=interpret,
    )(bt, sl, qg, k_pool, v_pool, k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))
    return out.reshape(B, H, d)
