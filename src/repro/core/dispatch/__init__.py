"""TokenDispatcher subsystem: moves routed tokens between the token-major
model layout and the expert-major kernel layouts.

Three dispatchers (select via ``MoEConfig.dispatcher``):

* ``allgather`` — global-view pjit; dense padded (E, C, D) layout,
  CF-bounded token dropping. Default; works on any mesh.
* ``alltoall``  — shard_map + lax.all_to_all over the EP axis (preferred
  for small top-k per paper §3.2); padded layout, needs an EP plan.
* ``sorted``    — argsort token permutation into a flat (T*k, D)
  expert-sorted buffer + per-expert group_sizes; true dropless with no
  C = T padding blow-up. Recommended for ``capacity_factor=None`` runs.

``get_dispatcher`` applies the legality fallbacks (expert-choice routing
needs the full-probability tables -> allgather; alltoall needs an EP plan
and divisible token shards).
"""
from __future__ import annotations

import warnings
from typing import Any, Optional

import numpy as np

from repro.core.dispatch.allgather import AllGatherDispatcher
from repro.core.dispatch.alltoall import AllToAllDispatcher
from repro.core.dispatch.base import (
    DispatchLayout,
    DispatchState,
    TokenDispatcher,
    capacity,
    dispatch_tables,
    expert_choice_tables,
    expert_ffn,
    num_groups,
)
from repro.core.dispatch.sorted import SortedDispatcher
from repro.sharding.rules import FoldingPlan

DISPATCHERS = {
    "allgather": AllGatherDispatcher,
    "alltoall": AllToAllDispatcher,
    "sorted": SortedDispatcher,
}


def get_dispatcher(
    cfg: Any,
    moe: Any,
    plan: Optional[FoldingPlan],
    total_tokens: int,
    batch: int,
) -> TokenDispatcher:
    """Resolve ``moe.dispatcher`` to a legal dispatcher instance for this
    (plan, shape), falling back to allgather when preconditions fail."""
    name = moe.dispatcher
    if name not in DISPATCHERS:
        raise ValueError(
            f"unknown dispatcher {name!r}; expected one of {sorted(DISPATCHERS)}"
        )
    if name == "sorted" and moe.router_type == "expert_choice":
        # EC routing emits per-expert (token, gate) tables directly; the
        # flat top-k assignment list the sort permutes does not exist
        name = "allgather"
    if name == "sorted" and moe.capacity_factor is not None:
        warnings.warn(
            "dispatcher='sorted' is always dropless: capacity_factor="
            f"{moe.capacity_factor} is ignored (no CF-bounded token "
            "dropping). Use a padded dispatcher for CF semantics.",
            stacklevel=2,
        )
    if name == "alltoall":
        ok = (
            moe.router_type != "expert_choice"  # EC gates are (T, E)
            and plan is not None
            and plan.moe_mode == "ep"
            and total_tokens
            % int(
                np.prod(
                    [plan.mesh.shape[a] for a in tuple(plan.batch_axes) + (plan.ep_axis,)]
                )
            )
            == 0
        )
        if not ok:
            name = "allgather"
    if name == "allgather":
        return AllGatherDispatcher(
            cfg, moe, plan, groups=num_groups(plan, total_tokens, batch)
        )
    return DISPATCHERS[name](cfg, moe, plan)


__all__ = [
    "DISPATCHERS",
    "DispatchLayout",
    "DispatchState",
    "TokenDispatcher",
    "AllGatherDispatcher",
    "AllToAllDispatcher",
    "SortedDispatcher",
    "capacity",
    "dispatch_tables",
    "expert_choice_tables",
    "expert_ffn",
    "num_groups",
    "get_dispatcher",
]
