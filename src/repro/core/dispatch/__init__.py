"""TokenDispatcher subsystem: moves routed tokens between the token-major
model layout and the expert-major kernel layouts.

Three dispatchers (select via ``MoEConfig.dispatcher``):

* ``allgather`` — global-view pjit; dense padded (E, C, D) layout,
  CF-bounded token dropping. Default; works on any mesh.
* ``alltoall``  — shard_map + lax.all_to_all over the EP axis (preferred
  for small top-k per paper §3.2); padded layout, needs an EP plan.
* ``a2a_overlap`` — alltoall with the exchange decomposed into double-
  buffered ppermute rounds so it overlaps expert compute (the serving
  decode schedule); same legality preconditions as alltoall.
* ``sorted``    — argsort token permutation into a flat (T*k, D)
  expert-sorted buffer + per-expert group_sizes; true dropless with no
  C = T padding blow-up. Recommended for ``capacity_factor=None`` runs.

``get_dispatcher`` applies the legality fallbacks (expert-choice routing
needs the full-probability tables -> allgather; alltoall needs an EP plan
and divisible token shards). Falling back from an EP dispatcher emits a
warning naming the offending shapes; with ``MoEConfig.strict_dispatch``
(set by the mesh-mode serving engine, where the fallback would silently
forfeit the EP win) — or with the ``REPRO_STRICT_DISPATCH`` environment
variable truthy, the default in this repo's test suite and CI — it raises
instead, so a dispatch bug cannot hide behind the quiet allgather path.
"""
from __future__ import annotations

import os
import warnings
from typing import Any, Optional

import numpy as np

from repro.core.dispatch.allgather import AllGatherDispatcher
from repro.core.dispatch.alltoall import AllToAllDispatcher, OverlapAllToAllDispatcher
from repro.core.dispatch.base import (
    DispatchLayout,
    DispatchState,
    TokenDispatcher,
    capacity,
    dispatch_tables,
    expert_choice_tables,
    expert_ffn,
    num_groups,
)
from repro.core.dispatch.sorted import SortedDispatcher
from repro.sharding.rules import FoldingPlan

DISPATCHERS = {
    "allgather": AllGatherDispatcher,
    "alltoall": AllToAllDispatcher,
    "a2a_overlap": OverlapAllToAllDispatcher,
    "sorted": SortedDispatcher,
}


def strict_dispatch_env() -> bool:
    """Environment override making every EP-dispatcher fallback an error
    (tests/CI export ``REPRO_STRICT_DISPATCH=1``)."""
    return os.environ.get("REPRO_STRICT_DISPATCH", "").lower() in (
        "1", "true", "yes", "on"
    )


def get_dispatcher(
    cfg: Any,
    moe: Any,
    plan: Optional[FoldingPlan],
    total_tokens: int,
    batch: int,
) -> TokenDispatcher:
    """Resolve ``moe.dispatcher`` to a legal dispatcher instance for this
    (plan, shape), falling back to allgather when preconditions fail."""
    name = moe.dispatcher
    if name not in DISPATCHERS:
        raise ValueError(
            f"unknown dispatcher {name!r}; expected one of {sorted(DISPATCHERS)}"
        )
    if name == "sorted" and moe.router_type == "expert_choice":
        # EC routing emits per-expert (token, gate) tables directly; the
        # flat top-k assignment list the sort permutes does not exist
        name = "allgather"
    if name == "sorted" and moe.capacity_factor is not None:
        warnings.warn(
            "dispatcher='sorted' is always dropless: capacity_factor="
            f"{moe.capacity_factor} is ignored (no CF-bounded token "
            "dropping). Use a padded dispatcher for CF semantics.",
            stacklevel=2,
        )
    if name in ("alltoall", "a2a_overlap"):
        shards = (
            int(np.prod([
                plan.mesh.shape[a]
                for a in tuple(plan.batch_axes) + (plan.ep_axis,)
            ]))
            if plan is not None and plan.ep_axis is not None
            else None
        )
        reason = None
        if moe.router_type == "expert_choice":
            reason = "expert_choice routing needs the full (T, E) gate table"
        elif plan is None or plan.moe_mode != "ep":
            reason = (
                f"no EP plan (plan={'None' if plan is None else plan.moe_mode!r})"
            )
        elif total_tokens % shards != 0:
            reason = (
                f"token count {total_tokens} not divisible by the "
                f"token-shard product {shards} (batch_axes="
                f"{plan.batch_axes}, ep_axis={plan.ep_axis!r}, "
                f"mesh={dict(plan.mesh.shape)})"
            )
        if reason is not None:
            msg = (
                f"dispatcher {name!r} is illegal here — {reason}; "
                "falling back to 'allgather'. In serving mode this fallback "
                "silently forfeits the EP win: pad the batch to the "
                "token-shard product or pick a legal dispatcher."
            )
            if getattr(moe, "strict_dispatch", False) or strict_dispatch_env():
                raise ValueError(msg)
            warnings.warn(msg, stacklevel=2)
            name = "allgather"
    if name == "allgather":
        return AllGatherDispatcher(
            cfg, moe, plan, groups=num_groups(plan, total_tokens, batch)
        )
    return DISPATCHERS[name](cfg, moe, plan)


__all__ = [
    "DISPATCHERS",
    "DispatchLayout",
    "DispatchState",
    "TokenDispatcher",
    "AllGatherDispatcher",
    "AllToAllDispatcher",
    "OverlapAllToAllDispatcher",
    "SortedDispatcher",
    "capacity",
    "dispatch_tables",
    "expert_choice_tables",
    "expert_ffn",
    "num_groups",
    "get_dispatcher",
]
