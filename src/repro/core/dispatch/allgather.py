"""AllGather token dispatcher (global-view pjit formulation).

Tokens stay replicated over the EP axis; each expert shard gathers the
(<= capacity) tokens routed to its local experts, and the combine is a
scatter-add whose cross-shard reduction XLA lowers to an
all-reduce/reduce-scatter over the EP axis. Dense padded ``(G, E, C, D)``
layout; overflow past capacity is dropped (CF-bounded) — with
``capacity_factor=None`` the padded layout blows up to ``C = T`` per group
(use the sorted dispatcher for efficient dropless runs).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dispatch.base import (
    DispatchLayout,
    DispatchState,
    TokenDispatcher,
    capacity,
    dispatch_tables,
    expert_choice_tables,
)
from repro.sharding.rules import FoldingPlan


class AllGatherDispatcher(TokenDispatcher):
    name = "allgather"

    def __init__(self, cfg, moe, plan: Optional[FoldingPlan], groups: int = 1):
        super().__init__(cfg, moe, plan)
        self.groups = groups

    def dispatch(self, x: jax.Array, idx: jax.Array, gates: jax.Array):
        T, D = x.shape
        moe, plan = self.moe, self.plan
        E, k = moe.num_experts, moe.top_k
        G = self.groups
        Tg = T // G
        C = capacity(moe, Tg)

        xg = x.reshape(G, Tg, D)
        if moe.router_type == "expert_choice":
            # gates here carries the full (T, E) probability matrix
            sel, slot_gate = jax.vmap(lambda p: expert_choice_tables(p, E, C))(
                gates.reshape(G, Tg, E)
            )
        else:
            sel, slot_gate = jax.vmap(lambda i, g: dispatch_tables(i, g, E, C))(
                idx.reshape(G, Tg, k), gates.reshape(G, Tg, k)
            )
        if plan is not None:
            xg = plan.constrain(xg, "batch", None, None)
            sel = plan.constrain(sel, "batch", None, None)

        # dispatch: local gather (tokens replicated over EP axis within a group)
        xe = jax.vmap(lambda xs, s: xs[s])(xg, sel)  # (G, E, C, D)
        if plan is not None:
            xe = plan.constrain(xe, "batch", "expert", None, None)
        state = DispatchState(
            layout=DispatchLayout("padded", E, capacity=C),
            residuals={"sel": sel, "slot_gate": slot_gate},
            static={"tokens": T, "tg": Tg},
        )
        return xe, state

    def combine(self, ye: jax.Array, state) -> jax.Array:
        # scatter-add back to token order; contributions from different
        # expert shards reduce over the EP axis.
        r = state.residuals
        E, C = state.layout.num_experts, state.layout.capacity
        Tg, D = state.static["tg"], ye.shape[-1]
        ye = ye * r["slot_gate"][..., None].astype(ye.dtype)

        def scatter(y_g, sel_g):
            flat = y_g.reshape(E * C, D)
            return jnp.zeros((Tg, D), flat.dtype).at[sel_g.reshape(E * C)].add(flat)

        out = jax.vmap(scatter)(ye, r["sel"])  # (G, Tg, D)
        if self.plan is not None:
            out = self.plan.constrain(out, "batch", None, None)
        return out.reshape(state.static["tokens"], D)
