"""Sorted dropless dispatcher (MegaBlocks/Megatron-style argsort permutation).

Instead of the dense padded ``(E, C, D)`` layout — which for dropless runs
degenerates to the worst case ``C = T`` — the token assignments are argsorted
by expert id into one flat ``(T*k, D)`` expert-sorted buffer plus per-expert
``group_sizes``. True dropless: every assignment is computed, no capacity,
no ``O(T*k*E)`` one-hot/cumsum table build (the permutation is an
``O(N log N)`` argsort + gather), and compute/memory scale with ``T*k``
instead of ``E*C``.

Layout notes for the kernel path: the Pallas grouped GEMM tiles rows, so
each expert's region is aligned up to the row-tile size (``row_block``) and
rows past ``group_sizes[e]`` in the last tile are masked. The XLA path
(``lax.ragged_dot``) consumes the compact buffer (``row_block=1``).

This dispatcher operates in the global pjit view (like allgather); under an
EP mesh XLA inserts the gather/reduce collectives. A shard_map variant with
explicit a2a of the sorted buffer is future work.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dispatch.base import (
    DispatchLayout,
    DispatchState,
    TokenDispatcher,
    expert_ffn,
)

# Row-tile alignment of the expert-sorted buffer on the kernel path. This is
# the single knob: it is threaded to the grouped GEMM as its row-tile size
# via layout.row_block -> ops.grouped_gemm(row_block=...), so buffer
# alignment and kernel tiling cannot drift apart. 128 = MXU-aligned.
KERNEL_ROW_BLOCK = 128


def aligned_rows(N: int, E: int, row_block: int) -> int:
    """Static worst-case buffer rows: sum_e ceil(g_e/b)*b <= N + E*(b-1),
    rounded up to a whole number of row tiles."""
    if row_block <= 1:
        return N
    return -(-(N + E * (row_block - 1)) // row_block) * row_block


class SortedDispatcher(TokenDispatcher):
    name = "sorted"

    def _indices(self, idx: jax.Array, gates: jax.Array, row_block: int):
        """Shared routing-index computation: the stable expert-major sort and
        the (token, slot, dest, gate_sorted, group_sizes) vectors both the
        materializing and the fused paths consume."""
        T = idx.shape[0]
        E = self.moe.num_experts
        k = idx.shape[-1]
        N = T * k
        b = row_block

        flat_e = idx.reshape(N)
        # stable argsort: expert-major, token-major within an expert (same
        # priority order as the table-based dispatchers)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        token = order // k  # token providing each sorted row
        slot = (order % k).astype(jnp.int32)  # its top-k slot (unique pair)
        group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)

        # destination row of each sorted assignment in the (tile-aligned)
        # buffer: expert region start + position within the expert
        padded = ((group_sizes + b - 1) // b) * b
        starts_pad = jnp.cumsum(padded) - padded
        starts = jnp.cumsum(group_sizes) - group_sizes
        pos_in_group = jnp.arange(N, dtype=jnp.int32) - starts[sorted_e]
        dest = (starts_pad[sorted_e] + pos_in_group).astype(jnp.int32)
        gate_sorted = gates.reshape(N)[order]
        return token, slot, dest, gate_sorted, group_sizes

    def dispatch(
        self, x: jax.Array, idx: jax.Array, gates: jax.Array, row_block: int = 1
    ):
        T, D = x.shape
        E = self.moe.num_experts
        N = T * idx.shape[-1]
        token, slot, dest, gate_sorted, group_sizes = self._indices(
            idx, gates, row_block
        )

        N_pad = aligned_rows(N, E, row_block)
        xs = jnp.zeros((N_pad, D), x.dtype).at[dest].set(x[token])
        state = DispatchState(
            layout=DispatchLayout(
                "sorted", E, group_sizes=group_sizes, row_block=row_block
            ),
            residuals={
                "token": token,
                "dest": dest,
                "gate_sorted": gate_sorted,
            },
            static={"tokens": T},
        )
        return xs, state

    def combine(self, ye: jax.Array, state) -> jax.Array:
        D = ye.shape[-1]
        r = state.residuals
        # fp32 accumulation for the k-way scatter-add (a bf16 accumulator
        # loses ~2 bits over k partial sums); cast once at the end
        yv = ye[r["dest"]].astype(jnp.float32)  # (N, D) valid rows, sorted order
        yv = yv * r["gate_sorted"][:, None].astype(jnp.float32)
        T = state.static["tokens"]
        out = jnp.zeros((T, D), jnp.float32).at[r["token"]].add(yv)
        return out.astype(ye.dtype)

    def _apply_fused(
        self, experts, x: jax.Array, gates: jax.Array, idx: jax.Array
    ) -> jax.Array:
        """Dispatch-in-kernel path: the gather runs in the grouped GEMM's
        prologue and the gate-weighted combine in its epilogue, so neither
        the permuted (N_pad, D) buffer nor the (N, D) gathered output is
        materialized in HBM."""
        from repro.core.quant import is_quantized
        from repro.kernels import ops

        token, slot, dest, gate_sorted, group_sizes = self._indices(
            idx, gates, KERNEL_ROW_BLOCK
        )
        if is_quantized(experts):
            return ops.grouped_gemm_fused_q8(
                x,
                experts["w_gate"], experts["w_up"], experts["w_down"],
                experts["w_gate_scale"], experts["w_up_scale"],
                experts["w_down_scale"],
                group_sizes, token, dest, slot, gate_sorted,
                row_block=KERNEL_ROW_BLOCK,
            )
        return ops.grouped_gemm_fused(
            x,
            experts["w_gate"], experts["w_up"], experts["w_down"],
            group_sizes, token, dest, slot, gate_sorted,
            row_block=KERNEL_ROW_BLOCK,
        )

    def apply(
        self,
        experts,
        x: jax.Array,
        gates: jax.Array,
        idx: jax.Array,
        use_kernel: bool = False,
    ) -> jax.Array:
        if use_kernel and getattr(self.moe, "fused_dispatch", False):
            return self._apply_fused(experts, x, gates, idx)
        # the kernel tiles rows -> tile-aligned regions; XLA ragged_dot
        # consumes the compact buffer
        row_block = KERNEL_ROW_BLOCK if use_kernel else 1
        xe, state = self.dispatch(x, idx, gates, row_block=row_block)
        ye = expert_ffn(experts, xe, state.layout, use_kernel)
        return self.combine(ye, state)
