"""TokenDispatcher subsystem: the common interface plus the shared
dispatch bookkeeping (capacity, table building, group splitting).

A dispatcher moves routed tokens between the token-major layout the model
computes in and an expert-major layout the expert kernels consume:

* ``dispatch(x, idx, gates)`` -> ``(expert-major tokens, DispatchState)``.
  The state carries the :class:`DispatchLayout` descriptor the kernel layer
  consumes (dense padded ``(E, C, D)`` vs. flat expert-sorted ``(N, D)`` +
  ``group_sizes``) plus the residual arrays combine needs to reverse the
  permutation.
* ``combine(ye, state)``      -> token-major ``(T, D)`` output with the
  gate weighting applied.

Dispatchers hold NO mutable per-invocation state: all per-call values flow
through the returned :class:`DispatchState`, so one instance is re-entrant
under ``jax.grad`` / ``jax.vmap`` / nested tracing (dispatch twice, combine
in any order).

Concrete dispatchers live in sibling modules: ``allgather`` (global-view
pjit), ``alltoall`` (shard_map + lax.all_to_all over the EP axis), and
``sorted`` (argsort token permutation; true dropless, no padded capacity).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import FoldingPlan


# ---------------------------------------------------------------------------
# Layout descriptor consumed by the kernel layer
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DispatchLayout:
    """Describes the expert-major buffer a dispatcher produced.

    * ``kind="padded"``: dense ``(..., E, C, D)`` buffer, ``capacity`` slots
      per expert; slots past the routed count hold garbage and are masked by
      the gate weights at combine.
    * ``kind="sorted"``: flat ``(N, D)`` expert-sorted buffer with
      ``group_sizes`` (E,) valid rows per expert. ``row_block`` is the row
      alignment of each expert's region (1 = compact; the Pallas grouped
      GEMM requires its row-tile size so every tile maps to one expert).
    """

    kind: str
    num_experts: int
    capacity: Optional[int] = None
    group_sizes: Optional[jax.Array] = None
    row_block: int = 1


@dataclasses.dataclass
class DispatchState:
    """Per-invocation dispatch residuals, returned by ``dispatch`` and
    passed back to ``combine``. ``layout`` describes the expert-major
    buffer for the kernel layer; ``residuals`` holds the arrays the
    concrete dispatcher needs to reverse its permutation (selection tables,
    argsort destinations, gate weights, ...); ``static`` holds hashable
    shape/geometry metadata (token counts, shard factors, axis names).
    Keeping these out of the dispatcher instance makes dispatch/combine
    pure functions of their inputs — re-entrant under jax.grad, jax.vmap,
    and nested traces. Both this class and :class:`DispatchLayout` are
    registered pytrees (arrays are leaves, everything else aux data), so
    the state may legally cross jit/vmap/scan boundaries."""

    layout: DispatchLayout
    residuals: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    static: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _layout_flatten(l: DispatchLayout):
    return (l.group_sizes,), (l.kind, l.num_experts, l.capacity, l.row_block)


def _layout_unflatten(aux, children):
    kind, num_experts, cap, row_block = aux
    return DispatchLayout(
        kind, num_experts, capacity=cap, group_sizes=children[0], row_block=row_block
    )


jax.tree_util.register_pytree_node(DispatchLayout, _layout_flatten, _layout_unflatten)


def _state_flatten(s: DispatchState):
    keys = tuple(sorted(s.residuals))
    children = (s.layout,) + tuple(s.residuals[k] for k in keys)
    return children, (keys, tuple(sorted(s.static.items())))


def _state_unflatten(aux, children):
    keys, static_items = aux
    return DispatchState(children[0], dict(zip(keys, children[1:])), dict(static_items))


jax.tree_util.register_pytree_node(DispatchState, _state_flatten, _state_unflatten)


# ---------------------------------------------------------------------------
# Shared dispatch bookkeeping
# ---------------------------------------------------------------------------


def capacity(moe, tokens_per_group: int) -> int:
    """Paper §2: ``C = ceil(k * tokens_per_group / E * CF)``. ``CF=None`` =
    dropless under the padded layout (worst case: one expert takes all)."""
    if moe.capacity_factor is None:
        return tokens_per_group
    c = math.ceil(moe.top_k * tokens_per_group / moe.num_experts * moe.capacity_factor)
    # an expert can receive each token at most once -> capacity <= T
    return max(min(int(c), tokens_per_group), 1)


def num_groups(plan: Optional[FoldingPlan], total_tokens: int, batch: int) -> int:
    """Tokens are dispatched in groups (GShard-style) so capacity and the
    dispatch working set stay per-data-shard. Groups = batch shards."""
    if plan is None:
        return 1
    g = int(np.prod([plan.mesh.shape[a] for a in plan.batch_axes])) or 1
    while g > 1 and (batch % g != 0 or total_tokens % g != 0):
        g -= 1
    return max(g, 1)


def expert_choice_tables(
    probs_full: jax.Array, E: int, C: int
) -> Tuple[jax.Array, jax.Array]:
    """Expert-Choice routing (Zhou et al., cited by the paper as the
    alternative to Top-k): each EXPERT picks its top-C tokens by router
    probability — perfect load balance by construction, no capacity
    overflow, variable experts-per-token. probs_full: (T, E).
    Returns (sel (E,C) token ids, slot_gate (E,C))."""
    scores = probs_full.T  # (E, T)
    g, sel = jax.lax.top_k(scores, C)  # per-expert top-C tokens
    return sel.astype(jnp.int32), g.astype(jnp.float32)


def dispatch_tables(
    idx: jax.Array, gates: jax.Array, E: int, C: int
) -> Tuple[jax.Array, jax.Array]:
    """Per-group dispatch bookkeeping for the padded layout.

    idx/gates: (T, k). Returns (sel (E, C) int32 token ids,
    slot_gate (E, C) fp32 combine weights). Overflow (position >= C) is
    dropped: its slot_gate is 0. Priority is token-major order (the paper /
    Megatron drop rule)."""
    T, k = idx.shape
    flat_e = idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (Tk, E)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1  # (Tk,)
    keep = pos < C
    safe_pos = jnp.where(keep, pos, C)  # overflow -> dump column C
    token_id = (jnp.arange(T * k, dtype=jnp.int32) // k).astype(jnp.int32)
    gate_flat = jnp.where(keep, gates.reshape(T * k), 0.0)

    sel = jnp.zeros((E, C + 1), jnp.int32).at[flat_e, safe_pos].set(token_id)
    slot_gate = jnp.zeros((E, C + 1), jnp.float32).at[flat_e, safe_pos].set(gate_flat)
    return sel[:, :C], slot_gate[:, :C]


# ---------------------------------------------------------------------------
# Expert FFN in the dispatcher's layout (the kernel boundary)
# ---------------------------------------------------------------------------


def expert_ffn(
    experts, xe: jax.Array, layout: DispatchLayout, use_kernel: bool = False
) -> jax.Array:
    """Apply the fused-SwiGLU expert FFN in the layout ``xe`` is in.

    * padded: ``(..., E, C, D) -> (..., E, C, D)``; Pallas ``expert_gemm``
      or the batched-einsum XLA path.
    * sorted: ``(N, D) -> (N, D)`` with ``layout.group_sizes`` rows per
      expert; Pallas group-size-aware ``grouped_gemm`` or the
      ``lax.ragged_dot`` XLA path.

    int8 experts (core/quant.py dicts carrying ``*_scale`` keys) route to
    the fused-dequant kernels on the Pallas path; the XLA paths dequantize
    the weights up front (functionally identical, no byte savings) so every
    dispatcher keeps working under quantization.
    """
    from repro.core.quant import dequantize_experts, is_quantized

    quant = is_quantized(experts)
    if layout.kind == "sorted":
        from repro.kernels.ops import grouped_gemm, grouped_gemm_q8, grouped_gemm_xla

        if use_kernel and quant:
            return grouped_gemm_q8(
                xe, experts["w_gate"], experts["w_up"], experts["w_down"],
                experts["w_gate_scale"], experts["w_up_scale"],
                experts["w_down_scale"], layout.group_sizes,
                row_block=layout.row_block,
            )
        if quant:
            experts = dequantize_experts(experts, xe.dtype)
        args = (xe, experts["w_gate"], experts["w_up"], experts["w_down"],
                layout.group_sizes)
        if use_kernel:
            return grouped_gemm(*args, row_block=layout.row_block)
        return grouped_gemm_xla(*args)
    if use_kernel and quant:
        from repro.kernels.ops import expert_gemm_q8

        return expert_gemm_q8(
            xe, experts["w_gate"], experts["w_up"], experts["w_down"],
            experts["w_gate_scale"], experts["w_up_scale"],
            experts["w_down_scale"],
        )
    if quant:
        experts = dequantize_experts(experts, xe.dtype)
    if use_kernel:
        from repro.kernels.ops import expert_gemm

        return expert_gemm(xe, experts["w_gate"], experts["w_up"], experts["w_down"])
    g = jnp.einsum("...ecd,edf->...ecf", xe, experts["w_gate"])
    u = jnp.einsum("...ecd,edf->...ecf", xe, experts["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
    return jnp.einsum("...ecf,efd->...ecd", h, experts["w_down"])


# ---------------------------------------------------------------------------
# Base class
# ---------------------------------------------------------------------------


class TokenDispatcher:
    """Stateless dispatch/combine pair. ``apply`` composes the pipeline
    dispatch -> expert FFN -> combine, threading the per-call
    :class:`DispatchState` between the two; dispatchers that own their
    collectives (alltoall) override ``apply`` to wrap the pipeline in
    shard_map."""

    name = "base"

    def __init__(self, cfg: Any, moe: Any, plan: Optional[FoldingPlan]):
        self.cfg, self.moe, self.plan = cfg, moe, plan

    def dispatch(
        self, x: jax.Array, idx: jax.Array, gates: jax.Array
    ) -> Tuple[jax.Array, DispatchState]:
        raise NotImplementedError

    def combine(self, ye: jax.Array, state: DispatchState) -> jax.Array:
        raise NotImplementedError

    def apply(
        self,
        experts,
        x: jax.Array,
        gates: jax.Array,
        idx: jax.Array,
        use_kernel: bool = False,
    ) -> jax.Array:
        xe, state = self.dispatch(x, idx, gates)
        ye = expert_ffn(experts, xe, state.layout, use_kernel)
        return self.combine(ye, state)
