"""AllToAll token dispatchers (shard_map over the EP axis; preferred for
small top-k, per the paper §3.2 practice #2).

Each token shard builds its local dispatch tables, sends capacity-sized
slot blocks to the shards owning the target experts, and the combine
reverses the exchange. Requires an EP plan (``plan.moe_mode == "ep"``) and
a token count divisible by the token-shard product; `get_dispatcher` falls
back to allgather otherwise (loudly — and serving mode treats the fallback
as a config error, see ``MoEConfig.strict_dispatch``).

Two exchange schedules over the same dispatch tables:

* :class:`AllToAllDispatcher` (``"alltoall"``) — one monolithic
  ``lax.all_to_all`` each way. The whole exchange must complete before any
  expert FFN row is computed, so dispatch latency is fully exposed.
* :class:`OverlapAllToAllDispatcher` (``"a2a_overlap"``) — the exchange is
  decomposed into ``ep - 1`` shifted ``lax.ppermute`` rounds, double-
  buffered against expert compute: the block exchanged in round ``r`` has
  no data dependence on the FFN of round ``r - 1``, so the compiler's async
  collectives (``collective-permute-start``/``-done`` on TPU) run each hop
  while the previous block's grouped GEMM executes. This is the serving
  decode schedule — the paper's overlapped-dispatch practice (§3.2) —
  where hiding the all-to-all behind attention/FFN compute is what keeps
  EP decode latency dense-like.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.dispatch.base import (
    DispatchLayout,
    DispatchState,
    TokenDispatcher,
    capacity,
    dispatch_tables,
    expert_ffn,
)


class AllToAllDispatcher(TokenDispatcher):
    name = "alltoall"

    def dispatch(self, x: jax.Array, idx: jax.Array, gates: jax.Array, *,
                 E: int, C: int, ep: int, E_loc: int, ep_axis: str):
        """Local shard view: table build + all_to_all. Called inside the
        shard_map region set up by ``apply`` (which supplies the static
        shard geometry)."""
        T_loc, D = x.shape
        sel, slot_gate = dispatch_tables(idx, gates, E, C)  # (E, C)
        send = x[sel]  # (E, C, D) outgoing slots, grouped by global expert
        recv = jax.lax.all_to_all(
            send.reshape(ep, E_loc, C, D), ep_axis, split_axis=0, concat_axis=0
        )  # (ep, E_loc, C, D): slot block from every sender for my experts
        xe = recv.transpose(1, 0, 2, 3).reshape(E_loc, ep * C, D)
        state = DispatchState(
            layout=DispatchLayout("padded", E_loc, capacity=ep * C),
            residuals={"sel": sel, "slot_gate": slot_gate},
            static={"tokens": T_loc, "E": E, "C": C, "ep": ep, "ep_axis": ep_axis},
        )
        return xe, state

    def combine(self, ye: jax.Array, state) -> jax.Array:
        r, st = state.residuals, state.static
        E, C, ep = st["E"], st["C"], st["ep"]
        E_loc = state.layout.num_experts
        D = ye.shape[-1]
        back = ye.reshape(E_loc, ep, C, D).transpose(1, 0, 2, 3)
        ret = jax.lax.all_to_all(back, st["ep_axis"], split_axis=0, concat_axis=0)
        ret = ret.reshape(E, C, D) * r["slot_gate"][..., None].astype(ye.dtype)
        return jnp.zeros((st["tokens"], D), ret.dtype).at[
            r["sel"].reshape(E * C)
        ].add(ret.reshape(E * C, D))

    def apply(
        self,
        experts,
        x: jax.Array,
        gates: jax.Array,
        idx: jax.Array,
        use_kernel: bool = False,
    ) -> jax.Array:
        plan, moe = self.plan, self.moe
        mesh = plan.mesh
        ep_axis = plan.ep_axis
        assert ep_axis is not None and plan.moe_mode == "ep"
        ep = mesh.shape[ep_axis]
        T = x.shape[0]
        E = moe.num_experts
        token_axes = tuple(plan.batch_axes) + (ep_axis,)
        shards = int(np.prod([mesh.shape[a] for a in token_axes]))
        assert T % shards == 0, (T, shards)
        E_loc = E // ep
        C = capacity(moe, T // shards)

        w_specs = jax.tree.map(lambda _: P(ep_axis, None, None), experts)

        def local_moe(x_l, gates_l, idx_l, experts_l):
            return self._local_pipeline(
                x_l, gates_l, idx_l, experts_l,
                E=E, C=C, ep=ep, E_loc=E_loc, ep_axis=ep_axis,
                use_kernel=use_kernel,
            )

        fn = shard_map(
            local_moe,
            mesh=mesh,
            in_specs=(
                P(token_axes, None), P(token_axes, None), P(token_axes, None), w_specs,
            ),
            out_specs=P(token_axes, None),
            check_rep=False,
        )
        return fn(x, gates, idx, experts)

    def _local_pipeline(self, x_l, gates_l, idx_l, experts_l, *,
                        E, C, ep, E_loc, ep_axis, use_kernel):
        """Per-shard dispatch -> expert FFN -> combine (inside shard_map).
        Subclasses override this to change the exchange schedule."""
        xe, state = self.dispatch(
            x_l, idx_l, gates_l, E=E, C=C, ep=ep, E_loc=E_loc, ep_axis=ep_axis
        )
        ye = expert_ffn(experts_l, xe[None], state.layout, use_kernel)[0]
        return self.combine(ye, state)


class OverlapAllToAllDispatcher(AllToAllDispatcher):
    """Double-buffered ring schedule: the all-to-all is decomposed into
    ``ep - 1`` shifted ``ppermute`` hops, each independent of the expert
    FFN on the previously received block, so exchange and compute overlap.

    Round ``r`` (0 <= r < ep): shard ``i`` sends the slot block destined to
    shard ``(i + r) % ep`` directly to it (round 0 is the local block — no
    exchange), runs the expert FFN on the block received from shard
    ``(i - r) % ep``, and returns the previous round's result with the
    inverse shift. Per-round blocks are ``(E_loc, C, D)`` — the padded
    expert FFN is slot-wise, so chunking capacity by source shard is
    numerically identical to the monolithic ``(E_loc, ep*C, D)`` GEMM."""

    name = "a2a_overlap"

    def _local_pipeline(self, x_l, gates_l, idx_l, experts_l, *,
                        E, C, ep, E_loc, ep_axis, use_kernel):
        T_loc, D = x_l.shape
        sel, slot_gate = dispatch_tables(idx_l, gates_l, E, C)  # (E, C)
        send = x_l[sel].reshape(ep, E_loc, C, D)  # [j] = slots for shard j
        my = jax.lax.axis_index(ep_axis)
        # rolled[r] = block destined to shard (my + r) % ep; round 0 local
        rolled = jnp.roll(send, -my, axis=0)
        layout = DispatchLayout("padded", E_loc, capacity=C)
        outs = []
        for r in range(ep):
            if r == 0:
                blk = rolled[0]
            else:
                blk = jax.lax.ppermute(
                    rolled[r], ep_axis, [(i, (i + r) % ep) for i in range(ep)]
                )  # arrives from shard (my - r) % ep: its slots for my experts
            ye = expert_ffn(experts_l, blk[None], layout, use_kernel)[0]
            if r == 0:
                outs.append(ye)
            else:
                outs.append(jax.lax.ppermute(
                    ye, ep_axis, [(i, (i - r) % ep) for i in range(ep)]
                ))  # back to its source: my block processed by (my + r) % ep
        # outs[r] holds results for global experts of shard (my + r) % ep;
        # un-roll to expert-shard-major order matching ``sel``
        ret = jnp.roll(jnp.stack(outs), my, axis=0).reshape(E, C, D)
        ret = ret * slot_gate[..., None].astype(ret.dtype)
        return jnp.zeros((T_loc, D), ret.dtype).at[
            sel.reshape(E * C)
        ].add(ret.reshape(E * C, D))
