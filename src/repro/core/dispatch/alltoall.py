"""AllToAll token dispatcher (shard_map + ``lax.all_to_all`` over the EP
axis; preferred for small top-k, per the paper §3.2 practice #2).

Each token shard builds its local dispatch tables, sends capacity-sized
slot blocks to the shards owning the target experts, and the combine
reverses the exchange. Requires an EP plan (``plan.moe_mode == "ep"``) and
a token count divisible by the token-shard product; `get_dispatcher` falls
back to allgather otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.dispatch.base import (
    DispatchLayout,
    TokenDispatcher,
    capacity,
    dispatch_tables,
    expert_ffn,
)


class AllToAllDispatcher(TokenDispatcher):
    name = "alltoall"

    def dispatch(self, x: jax.Array, idx: jax.Array, gates: jax.Array) -> jax.Array:
        """Local shard view: table build + all_to_all. Called inside the
        shard_map region set up by ``apply``."""
        moe = self.moe
        E, C, ep, E_loc = self._E, self._C, self._ep, self._E_loc
        T_loc, D = x.shape
        sel, slot_gate = dispatch_tables(idx, gates, E, C)  # (E, C)
        send = x[sel]  # (E, C, D) outgoing slots, grouped by global expert
        recv = jax.lax.all_to_all(
            send.reshape(ep, E_loc, C, D), self._ep_axis, split_axis=0, concat_axis=0
        )  # (ep, E_loc, C, D): slot block from every sender for my experts
        xe = recv.transpose(1, 0, 2, 3).reshape(E_loc, ep * C, D)
        self._sel, self._slot_gate, self._T_loc = sel, slot_gate, T_loc
        self.layout = DispatchLayout("padded", E_loc, capacity=ep * C)
        return xe

    def combine(self, ye: jax.Array) -> jax.Array:
        E, C, ep, E_loc = self._E, self._C, self._ep, self._E_loc
        D = ye.shape[-1]
        back = ye.reshape(E_loc, ep, C, D).transpose(1, 0, 2, 3)
        ret = jax.lax.all_to_all(back, self._ep_axis, split_axis=0, concat_axis=0)
        ret = ret.reshape(E, C, D) * self._slot_gate[..., None].astype(ye.dtype)
        return jnp.zeros((self._T_loc, D), ret.dtype).at[
            self._sel.reshape(E * C)
        ].add(ret.reshape(E * C, D))

    def apply(
        self,
        experts,
        x: jax.Array,
        gates: jax.Array,
        idx: jax.Array,
        use_kernel: bool = False,
    ) -> jax.Array:
        plan, moe = self.plan, self.moe
        mesh = plan.mesh
        ep_axis = plan.ep_axis
        assert ep_axis is not None and plan.moe_mode == "ep"
        ep = mesh.shape[ep_axis]
        T = x.shape[0]
        E = moe.num_experts
        token_axes = tuple(plan.batch_axes) + (ep_axis,)
        shards = int(np.prod([mesh.shape[a] for a in token_axes]))
        assert T % shards == 0, (T, shards)
        self._ep_axis, self._ep = ep_axis, ep
        self._E, self._E_loc = E, E // ep
        self._C = capacity(moe, T // shards)

        w_specs = jax.tree.map(lambda _: P(ep_axis, None, None), experts)

        def local_moe(x_l, gates_l, idx_l, experts_l):
            xe = self.dispatch(x_l, idx_l, gates_l)
            ye = expert_ffn(experts_l, xe[None], self.layout, use_kernel)[0]
            return self.combine(ye)

        fn = shard_map(
            local_moe,
            mesh=mesh,
            in_specs=(
                P(token_axes, None), P(token_axes, None), P(token_axes, None), w_specs,
            ),
            out_specs=P(token_axes, None),
            check_rep=False,
        )
        return fn(x, gates, idx, experts)
