"""Mixture-of-Experts layer: router + TokenDispatcher orchestration.

All dispatch/combine logic lives in the ``repro.core.dispatch`` subsystem;
this module routes tokens, picks the dispatcher, and applies the dense
residual. Three dispatchers (paper §3.2 tuning practice #2 + dropless):

* ``allgather`` — global-view pjit: tokens stay replicated over the EP
  axis, each expert shard gathers the (<= capacity) tokens routed to its
  local experts, combine is a scatter-add reduced over the EP axis.
* ``alltoall``  — shard_map formulation with explicit ``jax.lax.all_to_all``
  over the EP axis (preferred for small top-k, per the paper).
* ``sorted``    — MegaBlocks-style argsort token permutation into a flat
  (T*k, D) expert-sorted buffer + per-expert group_sizes; true dropless
  with no padded-capacity blow-up. Recommended with
  ``capacity_factor=None``.

Capacity (paper §2, padded dispatchers only): ``C = ceil(k *
tokens_per_group / E * CF)``; overflowing tokens are dropped from expert
compute and pass through on the residual stream. ``capacity_factor=None`` =
dropless (padded layout: C = tokens_per_group; sorted layout: exact).

Expert placement follows the FoldingPlan: 'expert' -> EP axis when the
expert count divides it, else expert hidden dim -> 'model' (expert-TP) —
MoE Parallel Folding on a fixed physical mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig
from repro.core.dispatch import (
    capacity,
    dispatch_tables,
    expert_choice_tables,
    get_dispatcher,
)
from repro.core.router import route, router_decl
from repro.models.layers import mlp_apply, mlp_decl
from repro.sharding.rules import FoldingPlan, ParamDecl

# Backward-compat alias: tests/benchmarks import the table builder under its
# pre-subsystem name.
_dispatch_tables = dispatch_tables

__all__ = [
    "moe_decl",
    "moe_apply",
    "capacity",
    "dispatch_tables",
    "_dispatch_tables",
    "expert_choice_tables",
]


def moe_decl(cfg: ModelConfig, moe: MoEConfig) -> Dict[str, Any]:
    D = cfg.d_model
    F = moe.experts_ff(cfg.d_ff)
    E = moe.num_experts
    dt = jnp.bfloat16
    decls: Dict[str, Any] = {
        "router": router_decl(D, moe),
        "experts": {
            "w_gate": ParamDecl((E, D, F), ("expert", "embed", "expert_ff"), "fan_in", dt),
            "w_up": ParamDecl((E, D, F), ("expert", "embed", "expert_ff"), "fan_in", dt),
            "w_down": ParamDecl((E, F, D), ("expert", "expert_ff", "embed"), "fan_in", dt),
        },
    }
    if moe.dense_residual:
        decls["dense_residual"] = mlp_decl(D, cfg.d_ff, dt)
    return decls


def moe_apply(
    cfg: ModelConfig,
    moe: MoEConfig,
    plan: Optional[FoldingPlan],
    params,
    x: jax.Array,  # (B, S, D)
    rng: Optional[jax.Array] = None,
    train: bool = False,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    if moe.router_type == "expert_choice":
        from repro.core.router import route_full

        gates, idx, aux = route_full(moe, params["router"], xf)
    else:
        gates, idx, aux = route(moe, params["router"], xf, rng, train)

    dispatcher = get_dispatcher(cfg, moe, plan, T, B)
    out = dispatcher.apply(params["experts"], xf, gates, idx, use_kernel)

    out = out.reshape(B, S, D).astype(x.dtype)
    if moe.dense_residual:
        out = out + mlp_apply(params["dense_residual"], x)
    return out, aux
