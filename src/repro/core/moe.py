"""Mixture-of-Experts layer with capacity-factor token dropping and the two
Megatron-Core token dispatchers (paper §3.2 tuning practice #2):

* ``allgather`` — global-view pjit formulation: tokens stay replicated over
  the EP axis, each expert shard gathers the (<= capacity) tokens routed to
  its local experts, and the combine is a scatter-add whose cross-shard
  reduction XLA lowers to an all-reduce/reduce-scatter over the EP axis.
* ``alltoall``  — shard_map formulation with explicit ``jax.lax.all_to_all``
  over the EP axis (preferred for small top-k, per the paper).

Capacity (paper §2): ``C = ceil(k * tokens_per_group / E * CF)``; overflowing
tokens are dropped from expert compute and pass through on the residual
stream. ``capacity_factor=None`` = dropless (C = tokens_per_group).

Expert placement follows the FoldingPlan: 'expert' -> EP axis when the
expert count divides it, else expert hidden dim -> 'model' (expert-TP) —
MoE Parallel Folding on a fixed physical mesh.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, MoEConfig
from repro.core.router import route, router_decl
from repro.models.layers import mlp_apply, mlp_decl
from repro.sharding.rules import FoldingPlan, ParamDecl


def moe_decl(cfg: ModelConfig, moe: MoEConfig) -> Dict[str, Any]:
    D = cfg.d_model
    F = moe.experts_ff(cfg.d_ff)
    E = moe.num_experts
    dt = jnp.bfloat16
    decls: Dict[str, Any] = {
        "router": router_decl(D, moe),
        "experts": {
            "w_gate": ParamDecl((E, D, F), ("expert", "embed", "expert_ff"), "fan_in", dt),
            "w_up": ParamDecl((E, D, F), ("expert", "embed", "expert_ff"), "fan_in", dt),
            "w_down": ParamDecl((E, F, D), ("expert", "expert_ff", "embed"), "fan_in", dt),
        },
    }
    if moe.dense_residual:
        decls["dense_residual"] = mlp_decl(D, cfg.d_ff, dt)
    return decls


def capacity(moe: MoEConfig, tokens_per_group: int) -> int:
    if moe.capacity_factor is None:
        return tokens_per_group  # dropless: worst case, one expert takes all
    c = math.ceil(moe.top_k * tokens_per_group / moe.num_experts * moe.capacity_factor)
    # an expert can receive each token at most once -> capacity <= T
    return max(min(int(c), tokens_per_group), 1)


def _num_groups(plan: Optional[FoldingPlan], total_tokens: int, batch: int) -> int:
    """Tokens are dispatched in groups (GShard-style) so capacity and the
    dispatch working set stay per-data-shard. Groups = batch shards."""
    if plan is None:
        return 1
    g = int(np.prod([plan.mesh.shape[a] for a in plan.batch_axes])) or 1
    while g > 1 and (batch % g != 0 or total_tokens % g != 0):
        g -= 1
    return max(g, 1)


def expert_choice_tables(
    probs_full: jax.Array, E: int, C: int
) -> Tuple[jax.Array, jax.Array]:
    """Expert-Choice routing (Zhou et al., cited by the paper as the
    alternative to Top-k): each EXPERT picks its top-C tokens by router
    probability — perfect load balance by construction, no capacity
    overflow, variable experts-per-token. probs_full: (T, E).
    Returns (sel (E,C) token ids, slot_gate (E,C))."""
    scores = probs_full.T  # (E, T)
    g, sel = jax.lax.top_k(scores, C)  # per-expert top-C tokens
    return sel.astype(jnp.int32), g.astype(jnp.float32)


def _dispatch_tables(
    idx: jax.Array, gates: jax.Array, E: int, C: int
) -> Tuple[jax.Array, jax.Array]:
    """Per-group dispatch bookkeeping.

    idx/gates: (T, k). Returns (sel (E, C) int32 token ids,
    slot_gate (E, C) fp32 combine weights). Overflow (position >= C) is
    dropped: its slot_gate is 0. Priority is token-major order (the paper /
    Megatron drop rule)."""
    T, k = idx.shape
    flat_e = idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (Tk, E)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1  # (Tk,)
    keep = pos < C
    safe_pos = jnp.where(keep, pos, C)  # overflow -> dump column C
    token_id = (jnp.arange(T * k, dtype=jnp.int32) // k).astype(jnp.int32)
    gate_flat = jnp.where(keep, gates.reshape(T * k), 0.0)

    sel = jnp.zeros((E, C + 1), jnp.int32).at[flat_e, safe_pos].set(token_id)
    slot_gate = jnp.zeros((E, C + 1), jnp.float32).at[flat_e, safe_pos].set(gate_flat)
    return sel[:, :C], slot_gate[:, :C]


def _expert_ffn(experts, xe: jax.Array, use_kernel: bool = False) -> jax.Array:
    """xe: (..., E, C, D) -> (..., E, C, D). Fused-SwiGLU expert GEMM; the
    Pallas kernel (kernels/expert_gemm.py) implements this contraction on
    TPU and is validated against this XLA path."""
    if use_kernel:
        from repro.kernels.ops import expert_gemm

        return expert_gemm(xe, experts["w_gate"], experts["w_up"], experts["w_down"])
    g = jnp.einsum("...ecd,edf->...ecf", xe, experts["w_gate"])
    u = jnp.einsum("...ecd,edf->...ecf", xe, experts["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
    return jnp.einsum("...ecf,efd->...ecd", h, experts["w_down"])


# ---------------------------------------------------------------------------
# AllGather dispatcher (global-view pjit)
# ---------------------------------------------------------------------------


def _moe_allgather(
    cfg: ModelConfig,
    moe: MoEConfig,
    plan: Optional[FoldingPlan],
    params,
    x: jax.Array,  # (T, D) flattened tokens, replicated over the EP axis
    gates: jax.Array,
    idx: jax.Array,
    groups: int,
    use_kernel: bool,
) -> jax.Array:
    T, D = x.shape
    E, k = moe.num_experts, moe.top_k
    Tg = T // groups
    C = capacity(moe, Tg)

    xg = x.reshape(groups, Tg, D)
    if moe.router_type == "expert_choice":
        # gates here carries the full (T, E) probability matrix
        sel, slot_gate = jax.vmap(lambda p: expert_choice_tables(p, E, C))(
            gates.reshape(groups, Tg, E)
        )
    else:
        sel, slot_gate = jax.vmap(lambda i, g: _dispatch_tables(i, g, E, C))(
            idx.reshape(groups, Tg, k), gates.reshape(groups, Tg, k)
        )
    if plan is not None:
        xg = plan.constrain(xg, "batch", None, None)
        sel = plan.constrain(sel, "batch", None, None)

    # dispatch: local gather (tokens replicated over EP axis within a group)
    xe = jax.vmap(lambda xs, s: xs[s])(xg, sel)  # (G, E, C, D)
    if plan is not None:
        xe = plan.constrain(xe, "batch", "expert", None, None)

    ye = _expert_ffn(params["experts"], xe, use_kernel)  # (G, E, C, D)
    ye = ye * slot_gate[..., None].astype(ye.dtype)

    # combine: scatter-add back to token order; contributions from different
    # expert shards reduce over the EP axis.
    def combine(y_g, sel_g):
        flat = y_g.reshape(E * C, D)
        return jnp.zeros((Tg, D), flat.dtype).at[sel_g.reshape(E * C)].add(flat)

    out = jax.vmap(combine)(ye, sel)  # (G, Tg, D)
    if plan is not None:
        out = plan.constrain(out, "batch", None, None)
    return out.reshape(T, D)


# ---------------------------------------------------------------------------
# AllToAll dispatcher (shard_map + lax.all_to_all over the EP axis)
# ---------------------------------------------------------------------------


def _moe_alltoall(
    cfg: ModelConfig,
    moe: MoEConfig,
    plan: FoldingPlan,
    params,
    x: jax.Array,  # (T, D)
    gates: jax.Array,
    idx: jax.Array,
    use_kernel: bool,
) -> jax.Array:
    mesh = plan.mesh
    ep_axis = plan.ep_axis
    assert ep_axis is not None and plan.moe_mode == "ep"
    ep = mesh.shape[ep_axis]
    T, D = x.shape
    E, k = moe.num_experts, moe.top_k
    token_axes = tuple(plan.batch_axes) + (ep_axis,)
    shards = int(np.prod([mesh.shape[a] for a in token_axes]))
    assert T % shards == 0, (T, shards)
    T_loc = T // shards
    C = capacity(moe, T_loc)
    E_loc = E // ep

    w_specs = jax.tree.map(
        lambda _: P(ep_axis, None, None), params["experts"]
    )

    def local_moe(x_l, gates_l, idx_l, experts_l):
        # x_l: (T_loc, D); experts_l: (E_loc, D, F) etc.
        sel, slot_gate = _dispatch_tables(idx_l, gates_l, E, C)  # (E, C)
        send = x_l[sel]  # (E, C, D) outgoing slots, grouped by global expert
        recv = jax.lax.all_to_all(
            send.reshape(ep, E_loc, C, D), ep_axis, split_axis=0, concat_axis=0
        )  # (ep, E_loc, C, D): slot block from every sender for my experts
        xe = recv.transpose(1, 0, 2, 3).reshape(E_loc, ep * C, D)
        ye = _expert_ffn(experts_l, xe[None], use_kernel)[0]
        back = ye.reshape(E_loc, ep, C, D).transpose(1, 0, 2, 3)
        ret = jax.lax.all_to_all(back, ep_axis, split_axis=0, concat_axis=0)
        ret = ret.reshape(E, C, D) * slot_gate[..., None].astype(ye.dtype)
        out = jnp.zeros((T_loc, D), ret.dtype).at[sel.reshape(E * C)].add(
            ret.reshape(E * C, D)
        )
        return out

    fn = shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(P(token_axes, None), P(token_axes, None), P(token_axes, None), w_specs),
        out_specs=P(token_axes, None),
        check_rep=False,
    )
    return fn(x, gates, idx, params["experts"])


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------


def moe_apply(
    cfg: ModelConfig,
    moe: MoEConfig,
    plan: Optional[FoldingPlan],
    params,
    x: jax.Array,  # (B, S, D)
    rng: Optional[jax.Array] = None,
    train: bool = False,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    if moe.router_type == "expert_choice":
        from repro.core.router import route_full

        gates, idx, aux = route_full(moe, params["router"], xf)
    else:
        gates, idx, aux = route(moe, params["router"], xf, rng, train)

    use_a2a = (
        moe.dispatcher == "alltoall"
        and moe.router_type != "expert_choice"  # EC gates are (T, E)
        and plan is not None
        and plan.moe_mode == "ep"
        and T % int(
            np.prod([plan.mesh.shape[a] for a in tuple(plan.batch_axes) + (plan.ep_axis,)])
        )
        == 0
    )
    if use_a2a:
        out = _moe_alltoall(cfg, moe, plan, params, xf, gates, idx, use_kernel)
    else:
        groups = _num_groups(plan, T, B)
        out = _moe_allgather(cfg, moe, plan, params, xf, gates, idx, groups, use_kernel)

    out = out.reshape(B, S, D).astype(x.dtype)
    if moe.dense_residual:
        out = out + mlp_apply(params["dense_residual"], x)
    return out, aux
