"""Symmetric int8 quantization for expert FFN weights and KV cache pages.

Two quantization surfaces, both serving/inference-only (training and the
PR 2 backward kernels stay bf16):

Expert weights (``quantize_experts``): per-expert, per-*output-channel*
symmetric scales — gate/up scale over F, down over D. Because the scale is
constant along the contraction dim, dequantization commutes with the
matmul: the Pallas kernels (kernels/expert_gemm.py) load int8 weight
tiles, accumulate in fp32, and apply the scale once in the epilogue — an
*exact* rewrite of dequantize-then-matmul, so kernel-vs-oracle parity is
tight and the only error is the rounding step itself. Scales are bf16 and
carry the same leading ``("expert", ...)`` logical axis as their weights,
so `FoldingPlan`/EP sharding splits them alongside their experts
(``quantize_decls``).

KV pages (``quantize_kv``): per-written-token, per-kv-head symmetric
scales stored in a sidecar pool leaf shaped ``(periods, num_pages,
page_size, KV, 1)``. Page-granular scales cannot survive incremental
decode writes (a later token cannot retroactively rescale the page), so
the sidecar is indexed exactly like the page payload and rides every
pool-tree operation (COW ``copy_pages``, defrag ``permute_pool``, DP
``pool_sharding``) structurally — the no-desync property tested in
tests/test_quant.py. Sidecar scales are f32: they are ~3% of page bytes
and keep the dequant error budget for greedy-token parity.

Error-budget contract (asserted in tests/test_quant.py):
* kernel vs quantized oracle: allclose at ``KERNEL_PARITY_TOL`` (the
  kernels are an exact rewrite; only accumulation order differs);
* quantized vs bf16 model: final-layer logits within
  ``INT8_LOGIT_BUDGET`` max-abs on the e8t2 smoke config, and greedy
  tokens *exactly* equal over a short decode.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

EXPERT_KEYS = ("w_gate", "w_up", "w_down")
QUANT_MODES = ("none", "int8")

# --- error-budget contract (see tests/test_quant.py) -----------------------
# int8 kernel vs the *quantized* oracle: same math, different accumulation
# order -> tight.
KERNEL_PARITY_TOL = 2e-2
# quantized-weight logits vs the bf16 model on the e8t2 smoke config
# (max-abs over the final logits; int8 rounding error through 2 MoE layers).
INT8_LOGIT_BUDGET = 0.25
# quantized-KV decode logits vs bf16 pages, single step.
INT8_KV_LOGIT_BUDGET = 0.25

_EPS = 1e-8
KV_SCALE_DTYPE = jnp.float32


def quantize_weight(w: jax.Array):
    """``(..., K, C) -> (int8 (..., K, C), bf16 (..., C))`` symmetric
    per-output-channel abs-max scales (axis -2 is the contraction dim)."""
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2)
    scale = jnp.maximum(amax, _EPS) / 127.0
    q = jnp.clip(jnp.round(wf / scale[..., None, :]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def dequantize_weight(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None, :]).astype(dtype)


def is_quantized(experts: Dict[str, jax.Array]) -> bool:
    return "w_gate_scale" in experts


def quantize_experts(experts: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Quantize an expert-FFN param dict ``{w_gate, w_up, w_down}`` (any
    leading dims, e.g. scanned layers) into int8 values + ``*_scale``
    bf16 sidecar entries. Idempotent on already-quantized dicts."""
    if is_quantized(experts):
        return experts
    out = dict(experts)
    for k in EXPERT_KEYS:
        q, s = quantize_weight(experts[k])
        out[k] = q
        out[k + "_scale"] = s
    return out


def dequantize_experts(experts: Dict[str, jax.Array], dtype) -> Dict[str, jax.Array]:
    """Inverse of :func:`quantize_experts` for XLA fallback paths (the
    einsum/ragged_dot dispatchers that don't carry fused-dequant kernels)."""
    if not is_quantized(experts):
        return experts
    return {
        k: dequantize_weight(experts[k], experts[k + "_scale"], dtype)
        for k in EXPERT_KEYS
    }


def _is_expert_dict(node) -> bool:
    return isinstance(node, dict) and all(k in node for k in EXPERT_KEYS)


def quantize_params(params):
    """Walk a model param pytree and quantize every expert-FFN dict in
    place (structurally — returns a new tree). Non-expert leaves pass
    through untouched; attention/embedding/router stay bf16."""
    if _is_expert_dict(params):
        return quantize_experts(params)
    if isinstance(params, dict):
        return {k: quantize_params(v) for k, v in params.items()}
    if isinstance(params, (list, tuple)):
        return type(params)(quantize_params(v) for v in params)
    return params


def quantize_decls(decls):
    """Mirror of :func:`quantize_params` over a ``ParamDecl`` tree: expert
    weight decls become int8 and gain bf16 ``*_scale`` decls whose axes
    drop the contraction dim — the leading ``("expert", ...)`` logical
    axis is preserved so scales shard alongside their experts under the
    FoldingPlan/EP rules."""
    import dataclasses

    from repro.sharding.rules import ParamDecl

    def _q(node):
        if _is_expert_dict(node) and all(
            isinstance(node[k], ParamDecl) for k in EXPERT_KEYS
        ):
            out = dict(node)
            for k in EXPERT_KEYS:
                d = node[k]
                out[k] = dataclasses.replace(d, dtype=jnp.int8, init="zeros")
                out[k + "_scale"] = ParamDecl(
                    d.shape[:-2] + d.shape[-1:],
                    d.axes[:-2] + d.axes[-1:],
                    "ones",
                    jnp.bfloat16,
                )
            return out
        if isinstance(node, dict):
            return {k: _q(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(_q(v) for v in node)
        return node

    return _q(decls)


# --- KV page quantization ---------------------------------------------------


def quantize_kv(x: jax.Array):
    """``(..., d) -> (int8 (..., d), f32 (..., 1))`` per-vector (token x
    kv-head) symmetric scales — the granularity that survives incremental
    page writes."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, _EPS) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(KV_SCALE_DTYPE)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


# --- greedy-parity probe model ---------------------------------------------


def sharpen_for_parity(cfg, params, steps: int = 80, seed: int = 0,
                       seq_len: int = 64, batch: int = 8, period: int = 32,
                       lr: float = 0.5):
    """Fit a greedy-parity probe: a few SGD steps on a fixed periodic token
    stream (a deterministic next-token task the smoke model memorizes).

    Greedy-token parity checked against a *random-init* model is vacuous —
    its logits are near-uniform, so argmax flips under any perturbation,
    int8 rounding included. After this, top-1 margins are O(1) while the
    int8 error budget is O(0.01), so "exact greedy parity" becomes a
    seed-robust, meaningful assertion (tests/test_quant.py and the
    BENCH_serving quant section both use it).

    Returns ``(params, pattern)``: the sharpened params and the (period,)
    int32 token pattern — build prompts from slices of it so decode stays
    in-distribution where the margins are."""
    import numpy as np

    from repro.models.model import loss_fn

    rng = np.random.RandomState(seed)
    pattern = rng.randint(1, max(2, cfg.vocab_size - 124), size=period)
    seq = np.tile(pattern, seq_len // period + 2)
    toks = jnp.asarray(
        np.stack([np.roll(seq, -i)[: seq_len + 1] for i in range(batch)]),
        jnp.int32,
    )
    data = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(
            lambda p: loss_fn(cfg, None, p, data)[0]
        )(p)
        # plain SGD with an fp32 update (bf16 params round-trip per step)
        return jax.tree.map(
            lambda a, b: (a.astype(jnp.float32) - lr * b.astype(jnp.float32))
            .astype(a.dtype),
            p, g,
        ), loss

    for _ in range(steps):
        params, _ = step(params)
    return params, pattern.astype(np.int32)
