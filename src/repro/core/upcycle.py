"""Sparse upcycling (paper §3.1) + online sharded upcycling (§3.1, NeMo).

``upcycle_config``  — derive the MoE ModelConfig from a dense one.
``upcycle_params``  — dense params -> MoE params: every converted FFN's
weights are broadcast N times into the experts (each expert starts as an
exact copy), the router is randomly initialized, and everything else is
copied verbatim.

Online upcycling: ``upcycle_params`` is a pure function of the dense pytree,
so the launcher jits it with ``out_shardings`` from the *MoE* parallel
config. Each device then materializes only its own expert shard:

* EP placement  — the dense FFN weight (replicated over the EP axis) is
  tiled into the expert dim, which XLA lowers to a local broadcast+slice on
  every device; no cross-device weight copying.
* ETP placement — the dense FFN weight arrives already sharded over 'model'
  on its hidden dim and each expert copy keeps that shard: local tile.

``tests/test_upcycle.py`` asserts the compiled HLO contains no gather
collectives and that the upcycled model's first forward pass is exactly the
dense model's output (Mixtral-type router; §5.2 / Fig. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig
from repro.core.router import router_decl
from repro.models.transformer import build_slots, periods_for
from repro.sharding.rules import ParamDecl, init_from_decls


def dense_input_shardings(dense_cfg: ModelConfig, moe_cfg: ModelConfig, plan):
    """Shardings to load the dense checkpoint with so that online upcycling
    is collective-free (paper §3.1: the dense checkpoint is sharded based on
    the *target* parallel config). With EP expert placement the dense FFN
    hidden dim must arrive replicated over the EP axis — each device then
    fills its local experts with a purely local broadcast+slice."""
    from repro.models.model import model_decl
    from repro.sharding.rules import FoldingPlan, shardings_from_decls

    moe_plan = FoldingPlan.make(moe_cfg, plan.mesh)
    overrides = None
    if moe_plan.moe_mode == "ep" and moe_plan.ep_axis == "model":
        overrides = {"ff": (None,)}  # keep dense FFN whole on the EP axis
    return shardings_from_decls(model_decl(dense_cfg), plan, overrides)


def upcycle_provenance(
    dense_cfg: ModelConfig,
    moe_cfg: ModelConfig,
    source_ckpt: Optional[str] = None,
) -> Dict[str, Any]:
    """Provenance block recorded into full-state checkpoint manifests of an
    upcycled run. On ``--resume`` the launcher sees this and restarts from
    the latest MoE TrainState instead of re-upcycling the dense source —
    the upcycle is a one-time init, not part of the resume path."""
    m = moe_cfg.moe
    assert m is not None
    return {
        "upcycled": True,
        "dense_config": dense_cfg.name,
        "moe_config": moe_cfg.name,
        "num_experts": m.num_experts,
        "top_k": m.top_k,
        "capacity_factor": m.capacity_factor,
        "router_type": m.router_type,
        "source_ckpt": source_ckpt,
    }


def upcycle_config(dense: ModelConfig, moe: MoEConfig, name: Optional[str] = None) -> ModelConfig:
    """Dense config -> N-Expert Top-k MoE config (family 'moe'/'hybrid')."""
    assert dense.d_ff > 0, "cannot upcycle an FFN-free architecture (see DESIGN.md)"
    assert dense.num_layers % moe.moe_layer_freq == 0
    family = dense.family
    if family in ("dense", "vlm"):
        family = "moe" if family == "dense" else "vlm"
    return dense.replace(
        name=name or f"{dense.name}-e{moe.num_experts}t{moe.top_k}",
        family=family,
        moe=moe,
    )


def _regroup_stacked(x: jax.Array, old_periods: int, new_periods: int, slot: int, nslots: int):
    """Reslice a (old_periods, ...) stacked param into the new period/slot
    grouping: layer l = p*nslots + slot."""
    if old_periods == new_periods and nslots == 1:
        return x
    # old grouping assumed single-slot (dense): (L, ...) -> (new_periods, nslots, ...)
    L = x.shape[0]
    assert L == new_periods * nslots, (L, new_periods, nslots)
    return x.reshape((new_periods, nslots) + x.shape[1:])[:, slot]


def upcycle_params(
    dense_cfg: ModelConfig,
    moe_cfg: ModelConfig,
    dense_params: Dict[str, Any],
    rng: jax.Array,
    expert_noise: float = 0.0,
) -> Dict[str, Any]:
    """Pure function: dense checkpoint pytree -> upcycled MoE pytree.

    Works for dense->moe and vlm->vlm(+moe); the dense stack must be
    single-slot (homogeneous). Jit this with sharded out_shardings for the
    online (per-device) variant.

    ``expert_noise`` > 0 perturbs each expert copy with relative Gaussian
    noise (He et al. [10] symmetry breaking); 0 (paper default) keeps exact
    copies and the function-preserving init.
    """
    moe = moe_cfg.moe
    assert moe is not None
    dense_slots = build_slots(dense_cfg)
    assert len(dense_slots) == 1, "upcycling expects a homogeneous dense stack"
    new_slots = build_slots(moe_cfg)
    nslots = len(new_slots)
    old_p = periods_for(dense_cfg, dense_slots)
    new_p = periods_for(moe_cfg, new_slots)

    out: Dict[str, Any] = {k: v for k, v in dense_params.items() if k != "stack"}
    dstack = dense_params["stack"]["slot0"]
    new_stack: Dict[str, Any] = {}
    E = moe.num_experts
    F = moe.experts_ff(moe_cfg.d_ff)
    rngs = jax.random.split(rng, nslots)
    for i, spec in enumerate(new_slots):
        slot_params = jax.tree.map(
            lambda x: _regroup_stacked(x, old_p, new_p, i, nslots), dstack
        )
        if spec.ffn == "moe":
            mlp = slot_params.pop("ffn")
            assert mlp["w_gate"].shape[-1] == F, (
                "expert_d_ff must match the dense d_ff for weight copying"
            )
            experts = {
                # (P, D, F) -> (P, E, D, F): each expert is an exact copy
                "w_gate": jnp.broadcast_to(mlp["w_gate"][:, None], (new_p, E) + mlp["w_gate"].shape[1:]),
                "w_up": jnp.broadcast_to(mlp["w_up"][:, None], (new_p, E) + mlp["w_up"].shape[1:]),
                "w_down": jnp.broadcast_to(mlp["w_down"][:, None], (new_p, E) + mlp["w_down"].shape[1:]),
            }
            if expert_noise > 0:
                nkey = jax.random.fold_in(rngs[i], 1)
                for j, kname in enumerate(("w_gate", "w_up", "w_down")):
                    w = experts[kname]
                    noise = jax.random.normal(
                        jax.random.fold_in(nkey, j), w.shape, jnp.float32
                    ) * (expert_noise * jnp.std(w.astype(jnp.float32)))
                    experts[kname] = (w.astype(jnp.float32) + noise).astype(w.dtype)
            router_decls = jax.tree.map(
                lambda d: ParamDecl((new_p,) + d.shape, ("layers",) + d.axes, d.init, d.dtype),
                router_decl(moe_cfg.d_model, moe),
                is_leaf=lambda d: isinstance(d, ParamDecl),
            )
            ffn = {
                "router": init_from_decls(router_decls, rngs[i]),
                "experts": experts,
            }
            if moe.dense_residual:
                ffn["dense_residual"] = mlp
            slot_params["ffn"] = ffn
        new_stack[f"slot{i}"] = slot_params
    out["stack"] = new_stack
    return out
