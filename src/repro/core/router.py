"""Routing algorithms (paper §2 Eq. 2-4 and §5.2).

Two router types, differing in the order of Softmax and KeepTopK:

* ``mixtral`` — ``softmax(topk(logits))``: gates are a softmax over the k
  surviving logits, so they sum to 1. With all experts identical (the
  upcycled init) the MoE output equals the dense FFN output exactly — the
  property the paper relies on for fast convergence (Fig. 3).
* ``st``      — ``topk(softmax(logits))``: keeps the absolute magnitudes of
  the router probabilities (gates do NOT sum to 1 for k < N), so the
  upcycled init no longer matches the dense model.

Optionally Noisy Top-K gating (Eq. 3): logits += N(0,1) * softplus(x @ W_noise).

Router math runs in fp32 regardless of the model dtype.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import MoEConfig
from repro.sharding.rules import ParamDecl


def router_decl(d_model: int, moe: MoEConfig) -> Dict[str, ParamDecl]:
    decls = {
        "w_g": ParamDecl((d_model, moe.num_experts), ("embed", "expert"), "normal:0.02", jnp.float32)
    }
    if moe.noisy_gating:
        decls["w_noise"] = ParamDecl(
            (d_model, moe.num_experts), ("embed", "expert"), "zeros", jnp.float32
        )
    return decls


def route(
    moe: MoEConfig,
    params,
    x: jax.Array,
    rng: Optional[jax.Array] = None,
    train: bool = False,
) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """x: (..., D). Returns (gates (..., k) fp32, expert_idx (..., k) int32, aux).

    aux contains the Switch-style load-balance loss and the router z-loss,
    both computed from the full (pre-top-k) softmax distribution.
    """
    xf = x.astype(jnp.float32)
    logits = xf @ params["w_g"]  # (..., E)
    if moe.noisy_gating and train and rng is not None:
        noise_std = jax.nn.softplus(xf @ params["w_noise"])
        logits = logits + jax.random.normal(rng, logits.shape) * noise_std

    probs_full = jax.nn.softmax(logits, axis=-1)

    if moe.router_type == "mixtral":
        top_logits, idx = jax.lax.top_k(logits, moe.top_k)
        gates = jax.nn.softmax(top_logits, axis=-1)
    elif moe.router_type == "st":
        gates, idx = jax.lax.top_k(probs_full, moe.top_k)
    else:
        raise ValueError(f"unknown router_type {moe.router_type}")

    # ---- aux losses -------------------------------------------------------
    E = moe.num_experts
    # fraction of token-assignments per expert (hard counts)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (..., k, E)
    f = jnp.mean(jnp.sum(onehot, axis=-2), axis=tuple(range(onehot.ndim - 2)))
    f = f / moe.top_k  # normalized dispatch fraction, sums to 1
    p = jnp.mean(probs_full, axis=tuple(range(probs_full.ndim - 1)))
    load_balance = E * jnp.sum(f * p)
    z = jax.nn.logsumexp(logits, axis=-1)
    z_loss = jnp.mean(jnp.square(z))
    aux = {
        "load_balance_loss": load_balance * moe.aux_loss_coef,
        "z_loss": z_loss * moe.z_loss_coef,
        "router_entropy": -jnp.mean(
            jnp.sum(probs_full * jnp.log(probs_full + 1e-9), axis=-1)
        ),
        "expert_fraction_max": jnp.max(f),
    }
    return gates, idx.astype(jnp.int32), aux


def route_full(moe: MoEConfig, params, x: jax.Array):
    """Expert-Choice support: returns the FULL (T, E) probability matrix as
    'gates' (dispatch picks per-expert top-C) plus the same aux losses.
    idx is a dummy top-1 (unused by the EC dispatch path)."""
    xf = x.astype(jnp.float32)
    logits = xf @ params["w_g"]
    probs_full = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(logits, 1)
    z = jax.nn.logsumexp(logits, axis=-1)
    aux = {
        # EC is load-balanced by construction; keep only the z-loss
        "load_balance_loss": jnp.zeros((), jnp.float32),
        "z_loss": jnp.mean(jnp.square(z)) * moe.z_loss_coef,
        "router_entropy": -jnp.mean(
            jnp.sum(probs_full * jnp.log(probs_full + 1e-9), axis=-1)
        ),
        "expert_fraction_max": jnp.float32(1.0 / moe.num_experts),
    }
    return probs_full, idx.astype(jnp.int32), aux
