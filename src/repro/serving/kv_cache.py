"""Block-table KV cache: a fixed-size page pool shared by every sequence.

The device side is a per-layer ``(num_pages + 1, page_size, KV, hd)`` k/v
pool (``models.model.paged_stack_decl``; the extra page is the trash page
padded positions scatter into). The host side is :class:`PagePool` — a
free-list allocator tracking which physical pages each request owns — plus
per-slot block tables mapping logical page index -> physical page.

Logical KV slot ``j`` of a request maps to
``pool[table[j // page_size], j % page_size]``: the identity position
mapping. Unlike the ring buffer, pages never wrap; a sliding-window config
instead *releases* pages that fall entirely below the window (the window
mask already excludes them, so the tokens are dead).

Memory accounting (``kv_bytes_resident``) counts only pages actually
allocated to live requests — the number the serving bench compares against
the ring cache's ``max_batch * max_seq`` dense footprint.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.model import paged_stack_decl
from repro.sharding.rules import ParamDecl


class PagePool:
    """Host-side allocator over ``num_pages`` usable pages.

    Invariants (asserted by :meth:`check_invariants` and exercised by the
    property suite): every page is either free or owned by exactly one
    request; ``free_pages + sum(owned) == num_pages`` at all times; a
    drained pool is fully free."""

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages > 0 and page_size > 0
        self.num_pages, self.page_size = num_pages, page_size
        # stack with low ids on top so allocation order is deterministic
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._owned: Dict[int, List[int]] = {}

    # -- queries ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` KV entries."""
        return math.ceil(tokens / self.page_size)

    def owned(self, rid: int) -> List[int]:
        return list(self._owned.get(rid, ()))

    def utilization(self) -> float:
        return self.used_pages / self.num_pages

    # -- mutation -----------------------------------------------------------
    def alloc(self, rid: int, n: int = 1) -> Optional[List[int]]:
        """Allocate ``n`` pages for ``rid``; None (no partial effect) if the
        pool cannot satisfy the request."""
        if n < 0 or n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(rid, []).extend(pages)
        return pages

    def release(self, rid: int, pages: List[int]) -> None:
        """Return specific pages owned by ``rid`` (dead sliding-window
        pages) to the free list."""
        owned = self._owned.get(rid, [])
        for p in pages:
            owned.remove(p)  # raises if not owned — double-free is a bug
            self._free.append(p)
        if not owned and rid in self._owned:
            del self._owned[rid]

    def free_request(self, rid: int) -> int:
        """Free every page owned by ``rid``; returns how many."""
        pages = self._owned.pop(rid, [])
        self._free.extend(pages)
        return len(pages)

    def defrag(self) -> Optional[Dict[int, int]]:
        """Compact allocated pages into the low-index prefix. Returns the
        {old_physical: new_physical} mapping (None if already compact); the
        caller must apply it to the device pool (:func:`permute_pool`) and
        every block table in the same step."""
        allocated = sorted(p for pages in self._owned.values() for p in pages)
        mapping = {old: new for new, old in enumerate(allocated) if old != new}
        if not mapping:
            return None
        remap = {old: new for new, old in enumerate(allocated)}
        for pages in self._owned.values():
            pages[:] = [remap.get(p, p) for p in pages]
        n = len(allocated)
        self._free = list(range(self.num_pages - 1, n - 1, -1))
        return mapping

    # -- invariants ---------------------------------------------------------
    def check_invariants(self) -> None:
        owned = [p for pages in self._owned.values() for p in pages]
        assert len(owned) == len(set(owned)), "page double-assigned"
        assert not set(owned) & set(self._free), "page both owned and free"
        assert len(owned) + len(self._free) == self.num_pages, "page leaked"
        assert all(0 <= p < self.num_pages for p in owned + self._free)


def init_paged_pool(cfg: ModelConfig, num_pages: int, page_size: int):
    """Zero-initialized device page pool with ``num_pages`` usable pages
    (+1 trash page at the end, per the ``paged_stack_decl`` convention)."""
    decls = paged_stack_decl(cfg, num_pages + 1, page_size)
    return jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype), decls,
        is_leaf=lambda d: isinstance(d, ParamDecl),
    )


def permute_pool(pool, mapping: Dict[int, int]):
    """Apply a defrag mapping to the device pool: page ``old`` moves to
    index ``new``. Leaves are (P, num_pages, ps, KV, hd); the trash page is
    never remapped."""
    n = jax.tree.leaves(pool)[0].shape[1]
    src = np.arange(n)
    for old, new in mapping.items():
        src[new] = old
    idx = jnp.asarray(src)
    return jax.tree.map(lambda a: jnp.take(a, idx, axis=1), pool)


def kv_page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """Bytes one allocated page pins across the whole stack (k + v, every
    layer)."""
    from repro.models.transformer import build_slots, periods_for

    slots = build_slots(cfg)
    periods = periods_for(cfg, slots)
    per_entry = cfg.num_kv_heads * cfg.head_dim_ * jnp.dtype(cfg.dtype).itemsize
    return 2 * periods * len(slots) * page_size * per_entry


def kv_bytes_resident(cfg: ModelConfig, pool: PagePool) -> int:
    """KV bytes pinned by live requests (the paged-mode resident set)."""
    return pool.used_pages * kv_page_bytes(cfg, pool.page_size)


def ring_kv_bytes(cfg: ModelConfig, max_batch: int, cache_len: int) -> int:
    """Resident KV bytes of the dense ring cache at the same batch — it
    allocates ``max_batch * cache_len`` entries regardless of occupancy."""
    from repro.models.transformer import build_slots, periods_for

    slots = build_slots(cfg)
    periods = periods_for(cfg, slots)
    per_entry = cfg.num_kv_heads * cfg.head_dim_ * jnp.dtype(cfg.dtype).itemsize
    return 2 * periods * len(slots) * max_batch * cache_len * per_entry
