"""Block-table KV cache: a fixed-size page pool shared by every sequence,
optionally partitioned into per-DP-shard sub-pools.

The device side is a per-layer ``(num_shards * (pages_per_shard + 1),
page_size, KV, hd)`` k/v pool (``models.model.paged_stack_decl``): each DP
shard owns a contiguous stride of ``pages_per_shard`` usable pages plus its
own trash page (the slot padded positions scatter into), so the page axis
shards evenly over the mesh 'data' axis and every row's page gather stays
within its shard's stride. With ``num_shards=1`` this reduces to the
original single-host layout: ``num_pages + 1`` device pages, trash last.

The host side is :class:`PagePool` — per-shard free-list allocators
tracking which physical pages each request owns (a request's pages all
come from ONE shard: its KV must be co-resident with its batch row) — plus
per-slot block tables mapping logical page index -> physical page.

Logical KV slot ``j`` of a request maps to
``pool[table[j // page_size], j % page_size]``: the identity position
mapping. Unlike the ring buffer, pages never wrap; a sliding-window config
instead *releases* pages that fall entirely below the window (the window
mask already excludes them, so the tokens are dead).

Memory accounting (``kv_bytes_resident``) counts only pages actually
allocated to live requests — the number the serving bench compares against
the ring cache's ``max_batch * max_seq`` dense footprint.
``kv_bytes_resident_per_shard`` splits it per device; the multi-device
scaling bench checks the per-shard numbers sum to the aggregate and that
aggregate residency grows with DP shard count.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.model import paged_stack_decl
from repro.sharding.rules import FoldingPlan, ParamDecl


class PagePool:
    """Host-side allocator over ``num_pages`` usable pages, split into
    ``num_shards`` equal sub-pools (``num_shards=1`` = single host).

    Physical page ids are device indices: shard ``s`` owns the stride
    ``[s * (pps + 1), s * (pps + 1) + pps)`` where ``pps = num_pages //
    num_shards``; device index ``s * (pps + 1) + pps`` is shard ``s``'s
    trash page and is never allocated. A request is pinned to the shard of
    its first allocation; later allocations come from the same sub-pool.

    Invariants (asserted by :meth:`check_invariants` and exercised by the
    property suite): every page is either free or owned by exactly one
    request; ``free_pages + sum(owned) == num_pages`` at all times; every
    page owned by a request lives in that request's shard; per-shard
    used/free counts sum to the aggregate; a drained pool is fully free."""

    def __init__(self, num_pages: int, page_size: int, num_shards: int = 1):
        assert num_pages > 0 and page_size > 0 and num_shards > 0
        assert num_pages % num_shards == 0, (num_pages, num_shards)
        self.num_pages, self.page_size = num_pages, page_size
        self.num_shards = num_shards
        self.pages_per_shard = num_pages // num_shards
        self._stride = self.pages_per_shard + 1  # usable pages + trash
        # per-shard stacks with low ids on top: allocation order is
        # deterministic and, at num_shards=1, identical to the original
        # single-list pool (0, 1, 2, ...)
        self._free: List[List[int]] = [
            list(range(s * self._stride + self.pages_per_shard - 1,
                       s * self._stride - 1, -1))
            for s in range(num_shards)
        ]
        self._owned: Dict[int, List[int]] = {}
        self._shard_of: Dict[int, int] = {}  # rid -> pinned shard

    # -- queries ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - self.free_pages

    @property
    def device_pages(self) -> int:
        """Device pool size along the page axis (usable + trash pages)."""
        return self.num_shards * self._stride

    def free_pages_in(self, shard: int) -> int:
        return len(self._free[shard])

    def used_pages_in(self, shard: int) -> int:
        return self.pages_per_shard - len(self._free[shard])

    def trash_page(self, shard: int) -> int:
        """Device index of ``shard``'s trash page (writes for padded /
        idle positions of that shard's rows land here)."""
        return shard * self._stride + self.pages_per_shard

    def shard_of_page(self, page: int) -> int:
        return page // self._stride

    def shard_of(self, rid: int) -> Optional[int]:
        """Shard ``rid`` is pinned to (None before its first alloc)."""
        return self._shard_of.get(rid)

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` KV entries."""
        return math.ceil(tokens / self.page_size)

    def owned(self, rid: int) -> List[int]:
        return list(self._owned.get(rid, ()))

    def utilization(self) -> float:
        return self.used_pages / self.num_pages

    # -- mutation -----------------------------------------------------------
    def alloc(self, rid: int, n: int = 1, shard: int = 0) -> Optional[List[int]]:
        """Allocate ``n`` pages for ``rid`` from ``shard``'s sub-pool; None
        (no partial effect) if that sub-pool cannot satisfy the request. A
        rid already holding pages must allocate from its pinned shard."""
        pinned = self._shard_of.get(rid)
        if pinned is not None:
            assert shard == pinned, (rid, shard, pinned)
        free = self._free[shard]
        if n < 0 or n > len(free):
            return None
        from repro.resilience import faults

        if any(s.kind == "alloc_fail" for s in faults.fire("serving.alloc")):
            # transient exhaustion: same contract as a genuinely dry pool
            # (None, no partial effect), so the scheduler's preemption /
            # stall machinery handles it — the chaos suite proves no
            # deadlock and eventual completion
            return None
        pages = [free.pop() for _ in range(n)]
        self._owned.setdefault(rid, []).extend(pages)
        self._shard_of[rid] = shard
        return pages

    def release(self, rid: int, pages: List[int]) -> None:
        """Return specific pages owned by ``rid`` (dead sliding-window
        pages) to their shard's free list."""
        owned = self._owned.get(rid, [])
        for p in pages:
            owned.remove(p)  # raises if not owned — double-free is a bug
            self._free[self.shard_of_page(p)].append(p)
        if not owned and rid in self._owned:
            del self._owned[rid]
            del self._shard_of[rid]

    def free_request(self, rid: int) -> int:
        """Free every page owned by ``rid``; returns how many."""
        pages = self._owned.pop(rid, [])
        self._shard_of.pop(rid, None)
        for p in pages:
            self._free[self.shard_of_page(p)].append(p)
        return len(pages)

    def defrag(self) -> Optional[Dict[int, int]]:
        """Compact allocated pages into the low-index prefix of each
        shard's stride (pages never migrate across shards — their KV lives
        on that shard's device). Returns the {old_physical: new_physical}
        mapping (None if already compact); the caller must apply it to the
        device pool (:func:`permute_pool`) and every block table in the
        same step."""
        remap: Dict[int, int] = {}
        alloc_per_shard: List[int] = []
        for s in range(self.num_shards):
            base = s * self._stride
            allocated = sorted(
                p for pages in self._owned.values() for p in pages
                if self.shard_of_page(p) == s
            )
            alloc_per_shard.append(len(allocated))
            for new, old in enumerate(allocated):
                remap[old] = base + new
        mapping = {old: new for old, new in remap.items() if old != new}
        if not mapping:
            return None
        for pages in self._owned.values():
            pages[:] = [remap.get(p, p) for p in pages]
        for s, n in enumerate(alloc_per_shard):
            base = s * self._stride
            self._free[s] = list(range(
                base + self.pages_per_shard - 1, base + n - 1, -1
            ))
        return mapping

    # -- invariants ---------------------------------------------------------
    def check_invariants(self) -> None:
        owned = [p for pages in self._owned.values() for p in pages]
        flat_free = [p for f in self._free for p in f]
        assert len(owned) == len(set(owned)), "page double-assigned"
        assert not set(owned) & set(flat_free), "page both owned and free"
        assert len(owned) + len(flat_free) == self.num_pages, "page leaked"
        trash = {self.trash_page(s) for s in range(self.num_shards)}
        assert not trash & set(owned + flat_free), "trash page in circulation"
        assert all(0 <= p < self.device_pages for p in owned + flat_free)
        for rid, pages in self._owned.items():
            s = self._shard_of[rid]
            assert all(self.shard_of_page(p) == s for p in pages), (
                f"request {rid} holds pages outside its shard {s}"
            )
        for s, f in enumerate(self._free):
            assert all(self.shard_of_page(p) == s for p in f)
        assert sum(self.used_pages_in(s) for s in range(self.num_shards)) \
            == self.used_pages, "per-shard used counts do not sum to aggregate"


def init_paged_pool(
    cfg: ModelConfig,
    num_pages: int,
    page_size: int,
    num_shards: int = 1,
    plan: Optional[FoldingPlan] = None,
):
    """Zero-initialized device page pool: ``num_pages`` usable pages split
    into ``num_shards`` strides, each with its own trailing trash page (the
    ``paged_stack_decl`` convention generalized; ``num_shards=1`` is the
    original layout). With a ``plan``, the page axis is sharded over the
    mesh batch axes so each DP shard's stride is device-resident locally —
    aggregate HBM then bounds the pool, not one device's worth."""
    assert num_pages % num_shards == 0, (num_pages, num_shards)
    stride = num_pages // num_shards + 1
    decls = paged_stack_decl(cfg, num_shards * stride, page_size)
    pool = jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype), decls,
        is_leaf=lambda d: isinstance(d, ParamDecl),
    )
    if plan is not None:
        sh = pool_sharding(plan)
        pool = jax.tree.map(lambda a: jax.device_put(a, sh), pool)
    return pool


def pool_sharding(plan: FoldingPlan):
    """NamedSharding for pool leaves ``(P, pages, ps, KV, hd)``: the page
    axis shards over the mesh batch axes (one stride per DP shard)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = plan.batch_axes
    part = axes if len(axes) > 1 else (axes[0] if axes else None)
    return NamedSharding(plan.mesh, P(None, part, None, None, None))


def permute_pool(pool, mapping: Dict[int, int]):
    """Apply a defrag mapping to the device pool: page ``old`` moves to
    index ``new``. Leaves are (P, num_pages, ps, KV, hd); the trash page is
    never remapped."""
    n = jax.tree.leaves(pool)[0].shape[1]
    src = np.arange(n)
    for old, new in mapping.items():
        src[new] = old
    idx = jnp.asarray(src)
    return jax.tree.map(lambda a: jnp.take(a, idx, axis=1), pool)


def kv_page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """Bytes one allocated page pins across the whole stack (k + v, every
    layer)."""
    from repro.models.transformer import build_slots, periods_for

    slots = build_slots(cfg)
    periods = periods_for(cfg, slots)
    per_entry = cfg.num_kv_heads * cfg.head_dim_ * jnp.dtype(cfg.dtype).itemsize
    return 2 * periods * len(slots) * page_size * per_entry


def kv_bytes_resident(cfg: ModelConfig, pool: PagePool) -> int:
    """KV bytes pinned by live requests (the paged-mode resident set),
    aggregated over every shard."""
    return pool.used_pages * kv_page_bytes(cfg, pool.page_size)


def kv_bytes_resident_per_shard(cfg: ModelConfig, pool: PagePool) -> List[int]:
    """Per-DP-shard resident KV bytes; sums to :func:`kv_bytes_resident`
    (checked by the shard-accounting property suite)."""
    pb = kv_page_bytes(cfg, pool.page_size)
    return [pool.used_pages_in(s) * pb for s in range(pool.num_shards)]


def ring_kv_bytes(cfg: ModelConfig, max_batch: int, cache_len: int) -> int:
    """Resident KV bytes of the dense ring cache at the same batch — it
    allocates ``max_batch * cache_len`` entries regardless of occupancy."""
    from repro.models.transformer import build_slots, periods_for

    slots = build_slots(cfg)
    periods = periods_for(cfg, slots)
    per_entry = cfg.num_kv_heads * cfg.head_dim_ * jnp.dtype(cfg.dtype).itemsize
    return 2 * periods * len(slots) * max_batch * cache_len * per_entry
