"""Block-table KV cache: a fixed-size page pool shared by every sequence,
optionally partitioned into per-DP-shard sub-pools.

The device side is a per-layer ``(num_shards * (pages_per_shard + 1),
page_size, KV, hd)`` k/v pool (``models.model.paged_stack_decl``): each DP
shard owns a contiguous stride of ``pages_per_shard`` usable pages plus its
own trash page (the slot padded positions scatter into), so the page axis
shards evenly over the mesh 'data' axis and every row's page gather stays
within its shard's stride. With ``num_shards=1`` this reduces to the
original single-host layout: ``num_pages + 1`` device pages, trash last.

The host side is :class:`PagePool` — per-shard free-list allocators
tracking which physical pages each request owns (a request's pages all
come from ONE shard: its KV must be co-resident with its batch row) — plus
per-slot block tables mapping logical page index -> physical page.

Logical KV slot ``j`` of a request maps to
``pool[table[j // page_size], j % page_size]``: the identity position
mapping. Unlike the ring buffer, pages never wrap; a sliding-window config
instead *releases* pages that fall entirely below the window (the window
mask already excludes them, so the tokens are dead).

Memory accounting (``kv_bytes_resident``) counts only pages actually
allocated to live requests — the number the serving bench compares against
the ring cache's ``max_batch * max_seq`` dense footprint.
``kv_bytes_resident_per_shard`` splits it per device; the multi-device
scaling bench checks the per-shard numbers sum to the aggregate and that
aggregate residency grows with DP shard count.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.model import paged_stack_decl
from repro.sharding.rules import FoldingPlan, ParamDecl


class PagePool:
    """Host-side allocator over ``num_pages`` usable pages, split into
    ``num_shards`` equal sub-pools (``num_shards=1`` = single host).

    Physical page ids are device indices: shard ``s`` owns the stride
    ``[s * (pps + 1), s * (pps + 1) + pps)`` where ``pps = num_pages //
    num_shards``; device index ``s * (pps + 1) + pps`` is shard ``s``'s
    trash page and is never allocated. A request is pinned to the shard of
    its first allocation; later allocations come from the same sub-pool.

    **Pinned-shard lifetime rule**: the pin is set by the first successful
    page acquisition (:meth:`alloc` or :meth:`attach`) and survives until
    :meth:`free_request` — including through states where the request
    transiently owns zero pages (a sliding-window request whose pages all
    fell below the window must realloc from the *same* shard, because its
    batch row and device KV stride live there). A zero-page ``alloc`` is a
    pure no-op: it neither pins a shard nor creates bookkeeping entries.

    **Refcounted shared pages** (prefix cache): with
    :meth:`enable_prefix_cache`, immutable full prefix pages can be
    *shared* across requests. Every page is then in exactly one of three
    states — free, *private* (owned by exactly one request), or *shared*
    (in the prefix cache, referenced by ``refcount >= 0`` live requests).
    :meth:`promote` moves a private page into the shared state;
    :meth:`attach` adds a reference; :meth:`free_request` / :meth:`detach`
    *decrement* instead of freeing. A shared page is never freed while its
    refcount is positive; at refcount 0 it stays cache-resident (a future
    request can still hit it) but becomes *evictable* — :meth:`alloc`
    transparently reclaims evictable pages, leaf-first along the radix
    tree, when a sub-pool's free list runs dry, so caching never causes
    preemption that a cache-less pool would not have had. :meth:`cow`
    clones a shared page into a fresh private one (copy-on-write at the
    divergence point; the caller copies the device contents).

    Invariants (asserted by :meth:`check_invariants` and exercised by the
    property suite): free / private / shared states partition the pages;
    ``free_pages + used_pages == num_pages`` at all times; refcounts equal
    the number of live references and are monotone non-increasing down any
    radix-tree path; every page held by a request lives in that request's
    pinned shard and the pin never changes while the request is live;
    per-shard used/free counts sum to the aggregate; a drained pool holds
    only zero-refcount cache pages (none, if the cache is disabled)."""

    def __init__(self, num_pages: int, page_size: int, num_shards: int = 1):
        assert num_pages > 0 and page_size > 0 and num_shards > 0
        assert num_pages % num_shards == 0, (num_pages, num_shards)
        self.num_pages, self.page_size = num_pages, page_size
        self.num_shards = num_shards
        self.pages_per_shard = num_pages // num_shards
        self._stride = self.pages_per_shard + 1  # usable pages + trash
        # per-shard stacks with low ids on top: allocation order is
        # deterministic and, at num_shards=1, identical to the original
        # single-list pool (0, 1, 2, ...)
        self._free: List[List[int]] = [
            list(range(s * self._stride + self.pages_per_shard - 1,
                       s * self._stride - 1, -1))
            for s in range(num_shards)
        ]
        self._owned: Dict[int, List[int]] = {}
        self._shard_of: Dict[int, int] = {}  # rid -> pinned shard
        # prefix-cache state: shared-page refcounts, per-request references,
        # and the insertion-ordered evictable (refcount-0) set
        self._shared: Dict[int, int] = {}  # phys -> live refcount
        self._refs: Dict[int, List[int]] = {}  # rid -> shared pages referenced
        self._evictable: Dict[int, None] = {}  # refcount-0 shared, FIFO order
        self.prefix: Optional["PrefixCache"] = None
        self.cow_clones = 0

    def enable_prefix_cache(self) -> "PrefixCache":
        """Attach a radix prefix index (see :class:`PrefixCache`)."""
        if self.prefix is None:
            self.prefix = PrefixCache(self)
        return self.prefix

    # -- queries ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - self.free_pages

    @property
    def device_pages(self) -> int:
        """Device pool size along the page axis (usable + trash pages)."""
        return self.num_shards * self._stride

    def free_pages_in(self, shard: int) -> int:
        return len(self._free[shard])

    def used_pages_in(self, shard: int) -> int:
        return self.pages_per_shard - len(self._free[shard])

    def trash_page(self, shard: int) -> int:
        """Device index of ``shard``'s trash page (writes for padded /
        idle positions of that shard's rows land here)."""
        return shard * self._stride + self.pages_per_shard

    def shard_of_page(self, page: int) -> int:
        return page // self._stride

    def shard_of(self, rid: int) -> Optional[int]:
        """Shard ``rid`` is pinned to (None before its first alloc)."""
        return self._shard_of.get(rid)

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` KV entries."""
        return math.ceil(tokens / self.page_size)

    def owned(self, rid: int) -> List[int]:
        return list(self._owned.get(rid, ()))

    def refs(self, rid: int) -> List[int]:
        """Shared pages ``rid`` holds references to (block-table order)."""
        return list(self._refs.get(rid, ()))

    def held(self, rid: int) -> int:
        """Total pages backing ``rid``: private + shared-referenced. This is
        the number admission/preemption accounting must use — a prefix-hit
        request occupies block-table slots it never alloc'd."""
        return len(self._owned.get(rid, ())) + len(self._refs.get(rid, ()))

    def refcount(self, phys: int) -> int:
        """Live references to shared page ``phys`` (0 = cache-resident but
        evictable; raises KeyError if the page is not shared)."""
        return self._shared[phys]

    @property
    def shared_pages(self) -> int:
        return len(self._shared)

    def evictable_in(self, shard: int) -> int:
        """Refcount-0 cache pages reclaimable from ``shard`` on demand."""
        return sum(1 for p in self._evictable if self.shard_of_page(p) == shard)

    @property
    def evictable_pages(self) -> int:
        return len(self._evictable)

    def available_in(self, shard: int) -> int:
        """Pages ``alloc`` could produce for ``shard`` right now: the free
        list plus evictable cache pages. Admission budgets must use this,
        not ``free_pages_in`` — otherwise retained cache pages would stall
        admission that a cache-less pool would have granted."""
        return len(self._free[shard]) + self.evictable_in(shard)

    def utilization(self) -> float:
        return self.used_pages / self.num_pages

    # -- mutation -----------------------------------------------------------
    def alloc(self, rid: int, n: int = 1, shard: int = 0) -> Optional[List[int]]:
        """Allocate ``n`` private pages for ``rid`` from ``shard``'s
        sub-pool; None (no partial effect) if that sub-pool cannot satisfy
        the request even after reclaiming refcount-0 cache pages. A rid
        already holding pages must allocate from its pinned shard. ``n=0``
        returns ``[]`` with NO side effects (no pin, no bookkeeping)."""
        assert n >= 0, f"negative page count {n} for rid {rid}"
        pinned = self._shard_of.get(rid)
        if pinned is not None:
            assert shard == pinned, (rid, shard, pinned)
        if n == 0:
            return []
        free = self._free[shard]
        if n > len(free) + self.evictable_in(shard):
            return None
        from repro.resilience import faults

        if any(s.kind == "alloc_fail" for s in faults.fire("serving.alloc")):
            # transient exhaustion: same contract as a genuinely dry pool
            # (None, no partial effect), so the scheduler's preemption /
            # stall machinery handles it — the chaos suite proves no
            # deadlock and eventual completion
            return None
        if n > len(free):
            self._reclaim(shard, n - len(free))
        pages = [free.pop() for _ in range(n)]
        self._owned.setdefault(rid, []).extend(pages)
        self._shard_of[rid] = shard
        return pages

    def _reclaim(self, shard: int, n: int) -> None:
        """Evict ``n`` refcount-0 cache pages from ``shard`` back to its
        free list, leaf-first along the radix tree (refcount monotonicity
        guarantees an evictable node's children are evictable too, so a
        leaf always exists among the evictable set)."""
        for _ in range(n):
            page = next(
                (p for p in self._evictable
                 if self.shard_of_page(p) == shard
                 and (self.prefix is None or self.prefix.is_leaf(p))),
                None,
            )
            assert page is not None, "reclaim short: evictable set has no leaf"
            del self._evictable[page]
            del self._shared[page]
            if self.prefix is not None:
                self.prefix.drop_page(page)
            self._free[shard].append(page)

    def attach(self, rid: int, pages: List[int], shard: int) -> None:
        """Add ``rid`` references to shared ``pages`` (a prefix-cache hit),
        pinning ``rid`` to ``shard``."""
        pinned = self._shard_of.get(rid)
        if pinned is not None:
            assert shard == pinned, (rid, shard, pinned)
        refs = self._refs.setdefault(rid, [])
        for p in pages:
            assert p in self._shared and self.shard_of_page(p) == shard, (
                rid, p, shard)
            if self._shared[p] == 0:
                del self._evictable[p]
            self._shared[p] += 1
            refs.append(p)
        if pages:
            self._shard_of[rid] = shard

    def promote(self, rid: int, phys: int) -> None:
        """Move ``rid``'s private page ``phys`` into the shared state with
        ``rid`` holding the first reference (its block table keeps using
        the same physical page)."""
        self._owned[rid].remove(phys)  # raises if not private to rid
        if not self._owned[rid]:
            del self._owned[rid]  # pin stays: rid still holds a reference
        self._shared[phys] = 1
        self._refs.setdefault(rid, []).append(phys)

    def detach(self, rid: int, pages: List[int]) -> None:
        """Drop ``rid``'s references to shared ``pages`` (refcount--; at 0
        the page becomes evictable but stays cache-resident)."""
        refs = self._refs.get(rid, [])
        for p in pages:
            refs.remove(p)  # raises if not referenced — double-detach is a bug
            self._shared[p] -= 1
            assert self._shared[p] >= 0, f"negative refcount on page {p}"
            if self._shared[p] == 0:
                self._evictable[p] = None
        if not refs:
            self._refs.pop(rid, None)

    def cow(self, rid: int, phys: int) -> Optional[int]:
        """Copy-on-write: swap ``rid``'s reference to shared page ``phys``
        for a fresh private page in the same shard (None if the shard is
        dry). The caller must copy the device contents old -> new
        (:func:`copy_pages`) and rewrite its block-table entry; the shared
        page itself is never written again."""
        shard = self.shard_of_page(phys)
        # alloc first: rid's live reference keeps `phys` un-evictable while
        # the reclaim inside alloc hunts for pages
        new = self.alloc(rid, 1, shard=shard)
        if new is None:
            return None
        self.detach(rid, [phys])
        self.cow_clones += 1
        return new[0]

    def release(self, rid: int, pages: List[int]) -> None:
        """Return specific private pages owned by ``rid`` (dead
        sliding-window pages) to their shard's free list. The rid's
        bookkeeping entry and shard pin survive even at zero owned pages —
        a live request's next alloc must come from the same shard (its
        batch row and device KV stride live there); only
        :meth:`free_request` unpins."""
        owned = self._owned.get(rid, [])
        for p in pages:
            owned.remove(p)  # raises if not owned — double-free is a bug
            self._free[self.shard_of_page(p)].append(p)

    def free_request(self, rid: int) -> int:
        """End of ``rid``'s lifetime: free its private pages, detach its
        shared references (refcount--, pages stay cache-resident), drop
        the shard pin. Returns how many private pages were freed."""
        pages = self._owned.pop(rid, [])
        for p in pages:
            self._free[self.shard_of_page(p)].append(p)
        self.detach(rid, self.refs(rid))
        self._shard_of.pop(rid, None)
        return len(pages)

    def defrag(self) -> Optional[Dict[int, int]]:
        """Compact allocated pages — private AND shared/cache-resident —
        into the low-index prefix of each shard's stride (pages never
        migrate across shards — their KV lives on that shard's device).
        Returns the {old_physical: new_physical} mapping (None if already
        compact); the caller must apply it to the device pool
        (:func:`permute_pool`) and every block table in the same step."""
        remap: Dict[int, int] = {}
        alloc_per_shard: List[int] = []
        for s in range(self.num_shards):
            base = s * self._stride
            allocated = sorted(
                {p for pages in self._owned.values() for p in pages
                 if self.shard_of_page(p) == s}
                | {p for p in self._shared if self.shard_of_page(p) == s}
            )
            alloc_per_shard.append(len(allocated))
            for new, old in enumerate(allocated):
                remap[old] = base + new
        mapping = {old: new for old, new in remap.items() if old != new}
        if not mapping:
            return None
        for pages in self._owned.values():
            pages[:] = [remap.get(p, p) for p in pages]
        for refs in self._refs.values():
            refs[:] = [remap.get(p, p) for p in refs]
        self._shared = {remap.get(p, p): r for p, r in self._shared.items()}
        self._evictable = {remap.get(p, p): None for p in self._evictable}
        if self.prefix is not None:
            self.prefix.remap(remap)
        for s, n in enumerate(alloc_per_shard):
            base = s * self._stride
            self._free[s] = list(range(
                base + self.pages_per_shard - 1, base + n - 1, -1
            ))
        return mapping

    def drop_prefix_cache(self) -> int:
        """Evict every refcount-0 cache page (e.g. before a drain check or
        a workload switch); returns how many pages went back to the free
        lists. Pages still referenced by live requests stay shared."""
        dropped = 0
        while self._evictable:
            for s in range(self.num_shards):
                n = self.evictable_in(s)
                if n:
                    self._reclaim(s, n)
                    dropped += n
        return dropped

    # -- invariants ---------------------------------------------------------
    def check_invariants(self) -> None:
        owned = [p for pages in self._owned.values() for p in pages]
        shared = list(self._shared)
        flat_free = [p for f in self._free for p in f]
        circulating = owned + shared + flat_free
        assert len(owned) == len(set(owned)), "page double-assigned"
        assert not set(owned) & set(flat_free), "page both owned and free"
        assert not set(shared) & set(flat_free), "shared page on free list"
        assert not set(shared) & set(owned), "page both shared and private"
        assert len(circulating) == self.num_pages, "page leaked"
        trash = {self.trash_page(s) for s in range(self.num_shards)}
        assert not trash & set(circulating), "trash page in circulation"
        assert all(0 <= p < self.device_pages for p in circulating)
        for rid, pages in self._owned.items():
            s = self._shard_of[rid]
            assert all(self.shard_of_page(p) == s for p in pages), (
                f"request {rid} holds pages outside its shard {s}"
            )
        for s, f in enumerate(self._free):
            assert all(self.shard_of_page(p) == s for p in f)
        assert sum(self.used_pages_in(s) for s in range(self.num_shards)) \
            == self.used_pages, "per-shard used counts do not sum to aggregate"
        # refcount consistency: _shared counts == live references, the
        # evictable set is exactly the refcount-0 pages, every reference
        # lives in the referencing rid's pinned shard
        counts: Dict[int, int] = {}
        for rid, refs in self._refs.items():
            assert refs, f"empty refs entry for rid {rid}"
            s = self._shard_of[rid]
            for p in refs:
                assert p in self._shared, f"reference to non-shared page {p}"
                assert self.shard_of_page(p) == s, (
                    f"request {rid} references page {p} outside its shard {s}"
                )
                counts[p] = counts.get(p, 0) + 1
        for p, r in self._shared.items():
            assert r == counts.get(p, 0), (
                f"page {p}: refcount {r} != {counts.get(p, 0)} live refs"
            )
        assert set(self._evictable) == {p for p, r in self._shared.items()
                                        if r == 0}, "evictable set drifted"
        # pin lifetime: exactly the rids holding pages or references are
        # pinned (a live zero-page rid keeps its _owned entry, so stays
        # pinned); nobody else
        assert set(self._owned) | set(self._refs) <= set(self._shard_of), (
            "request holding pages without a shard pin"
        )
        if self.prefix is not None:
            self.prefix.check(self)
        else:
            assert not self._shared and not self._refs and not self._evictable


class _TrieNode:
    """One cached page: reached from its parent by a full ``page_size``
    token run."""
    __slots__ = ("page", "key", "parent", "children")

    def __init__(self, page, key, parent):
        self.page = page  # physical page id holding this run's KV
        self.key = key  # tuple of page_size token ids
        self.parent = parent  # _TrieNode or None (root child)
        self.children: Dict[tuple, "_TrieNode"] = {}


class PrefixCache:
    """Radix index over a :class:`PagePool`: per-shard tries whose edges
    are full ``page_size`` token runs, mapping prompt prefixes to shared
    physical pages. Sharing is full-page granular and position-aligned —
    prefixes start at position 0 and RoPE is baked into cached KV, so a
    token-run match implies bit-identical KV.

    ``match`` walks the trie; ``acquire`` additionally refcounts the hit
    pages onto a request (``PagePool.attach``); ``insert`` promotes a
    request's freshly-prefilled private full-prompt pages into the trie
    (first writer wins — a duplicate page stays private to its request).
    Eviction (``PagePool._reclaim``) is leaf-first; ``drop_page`` unlinks
    an evicted leaf, ``remap`` follows a defrag compaction."""

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.page_size = pool.page_size
        self._roots: List[Dict[tuple, _TrieNode]] = [
            {} for _ in range(pool.num_shards)
        ]
        self._node_of: Dict[int, _TrieNode] = {}  # phys -> node
        self.lookups = 0
        self.hits = 0
        self.hit_pages = 0
        self.inserted_pages = 0
        self.evicted_pages = 0

    def _runs(self, tokens) -> List[tuple]:
        ps = self.page_size
        n = len(tokens) // ps
        return [tuple(int(t) for t in tokens[j * ps:(j + 1) * ps])
                for j in range(n)]

    def match(self, tokens, shard: int) -> List[int]:
        """Longest-prefix hit: physical pages covering the leading full
        pages of ``tokens`` already cached on ``shard``."""
        pages: List[int] = []
        children = self._roots[shard]
        for run in self._runs(tokens):
            node = children.get(run)
            if node is None:
                break
            pages.append(node.page)
            children = node.children
        return pages

    def acquire(self, rid: int, tokens, shard: int) -> List[int]:
        """``match`` + refcount the hit pages onto ``rid``."""
        self.lookups += 1
        pages = self.match(tokens, shard)
        if pages:
            self.pool.attach(rid, pages, shard)
            self.hits += 1
            self.hit_pages += len(pages)
        return pages

    def insert(self, rid: int, tokens, upto_page: int, table_row) -> int:
        """Promote ``rid``'s private pages covering full token runs
        ``[0, upto_page)`` (physical ids from ``table_row``) into the trie;
        returns how many pages were newly promoted. Pages whose run is
        already cached are skipped (the duplicate stays private — it will
        be freed normally); descent continues through them, so a request
        extending a cached prefix grafts its tail under the existing
        nodes."""
        runs = self._runs(tokens)
        shard = self.pool.shard_of(rid)
        assert shard is not None
        children = self._roots[shard]
        promoted = 0
        parent = None
        for j in range(min(upto_page, len(runs))):
            run = runs[j]
            node = children.get(run)
            if node is None:
                phys = int(table_row[j])
                if phys in self.pool._shared:
                    # rid's page j is someone else's cached page it attached
                    # to under a different path? impossible — its table
                    # entries are either its own private pages or pages it
                    # acquired along exactly this path (node would exist)
                    raise AssertionError(
                        f"table page {phys} shared but absent from trie path")
                node = _TrieNode(phys, run, parent)
                children[run] = node
                self._node_of[phys] = node
                self.pool.promote(rid, phys)
                promoted += 1
            children = node.children
            parent = node
        self.inserted_pages += promoted
        return promoted

    def is_leaf(self, page: int) -> bool:
        return not self._node_of[page].children

    def drop_page(self, page: int) -> None:
        """Unlink an evicted page's node (must be a leaf)."""
        node = self._node_of.pop(page)
        assert not node.children, "evicting a non-leaf cache page"
        siblings = (node.parent.children if node.parent is not None
                    else self._roots[self.pool.shard_of_page(page)])
        del siblings[node.key]
        self.evicted_pages += 1

    def remap(self, mapping: Dict[int, int]) -> None:
        """Follow a defrag compaction: rewrite node physical ids."""
        for node in self._node_of.values():
            node.page = mapping.get(node.page, node.page)
        self._node_of = {node.page: node for node in self._node_of.values()}

    def pages(self) -> set:
        return set(self._node_of)

    def stats(self) -> Dict[str, int]:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_pages": self.hit_pages,
            "inserted_pages": self.inserted_pages,
            "evicted_pages": self.evicted_pages,
            "resident_pages": len(self._node_of),
        }

    def check(self, pool: PagePool) -> None:
        """Trie-side invariants: trie pages == shared pages, and refcounts
        are monotone non-increasing down every path (acquire takes whole
        prefixes, so a parent is referenced at least as often as any
        child) — this is what makes leaf-first eviction complete."""
        assert self.pages() == set(pool._shared), (
            "trie pages drifted from the pool's shared set"
        )
        for node in self._node_of.values():
            if node.parent is not None:
                assert pool._shared[node.parent.page] >= pool._shared[node.page], (
                    f"refcount not monotone: parent page {node.parent.page} "
                    f"< child page {node.page}"
                )
                assert node.parent.children.get(node.key) is node
            for key, child in node.children.items():
                assert child.parent is node and child.key == key


def init_paged_pool(
    cfg: ModelConfig,
    num_pages: int,
    page_size: int,
    num_shards: int = 1,
    plan: Optional[FoldingPlan] = None,
):
    """Zero-initialized device page pool: ``num_pages`` usable pages split
    into ``num_shards`` strides, each with its own trailing trash page (the
    ``paged_stack_decl`` convention generalized; ``num_shards=1`` is the
    original layout). With a ``plan``, the page axis is sharded over the
    mesh batch axes so each DP shard's stride is device-resident locally —
    aggregate HBM then bounds the pool, not one device's worth."""
    assert num_pages % num_shards == 0, (num_pages, num_shards)
    stride = num_pages // num_shards + 1
    decls = paged_stack_decl(cfg, num_shards * stride, page_size)
    pool = jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype), decls,
        is_leaf=lambda d: isinstance(d, ParamDecl),
    )
    if plan is not None:
        sh = pool_sharding(plan)
        pool = jax.tree.map(lambda a: jax.device_put(a, sh), pool)
    return pool


def pool_sharding(plan: FoldingPlan):
    """NamedSharding for pool leaves ``(P, pages, ps, KV, hd)``: the page
    axis shards over the mesh batch axes (one stride per DP shard)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = plan.batch_axes
    part = axes if len(axes) > 1 else (axes[0] if axes else None)
    return NamedSharding(plan.mesh, P(None, part, None, None, None))


def permute_pool(pool, mapping: Dict[int, int]):
    """Apply a defrag mapping to the device pool: page ``old`` moves to
    index ``new``. Leaves are (P, num_pages, ps, KV, hd); the trash page is
    never remapped."""
    n = jax.tree.leaves(pool)[0].shape[1]
    src = np.arange(n)
    for old, new in mapping.items():
        src[new] = old
    idx = jnp.asarray(src)
    return jax.tree.map(lambda a: jnp.take(a, idx, axis=1), pool)


def copy_pages(pool, copies: List[tuple]):
    """Apply COW clones to the device pool: for each ``(src, dst)`` pair,
    page ``dst`` becomes a copy of page ``src`` across every k/v leaf (the
    shared source page is never written again)."""
    if not copies:
        return pool
    src = jnp.asarray([s for s, _ in copies], jnp.int32)
    dst = jnp.asarray([d for _, d in copies], jnp.int32)
    return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), pool)


def kv_page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """Bytes one allocated page pins across the whole stack (k + v, every
    layer). Quant-aware: ``cfg.quant_kv == "int8"`` pages are int8 payload
    plus the per-token f32 scale sidecar (roofline/analysis.py owns the
    config-driven byte widths)."""
    from repro.models.transformer import build_slots, periods_for
    from repro.roofline.analysis import kv_entry_bytes

    slots = build_slots(cfg)
    periods = periods_for(cfg, slots)
    per_entry = cfg.num_kv_heads * kv_entry_bytes(cfg)
    return int(2 * periods * len(slots) * page_size * per_entry)


def kv_bytes_resident(cfg: ModelConfig, pool: PagePool) -> int:
    """KV bytes pinned by live requests (the paged-mode resident set),
    aggregated over every shard."""
    return pool.used_pages * kv_page_bytes(cfg, pool.page_size)


def kv_bytes_live(cfg: ModelConfig, pool: PagePool) -> int:
    """KV bytes *referenced by live requests*: private pages plus shared
    pages counted once, excluding refcount-0 cache-resident pages (those
    are reclaimable on demand, like OS page cache). This is the
    apples-to-apples number against a cache-less pool, where every live
    request duplicates its prefix."""
    live = pool.used_pages - pool.evictable_pages
    return live * kv_page_bytes(cfg, pool.page_size)


def kv_bytes_resident_per_shard(cfg: ModelConfig, pool: PagePool) -> List[int]:
    """Per-DP-shard resident KV bytes; sums to :func:`kv_bytes_resident`
    (checked by the shard-accounting property suite)."""
    pb = kv_page_bytes(cfg, pool.page_size)
    return [pool.used_pages_in(s) * pb for s in range(pool.num_shards)]


def ring_kv_bytes(cfg: ModelConfig, max_batch: int, cache_len: int) -> int:
    """Resident KV bytes of the dense ring cache at the same batch — it
    allocates ``max_batch * cache_len`` entries regardless of occupancy."""
    from repro.models.transformer import build_slots, periods_for

    slots = build_slots(cfg)
    periods = periods_for(cfg, slots)
    per_entry = cfg.num_kv_heads * cfg.head_dim_ * jnp.dtype(cfg.dtype).itemsize
    return 2 * periods * len(slots) * max_batch * cache_len * per_entry
