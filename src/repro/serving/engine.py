"""Batched serving engine: continuous-batching request handling with two
interchangeable KV cache backends.

* ``cache_mode="ring"`` — the original dense ring-buffer cache: one
  ``max_batch x max_seq`` KV slab regardless of prompt length, fused
  single-request prefill spliced into the batch cache. Kept as the parity
  oracle for the paged path; prefill is compiled once per padded
  prompt-length bucket (see ``prefill_traces``).
* ``cache_mode="paged"`` — the block-table subsystem: a shared page pool
  (``serving/kv_cache.py``), a chunked-prefill continuous-batching
  scheduler with free-page admission and preemption-by-recompute
  (``serving/scheduler.py``), and decode through the page-table cache view
  (``models.model.decode_step_paged`` — Pallas paged-attention kernel when
  ``use_kernel=True``). Exactly three compiled steps serve every request
  mix: one prefill chunk (static chunk length, right-padded), one batched
  decode, regardless of prompt lengths.

Greedy decode over both backends is token-for-token identical — pinned by
``tests/test_serving_paged.py``.

**Mesh-aware (EP x DP) mode** — pass ``mesh=`` (paged mode only): the
engine resolves a :class:`FoldingPlan`, shards the expert FFN weights over
the plan's ``ep`` axis and the page pool / decode batch over the mesh batch
('data') axes, and routes MoE decode through the overlapped expert
all-to-all (``dispatcher="a2a_overlap"`` unless overridden) with
``strict_dispatch`` set, so an illegal EP dispatch is a loud config error
instead of a silent allgather fallback. Batch and chunk geometry are
rounded up to the token-shard product the EP dispatchers shard over; the
scheduler partitions batch slots and the page pool per DP shard
(``SchedulerConfig.dp_shards``), with per-device resident-bytes accounting
surfaced via :meth:`ServingEngine.kv_stats`. With a 1x1 mesh everything
reduces to the single-host behavior bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.config import ModelConfig, with_dispatcher
from repro.resilience import faults
from repro.resilience.recovery import HangError, ShedError
from repro.models.model import (
    cache_decl,
    decode_step,
    decode_step_paged,
    model_decl,
    paged_forward,
    prefill_forward,
)
from repro.serving.kv_cache import (
    PagePool,
    copy_pages,
    init_paged_pool,
    kv_bytes_live,
    kv_bytes_resident,
    kv_bytes_resident_per_shard,
    permute_pool,
    ring_kv_bytes,
)
from repro.serving.scheduler import ChunkedScheduler, SchedulerConfig
from repro.sharding.rules import FoldingPlan, ParamDecl, shardings_from_decls


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    deadline_steps: Optional[int] = None  # per-request deadline override
    status: str = "ok"  # "ok" | "deadline" (evicted past its deadline)


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        plan: Optional[FoldingPlan] = None,
        max_batch: int = 4,
        max_seq: int = 256,
        greedy: bool = True,
        dispatcher: Optional[str] = None,
        use_kernel: bool = False,
        cache_mode: str = "ring",
        page_size: int = 16,
        num_pages: Optional[int] = None,
        prefill_chunk: int = 32,
        watermark: int = 0,
        mesh: Optional[Mesh] = None,
        deadline_steps: Optional[int] = None,
        max_queue: Optional[int] = None,
        shed_watermark: Optional[int] = None,
        step_timeout_s: Optional[float] = None,
        prefix_cache: bool = False,
        quant_weights: str = "none",
        quant_kv: str = "none",
        fused_dispatch: bool = False,
    ):
        # MoE decode runs through the same dispatch subsystem as training;
        # `dispatcher` overrides the config's token dispatcher (e.g. "sorted"
        # for dropless decode), `use_kernel` enables the Pallas expert GEMMs
        # and (paged mode) the paged-attention decode kernel. `mesh` turns
        # on the EP x DP sharded mode (see module docstring).
        assert cache_mode in ("ring", "paged"), cache_mode
        if prefix_cache and cache_mode != "paged":
            raise ValueError("prefix_cache requires cache_mode='paged'")
        if prefix_cache and cfg.sliding_window is not None:
            raise ValueError(
                "prefix_cache is incompatible with sliding_window: shared "
                "prefix pages must be immutable, a window releases them"
            )
        self.prefix_caching = prefix_cache
        if cache_mode == "ring" and (deadline_steps is not None
                                     or shed_watermark is not None):
            raise ValueError(
                "deadline_steps/shed_watermark need the paged scheduler "
                "(the ring cache has no step clock or page accounting); "
                "max_queue load-shedding works in both modes"
            )
        self.deadline_steps = deadline_steps
        self.max_queue = max_queue
        self.shed_watermark = shed_watermark
        self.step_timeout_s = step_timeout_s
        self.shed_count = 0  # ring-mode max_queue sheds (paged: scheduler's)
        cfg = with_dispatcher(cfg, dispatcher)
        if fused_dispatch and cfg.moe is not None:
            # dispatch-in-kernel decode: sorted-only (MoEConfig asserts) and
            # meaningful only with use_kernel (the fusion lives in Pallas)
            if not use_kernel:
                raise ValueError("fused_dispatch requires use_kernel=True")
            cfg = cfg.replace(
                moe=dataclasses.replace(cfg.moe, fused_dispatch=True)
            )
        # -- low-precision serving (core/quant.py) --------------------------
        # quant_weights: expert FFN weights become int8 + per-channel scales
        # (quantized once here; the fused-dequant kernels / XLA dequant
        # fallback pick them up by key). quant_kv: the page pool stores int8
        # KV with per-token scale sidecar leaves — paged mode only, the ring
        # cache has no sidecar. Engine kwargs extend (never clear) any quant
        # modes already set on the config.
        for qv in (quant_weights, quant_kv):
            if qv not in ("none", "int8"):
                raise ValueError(f"quant mode must be 'none' or 'int8', got {qv!r}")
        if quant_weights != "none" or quant_kv != "none":
            cfg = cfg.replace(
                quant_weights=quant_weights if quant_weights != "none"
                else cfg.quant_weights,
                quant_kv=quant_kv if quant_kv != "none" else cfg.quant_kv,
            )
        if cfg.quant_kv == "int8" and cache_mode != "paged":
            raise ValueError(
                "quant_kv requires cache_mode='paged' (the scale sidecar "
                "lives in the page pool)"
            )
        if cfg.quant_weights == "int8":
            from repro.core.quant import quantize_params

            params = quantize_params(params)  # idempotent
        self.mesh = mesh
        self.dp_shards, self.ep_size = 1, 1
        if mesh is not None:
            assert cache_mode == "paged", (
                "mesh-aware serving requires cache_mode='paged' (the ring "
                "cache has no per-shard pool partition)"
            )
            if plan is None:
                plan = FoldingPlan.make(cfg, mesh)
            dp = max(1, int(np.prod([mesh.shape[a] for a in plan.batch_axes])))
            self.dp_shards = dp
            if cfg.moe is not None and plan.moe_mode == "ep":
                self.ep_size = plan.ep_size
                # decode must go through the EP exchange: default the
                # padded-CF dispatchers to the overlapped schedule (same
                # numerics, hidden exchange) and make any fallback a loud
                # error. An explicit `dispatcher=` or a dropless 'sorted'
                # config is left alone.
                if dispatcher is None and cfg.moe.dispatcher in (
                    "allgather", "alltoall"
                ):
                    cfg = with_dispatcher(cfg, "a2a_overlap")
                if cfg.moe.dispatcher in ("alltoall", "a2a_overlap"):
                    cfg = cfg.replace(moe=dataclasses.replace(
                        cfg.moe, strict_dispatch=True
                    ))
            # decode token count = max_batch, prefill token count =
            # prefill_chunk: both must divide over the token-shard product
            tsp = dp * (
                self.ep_size
                if cfg.moe is not None
                and cfg.moe.dispatcher in ("alltoall", "a2a_overlap")
                else 1
            )
            max_batch = _round_up(max_batch, tsp)
            prefill_chunk = _round_up(prefill_chunk, tsp)
            # weights go to their folded placement (expert FFN over ep_axis)
            params = jax.device_put(
                params, shardings_from_decls(model_decl(cfg), plan)
            )
        self.cfg, self.params, self.plan = cfg, params, plan
        self.max_batch, self.max_seq = max_batch, max_seq
        self.greedy = greedy
        self.use_kernel = use_kernel
        self.cache_mode = cache_mode
        W = max_seq if cfg.sliding_window is None else min(max_seq, cfg.sliding_window)
        self.cache_len = W
        if cache_mode == "paged":
            self._init_paged(page_size, num_pages, prefill_chunk, watermark)
            return
        decls = cache_decl(cfg, max_batch, max_seq)
        self.cache = jax.tree.map(
            lambda d: jnp.zeros(d.shape, d.dtype), decls,
            is_leaf=lambda d: isinstance(d, ParamDecl),
        )
        self.cache["slot_pos"] = jnp.full_like(self.cache["slot_pos"], -1)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, t: decode_step(cfg, plan, p, c, t, use_kernel=self.use_kernel)
        )
        self._next_tok = jnp.zeros((max_batch,), jnp.int32)
        # prefill compiles once per padded prompt-length bucket, not per
        # request; `prefill_traces` counts actual traces (regression-tested)
        self._prefill_fns: Dict[int, object] = {}
        self.prefill_traces = 0

    # -- paged backend setup ------------------------------------------------
    def _init_paged(self, page_size, num_pages, prefill_chunk, watermark):
        cfg = self.cfg
        dp = self.dp_shards
        maxP = math.ceil(self.max_seq / page_size)
        if num_pages is None:
            # capacity parity with the ring cache; the memory win is that
            # only *allocated* pages count as resident
            num_pages = self.max_batch * maxP
        num_pages = _round_up(num_pages, dp)  # equal per-shard sub-pools
        self.page_size, self.num_pages = page_size, num_pages
        self.prefill_chunk = prefill_chunk
        self.pool_dev = init_paged_pool(
            cfg, num_pages, page_size, num_shards=dp,
            plan=self.plan if self.mesh is not None else None,
        )
        self.page_pool = PagePool(num_pages, page_size, num_shards=dp)
        if self.prefix_caching:
            self.page_pool.enable_prefix_cache()
        self.sched = ChunkedScheduler(
            SchedulerConfig(
                max_batch=self.max_batch, page_size=page_size,
                prefill_chunk=prefill_chunk, max_pages_per_seq=maxP,
                watermark=watermark, window=cfg.sliding_window,
                dp_shards=dp, deadline_steps=self.deadline_steps,
                max_queue=self.max_queue,
                shed_watermark=self.shed_watermark,
            ),
            self.page_pool,
        )
        self._rid2req: Dict[int, Request] = {}
        self._next_np = np.zeros((self.max_batch,), np.int32)
        self.peak_used_pages = 0
        self.peak_live_pages = 0  # used minus reclaimable (refcount-0) cache
        # per-slot trash page: idle/padded writes of a batch row land in its
        # own DP shard's trash so they never cross the pool's shard strides
        # (at dp=1 this is the legacy last-device-page convention)
        self._trash_np = np.array(
            [self.page_pool.trash_page(self.sched.shard_of_slot(s))
             for s in range(self.max_batch)], np.int32,
        )
        # the pool operand is donated (as dryrun donates the decode cache):
        # the scatter updates in place instead of materializing a second
        # full-size pool every step
        self._chunk_fn = jax.jit(
            lambda p, pool, t, s, bt, vl, tr: paged_forward(
                cfg, self.plan, p, pool, t, s, bt, vl,
                use_kernel=self.use_kernel, trash_page=tr,
            ),
            donate_argnums=(1,),
        )
        self._decode_paged = jax.jit(
            lambda p, pool, t, pos, bt, a, tr: decode_step_paged(
                cfg, self.plan, p, pool, t, pos, bt, a,
                use_kernel=self.use_kernel, trash_page=tr,
            ),
            donate_argnums=(1,),
        )

    # -- request management -------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue ``req``; raises :class:`ShedError` (request NOT enqueued)
        when admission control rejects it — queue depth past ``max_queue``
        or (paged) page headroom below ``shed_watermark``."""
        if self.cache_mode == "paged":
            self.sched.submit(
                req.rid, len(req.prompt), req.max_new_tokens,
                deadline_steps=req.deadline_steps,
                tokens=(np.asarray(req.prompt, np.int32)
                        if self.prefix_caching else None),
            )  # may shed — then the rid is never registered
            self._rid2req[req.rid] = req
        else:
            if self.max_queue is not None and len(self.queue) >= self.max_queue:
                self.shed_count += 1
                raise ShedError(
                    f"request {req.rid} shed: queue depth {len(self.queue)} "
                    f"at max_queue={self.max_queue}; back off and resubmit"
                )
            self.queue.append(req)

    def _bucket(self, L: int) -> int:
        """Padded prefill length for a prompt of L tokens. Sliding-window
        rings prefill exactly (padding could wrap over valid entries);
        otherwise the next power of two (>=16), capped at the ring size."""
        if self.cfg.sliding_window is not None or L >= self.cache_len:
            return L
        return min(1 << max(L - 1, 15).bit_length(), self.cache_len)

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        """Run a single-request prefill and splice its cache into the batch
        cache at ``slot``. Compiled once per prompt-length bucket."""
        L = len(req.prompt)
        b = self._bucket(L)
        fn = self._prefill_fns.get(b)
        if fn is None:
            def traced(p, batch, vl):
                self.prefill_traces += 1  # fires at trace time only
                return prefill_forward(
                    self.cfg, self.plan, p, batch, cache_len=self.cache_len,
                    use_kernel=self.use_kernel, valid_len=vl,
                )

            fn = jax.jit(traced)
            self._prefill_fns[b] = fn
        toks = np.zeros((1, b), np.int32)
        toks[0, :L] = req.prompt
        batch = {"tokens": jnp.asarray(toks)}
        logits, rc = fn(self.params, batch, jnp.asarray([L], jnp.int32))

        def splice(dst, src):
            if dst.ndim >= 3 and dst.shape[1] == self.max_batch:  # stacked (P,B,...)
                return dst.at[:, slot].set(src[:, 0])
            return dst.at[slot].set(src[0])

        self.cache["stack"] = jax.tree.map(splice, self.cache["stack"], rc["stack"])
        self.cache["pos"] = self.cache["pos"].at[slot].set(rc["pos"][0])
        self.cache["slot_pos"] = self.cache["slot_pos"].at[slot].set(rc["slot_pos"][0])
        tok = int(jnp.argmax(logits[0, : self.cfg.vocab_size]))
        req.output.append(tok)
        self._next_tok = self._next_tok.at[slot].set(tok)
        self.slots[slot] = req

    def _fill_free_slots(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                self._prefill_into_slot(i, self.queue.pop(0))

    # -- main loop ----------------------------------------------------------
    def step(self) -> int:
        """One engine step. Returns the number of active requests. The
        ``serving.step`` fault site can inject a hang here; with
        ``step_timeout_s`` set a step that exceeds its wall budget raises
        :class:`HangError` (watchdog for hung collectives/device stalls)."""
        t0 = time.perf_counter()
        for spec in faults.fire("serving.step"):
            if spec.kind == "hang":
                time.sleep(
                    spec.args.get("seconds", 2.0 * (self.step_timeout_s or 0.05))
                )
        n = self._step_paged() if self.cache_mode == "paged" else self._step_ring()
        dt = time.perf_counter() - t0
        if self.step_timeout_s is not None and dt > self.step_timeout_s:
            raise HangError(
                f"serving step exceeded its {self.step_timeout_s:.3f}s wall "
                f"budget ({dt:.3f}s) — hung collective or wedged host"
            )
        return n

    def _step_ring(self) -> int:
        self._fill_free_slots()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(self.params, self.cache, self._next_tok)
        toks = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1)
        self._next_tok = toks.astype(jnp.int32)
        for i in active:
            if self._emit(self.slots[i], int(toks[i])):
                self.slots[i] = None
        return len(active)

    def _emit(self, req: Request, tok: int) -> bool:
        """Append a generated token; True if the request just finished."""
        req.output.append(tok)
        done = len(req.output) >= req.max_new_tokens or (
            req.eos_id is not None and tok == req.eos_id
        )
        req.done = req.done or done
        return done

    def _step_paged(self) -> int:
        plan = self.sched.plan()
        for rid in plan.expired:
            req = self._rid2req[rid]
            req.done = True
            req.status = "deadline"
        if plan.cow_copies:
            self._apply_cow(plan.cow_copies)
        # sample the peak right after planning (allocation) — on_token below
        # may free a finished request's pages within the same step
        self._sample_peaks()
        n_active = len(self.sched.running)
        self._run_prefills(plan)
        if plan.decode_slots:
            self._run_decode(plan)
            self._sample_peaks()  # decode may have allocated (lookahead)
        return n_active

    def _sample_peaks(self) -> None:
        self.peak_used_pages = max(self.peak_used_pages, self.page_pool.used_pages)
        self.peak_live_pages = max(
            self.peak_live_pages,
            self.page_pool.used_pages - self.page_pool.evictable_pages,
        )

    def _apply_cow(self, copies) -> None:
        """Materialize prefix-cache COW clones on the device pool(s) before
        any chunk of this step scatters into the clone."""
        self.pool_dev = copy_pages(self.pool_dev, copies)

    def _run_prefills(self, plan) -> None:
        for c in plan.prefills:
            req = self._rid2req[c.rid]
            # after preemption the generated tokens are prompt suffix
            full = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(req.output, np.int32)]
            )
            toks = np.zeros((1, self.prefill_chunk), np.int32)
            toks[0, : c.length] = full[c.start : c.start + c.length]
            bt = jnp.asarray(self.sched.block_table(c.slot)[None], jnp.int32)
            logits = self._prefill_chunk_device(
                jnp.asarray(toks), jnp.asarray([c.start], jnp.int32), bt,
                jnp.asarray([c.length], jnp.int32),
                jnp.asarray(self._trash_np[c.slot : c.slot + 1]),
            )
            if self.prefix_caching:
                # the chunk's pages now hold real KV: promote the full
                # original-prompt pages covered so far into the trie
                self.sched.note_prefilled(c.rid, c.start + c.length)
            if c.final:
                tok = int(jnp.argmax(logits[0, : self.cfg.vocab_size]))
                self._next_np[c.slot] = tok
                self.sched.on_token(c.slot, self._emit(req, tok))

    def _prefill_chunk_device(self, toks, start, bt, vlen, trash):
        """Run one prefill chunk on the device pool; SpeculativeEngine
        overrides to keep its drafter pool in lockstep."""
        logits, self.pool_dev = self._chunk_fn(
            self.params, self.pool_dev, toks, start, bt, vlen, trash
        )
        return logits

    def _run_decode(self, plan) -> None:
        """One decode token per ready slot. SpeculativeEngine overrides
        with draft-k-verify-in-one-chunk."""
        active = np.zeros((self.max_batch,), np.int32)
        pos = np.zeros((self.max_batch,), np.int32)
        for slot in plan.decode_slots:
            r = self.sched.running[slot]
            active[slot] = 1
            pos[slot] = r.decode_pos  # cache position this step writes
        bt = jnp.asarray(self.sched.tables, jnp.int32)
        logits, self.pool_dev = self._decode_paged(
            self.params, self.pool_dev, jnp.asarray(self._next_np),
            jnp.asarray(pos), bt, jnp.asarray(active),
            jnp.asarray(self._trash_np),
        )
        toks = np.asarray(
            jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1), np.int32
        )
        for slot in plan.decode_slots:
            req = self._rid2req[self.sched.running[slot].rid]
            tok = int(toks[slot])
            self._next_np[slot] = tok
            self.sched.on_token(slot, self._emit(req, tok))

    def run(self, requests: List[Request], max_steps: int = 10_000) -> Dict[int, List[int]]:
        for r in requests:
            self.submit(r)
        steps = 0
        while steps < max_steps:
            if self.cache_mode == "paged":
                if not self.sched.has_work:
                    break
            elif not (any(self.slots) or self.queue):
                break
            self.step()
            steps += 1
        return {r.rid: r.output for r in requests}

    # -- paged utilities ----------------------------------------------------
    def defrag(self) -> bool:
        """Compact the page pool (paged mode): permutes the device pool and
        rewrites every block table. Returns True if anything moved."""
        assert self.cache_mode == "paged"
        mapping = self.page_pool.defrag()
        if not mapping:
            return False
        self.sched.apply_defrag(mapping)
        self._permute_pools(mapping)
        return True

    def _permute_pools(self, mapping) -> None:
        """Apply a defrag mapping to the device pool(s); SpeculativeEngine
        overrides to move its drafter pool with the same mapping."""
        self.pool_dev = permute_pool(self.pool_dev, mapping)

    def health(self) -> Dict[str, object]:
        """Operational snapshot: residency, backlog, shed/evict counters,
        and the age of the oldest live request — what an external
        load-balancer polls to decide whether to route here."""
        if self.cache_mode == "paged":
            free = sum(
                self.page_pool.free_pages_in(sh) for sh in range(self.dp_shards)
            )
            return {
                "mode": "paged",
                "resident_requests": len(self.sched.running),
                "queued_requests": len(self.sched.queue),
                "resident_pages": self.page_pool.used_pages,
                "free_pages": free,
                "num_pages": self.num_pages,
                "shed_count": self.sched.shed_count,
                "deadline_evictions": self.sched.deadline_evictions,
                "oldest_request_age_steps": self.sched.oldest_request_age(),
                "engine_steps": self.sched.step_count,
            }
        return {
            "mode": "ring",
            "resident_requests": sum(1 for s in self.slots if s is not None),
            "queued_requests": len(self.queue),
            "shed_count": self.shed_count,
            "deadline_evictions": 0,
        }

    def kv_stats(self) -> Dict[str, float]:
        """Resident-KV accounting for the bench (both modes). In paged mode
        the aggregate numbers are joined by per-DP-shard residency and the
        scheduler's peak concurrent-resident-request count (the multi-device
        scaling bench's headline metric)."""
        if self.cache_mode == "paged":
            from repro.serving.kv_cache import kv_page_bytes

            page_bytes = kv_page_bytes(self.cfg, self.page_size)
            stats = {
                "kv_bytes_resident": kv_bytes_resident(self.cfg, self.page_pool),
                "kv_bytes_live": kv_bytes_live(self.cfg, self.page_pool),
                "kv_bytes_resident_per_shard": kv_bytes_resident_per_shard(
                    self.cfg, self.page_pool
                ),
                "kv_bytes_peak": self.peak_used_pages * page_bytes,
                "kv_bytes_live_peak": self.peak_live_pages * page_bytes,
                "page_utilization": self.page_pool.utilization(),
                "peak_used_pages": self.peak_used_pages,
                "peak_live_pages": self.peak_live_pages,
                "num_pages": self.num_pages,
                "peak_resident_requests": self.sched.peak_resident_requests,
                "dp_shards": self.dp_shards,
                "ep_size": self.ep_size,
            }
            if self.page_pool.prefix is not None:
                stats["prefix"] = dict(
                    self.page_pool.prefix.stats(),
                    hit_tokens=self.sched.prefix_hit_tokens,
                    cow_clones=self.page_pool.cow_clones,
                )
            return stats
        return {
            "kv_bytes_resident": ring_kv_bytes(
                self.cfg, self.max_batch, self.cache_len
            ),
            "kv_bytes_peak": ring_kv_bytes(self.cfg, self.max_batch, self.cache_len),
            "page_utilization": 1.0,
            "peak_used_pages": 0,
            "num_pages": 0,
        }
