"""Batched serving engine: continuous-batching-style request handling on top
of the fused prefill + single-token decode steps.

Requests arrive with a prompt; the engine packs up to ``max_batch`` active
requests into one fixed-shape decode batch (static shapes => one compiled
decode_step). Slots free as requests hit max_new_tokens or EOS and are
refilled from the queue — a minimal vLLM-style scheduler without paged KV
(the ring-buffer cache covers the sliding-window configs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, with_dispatcher
from repro.models.model import cache_decl, decode_step, prefill_forward
from repro.sharding.rules import FoldingPlan, ParamDecl


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        plan: Optional[FoldingPlan] = None,
        max_batch: int = 4,
        max_seq: int = 256,
        greedy: bool = True,
        dispatcher: Optional[str] = None,
        use_kernel: bool = False,
    ):
        # MoE decode runs through the same dispatch subsystem as training;
        # `dispatcher` overrides the config's token dispatcher (e.g. "sorted"
        # for dropless decode), `use_kernel` enables the Pallas expert GEMMs.
        cfg = with_dispatcher(cfg, dispatcher)
        self.cfg, self.params, self.plan = cfg, params, plan
        self.max_batch, self.max_seq = max_batch, max_seq
        self.greedy = greedy
        self.use_kernel = use_kernel
        W = max_seq if cfg.sliding_window is None else min(max_seq, cfg.sliding_window)
        self.cache_len = W
        decls = cache_decl(cfg, max_batch, max_seq)
        self.cache = jax.tree.map(
            lambda d: jnp.zeros(d.shape, d.dtype), decls,
            is_leaf=lambda d: isinstance(d, ParamDecl),
        )
        self.cache["slot_pos"] = jnp.full_like(self.cache["slot_pos"], -1)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, t: decode_step(cfg, plan, p, c, t, use_kernel=self.use_kernel)
        )
        self._next_tok = jnp.zeros((max_batch,), jnp.int32)

    # -- request management -------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        """Run a single-request prefill and splice its cache into the batch
        cache at ``slot``."""
        batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
        logits, rc = jax.jit(
            lambda p, b: prefill_forward(
                self.cfg, self.plan, p, b, cache_len=self.cache_len,
                use_kernel=self.use_kernel,
            )
        )(self.params, batch)

        def splice(dst, src):
            if dst.ndim >= 3 and dst.shape[1] == self.max_batch:  # stacked (P,B,...)
                return dst.at[:, slot].set(src[:, 0])
            return dst.at[slot].set(src[0])

        self.cache["stack"] = jax.tree.map(splice, self.cache["stack"], rc["stack"])
        self.cache["pos"] = self.cache["pos"].at[slot].set(rc["pos"][0])
        self.cache["slot_pos"] = self.cache["slot_pos"].at[slot].set(rc["slot_pos"][0])
        tok = int(jnp.argmax(logits[0, : self.cfg.vocab_size]))
        req.output.append(tok)
        self._next_tok = self._next_tok.at[slot].set(tok)
        self.slots[slot] = req

    def _fill_free_slots(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                self._prefill_into_slot(i, self.queue.pop(0))

    # -- main loop ----------------------------------------------------------
    def step(self) -> int:
        """One batched decode step across all active slots. Returns the
        number of active requests."""
        self._fill_free_slots()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        logits, self.cache = self._decode(self.params, self.cache, self._next_tok)
        toks = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1)
        self._next_tok = toks.astype(jnp.int32)
        for i in active:
            req = self.slots[i]
            tok = int(toks[i])
            req.output.append(tok)
            if len(req.output) >= req.max_new_tokens or (
                req.eos_id is not None and tok == req.eos_id
            ):
                req.done = True
                self.slots[i] = None
        return len(active)

    def run(self, requests: List[Request], max_steps: int = 10_000) -> Dict[int, List[int]]:
        for r in requests:
            self.submit(r)
        steps = 0
        while (any(self.slots) or self.queue) and steps < max_steps:
            self.step()
            steps += 1
        return {r.rid: r.output for r in requests}
