"""Serving subsystem: continuous-batching engine with ring-buffer and
paged-KV (block-table) cache backends — see engine.py, kv_cache.py,
scheduler.py."""
from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.kv_cache import PagePool  # noqa: F401
from repro.serving.scheduler import ChunkedScheduler, SchedulerConfig  # noqa: F401
