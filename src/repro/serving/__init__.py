"""Serving subsystem: continuous-batching engine with ring-buffer and
paged-KV (block-table) cache backends, radix prefix-cache KV reuse, and
dense-drafter speculative decoding — see engine.py, kv_cache.py,
scheduler.py, speculative.py."""
from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.kv_cache import PagePool, PrefixCache  # noqa: F401
from repro.serving.scheduler import ChunkedScheduler, SchedulerConfig  # noqa: F401
from repro.serving.speculative import SpeculativeEngine  # noqa: F401
