"""Speculative decoding with the dense upcycling parent as drafter.

The paper's recipe makes the MoE a function-preserving derivative of its
dense source (§3.1): same tokenizer, same d_model/heads/layers, and — at
Mixtral-type router init — the *same output distribution*. That hands the
serving stack a free speculative pair: the dense parent drafts ``k``
tokens autoregressively (cheap single-token decodes, no expert dispatch),
and the MoE verifies all of them in ONE chunked-prefill-shaped step
(``paged_forward(..., return_all_logits=True)`` at static length
``k + 1``). Greedy acceptance: keep the longest prefix where the draft
matches the verifier's argmax, then emit the verifier's own next token —
so every verify step emits between 1 and ``k + 1`` tokens and the output
is *token-for-token identical* to non-speculative greedy decode (pinned by
``tests/test_serving_paged.py``).

Mechanics on the paged-KV subsystem:

* ONE host :class:`~repro.serving.kv_cache.PagePool` + scheduler + block
  tables drive TWO device pools with identical page geometry (same
  num_pages / page_size / per-shard trash pages): the verifier's MoE KV
  and the drafter's dense KV. Prefill chunks, COW clones, and defrag
  permutations are applied to both in lockstep, so a block-table entry
  means the same thing in either pool. Prefix-cache hits therefore skip
  prefill compute for drafter and verifier at once — the two features
  compound.
* Per row, the draft depth is ``min(k, remaining - 1, lookahead)`` where
  ``lookahead`` is how many pages past the next write the scheduler could
  map *without preemption* (speculative appetite must not evict admitted
  work — it degrades to plain decode when the pool is tight).
* The drafter runs ``d + 1`` decode steps (inputs ``t0, d1..dd``), so its
  KV covers the same positions the verifier writes; rejected positions in
  both pools are masked by ``seq_lens`` until overwritten by later steps.

Acceptance-rate semantics: ``accepted_tokens / drafted_tokens`` counts
only draft positions (the always-emitted correction/bonus token is free).
A function-preserving upcycled pair accepts ~100%; the rate degrades
gracefully as the MoE trains away from its parent, and correctness never
depends on it.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.upcycle import upcycle_params, upcycle_provenance
from repro.models.model import decode_step_paged, paged_forward
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import copy_pages, init_paged_pool, permute_pool


class SpeculativeEngine(ServingEngine):
    """Paged :class:`ServingEngine` whose decode phase drafts ``draft_k``
    tokens on a dense parent model and verifies them in one MoE step."""

    def __init__(self, cfg: ModelConfig, params, draft_cfg: ModelConfig,
                 draft_params, draft_k: int = 4, **kw):
        assert draft_k >= 1, draft_k
        if kw.setdefault("cache_mode", "paged") != "paged":
            raise ValueError("SpeculativeEngine requires cache_mode='paged'")
        if kw.get("mesh") is not None:
            raise ValueError("SpeculativeEngine does not support mesh mode yet")
        if draft_cfg.vocab_size != cfg.vocab_size:
            raise ValueError(
                "drafter and verifier must share the tokenizer: "
                f"{draft_cfg.vocab_size} != {cfg.vocab_size}"
            )
        super().__init__(cfg, params, **kw)
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        self.draft_k = draft_k
        # drafter device pool mirrors the verifier's page geometry so the
        # one set of block tables addresses both
        self.draft_pool_dev = init_paged_pool(
            draft_cfg, self.num_pages, self.page_size, num_shards=self.dp_shards
        )
        self._draft_chunk = jax.jit(
            lambda p, pool, t, s, bt, vl, tr: paged_forward(
                draft_cfg, None, p, pool, t, s, bt, vl,
                use_kernel=self.use_kernel, trash_page=tr,
            ),
            donate_argnums=(1,),
        )
        self._draft_decode = jax.jit(
            lambda p, pool, t, pos, bt, a, tr: decode_step_paged(
                draft_cfg, None, p, pool, t, pos, bt, a,
                use_kernel=self.use_kernel, trash_page=tr,
            ),
            donate_argnums=(1,),
        )
        # verify = one chunk at static S = k+1 returning logits at EVERY
        # position; per-row real lengths via valid_len (d + 1)
        self._verify_fn = jax.jit(
            lambda p, pool, t, s, bt, vl, tr: paged_forward(
                cfg, None, p, pool, t, s, bt, vl,
                use_kernel=self.use_kernel, trash_page=tr,
                return_all_logits=True,
            ),
            donate_argnums=(1,),
        )
        self.spec_steps = 0  # verify calls (= decode-phase engine steps)
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.provenance = None  # set by from_upcycle

    @classmethod
    def from_upcycle(cls, dense_cfg: ModelConfig, moe_cfg: ModelConfig,
                     dense_params, rng: Optional[jax.Array] = None,
                     draft_k: int = 4, **kw) -> "SpeculativeEngine":
        """Build the drafter/verifier pair the way the paper builds the
        models: upcycle the dense parent's params into the MoE (function-
        preserving at Mixtral router init), keep the dense params as the
        drafter, and record the :func:`upcycle_provenance` link."""
        if rng is None:
            rng = jax.random.PRNGKey(0)
        params = upcycle_params(dense_cfg, moe_cfg, dense_params, rng)
        eng = cls(moe_cfg, params, dense_cfg, dense_params,
                  draft_k=draft_k, **kw)
        eng.provenance = upcycle_provenance(dense_cfg, moe_cfg)
        return eng

    @property
    def acceptance_rate(self) -> float:
        if self.drafted_tokens == 0:
            return 0.0
        return self.accepted_tokens / self.drafted_tokens

    # -- lockstep hooks ------------------------------------------------------
    def _apply_cow(self, copies) -> None:
        super()._apply_cow(copies)
        self.draft_pool_dev = copy_pages(self.draft_pool_dev, copies)

    def _permute_pools(self, mapping) -> None:
        super()._permute_pools(mapping)
        self.draft_pool_dev = permute_pool(self.draft_pool_dev, mapping)

    def _prefill_chunk_device(self, toks, start, bt, vlen, trash):
        _, self.draft_pool_dev = self._draft_chunk(
            self.draft_params, self.draft_pool_dev, toks, start, bt, vlen,
            trash,
        )
        return super()._prefill_chunk_device(toks, start, bt, vlen, trash)

    # -- draft / verify decode ----------------------------------------------
    def _run_decode(self, plan) -> None:
        slots = plan.decode_slots
        B, k, V = self.max_batch, self.draft_k, self.cfg.vocab_size
        # per-row draft depth: never draft past the request's budget (the
        # correction token always emits), never force page eviction
        d = np.zeros((B,), np.int32)
        for slot in slots:
            req = self._rid2req[self.sched.running[slot].rid]
            want = max(min(k, req.max_new_tokens - len(req.output) - 1), 0)
            d[slot] = self.sched.ensure_lookahead(slot, want)
        base_pos = np.zeros((B,), np.int32)
        for slot in slots:
            base_pos[slot] = self.sched.running[slot].decode_pos
        bt = jnp.asarray(self.sched.tables, jnp.int32)
        trash = jnp.asarray(self._trash_np)

        # ---- draft phase: d+1 drafter decodes per row (feed t0, d1..dd) —
        # the last step writes the drafter's KV at base+d so a fully-
        # accepted step leaves no KV hole
        drafts = np.zeros((B, k), np.int32)
        cur = self._next_np.copy()
        pos = base_pos.copy()
        for i in range(k + 1):
            act = np.zeros((B,), np.int32)
            for slot in slots:
                if i <= d[slot]:
                    act[slot] = 1
            if not act.any():
                break
            logits, self.draft_pool_dev = self._draft_decode(
                self.draft_params, self.draft_pool_dev, jnp.asarray(cur),
                jnp.asarray(pos), bt, jnp.asarray(act), trash,
            )
            toks = np.asarray(jnp.argmax(logits[:, :V], axis=-1), np.int32)
            for slot in slots:
                if i <= d[slot]:
                    pos[slot] += 1
                    if i < d[slot]:
                        drafts[slot, i] = toks[slot]
                        cur[slot] = toks[slot]

        # ---- verify phase: one MoE chunk scores t0 + all drafts ----------
        vt = np.zeros((B, k + 1), np.int32)
        vl = np.zeros((B,), np.int32)
        for slot in slots:
            vt[slot, 0] = self._next_np[slot]
            vt[slot, 1:1 + d[slot]] = drafts[slot, :d[slot]]
            vl[slot] = d[slot] + 1
        logits_all, self.pool_dev = self._verify_fn(
            self.params, self.pool_dev, jnp.asarray(vt),
            jnp.asarray(base_pos), bt, jnp.asarray(vl), trash,
        )
        targets = np.asarray(
            jnp.argmax(logits_all[:, :, :V], axis=-1), np.int32
        )  # (B, k+1): target token after each input position

        # ---- accept longest agreeing prefix + the verifier's correction --
        self.spec_steps += 1
        for slot in slots:
            req = self._rid2req[self.sched.running[slot].rid]
            m = 0
            while m < d[slot] and drafts[slot, m] == targets[slot, m]:
                m += 1
            self.drafted_tokens += int(d[slot])
            self.accepted_tokens += m
            emitted = list(drafts[slot, :m]) + [targets[slot, m]]
            for tok in emitted:
                tok = int(tok)
                self._next_np[slot] = tok
                done = self._emit(req, tok)
                self.sched.on_token(slot, done)
                if done:
                    break  # later verified tokens are discarded (eos/budget)

    def kv_stats(self):
        stats = super().kv_stats()
        stats["speculation"] = {
            "draft_k": self.draft_k,
            "spec_steps": self.spec_steps,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "acceptance_rate": round(self.acceptance_rate, 4),
        }
        return stats
