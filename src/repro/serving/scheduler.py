"""Continuous-batching scheduler with chunked prefill over a page pool.

Policy layer only — no model, no device arrays — so the property suite can
drive it with simulated token streams. Each :meth:`ChunkedScheduler.plan`
call produces one engine step:

* **admission**: FIFO from the queue into free batch slots, gated by the
  free-page budget (a request is admitted only if its whole prompt fits,
  plus ``watermark`` reserve pages — chunked prefill then spreads the
  actual allocation over several steps). Under ``dp_shards > 1`` the batch
  slots are partitioned into contiguous blocks (one per DP shard, matching
  the mesh 'data' sharding of the decode batch) and each shard owns one
  sub-pool: the head request is placed into the free slot whose shard has
  the largest free-page budget, so load balances across shard pools while
  admission still reasons over the aggregate (a request blocked on every
  shard blocks the queue, FIFO preserved).
* **chunked prefill**: each prefilling slot contributes at most
  ``prefill_chunk`` prompt tokens per step, so a long prompt interleaves
  with decode instead of stalling the batch. The chunk length is static
  (the last chunk is right-padded), so ONE compiled prefill step serves
  every chunk of every request.
* **decode**: every slot whose prompt is fully prefilled decodes one token.
* **preemption**: when the pool cannot supply a page, the *youngest*
  running request is evicted (pages freed, requeued at the front for
  recompute — its generated tokens become prompt suffix). Victims are
  always strictly younger than the request that needs the page, so the
  oldest request always makes progress and every submitted request
  terminates (provided the pool can hold one maximal request — enforced at
  ``submit``). With ``dp_shards > 1`` victims come from the *same shard*
  as the starved request — only their pages live in that sub-pool — and
  the termination argument applies per shard (each shard's oldest request
  always progresses).
* **sliding window**: with ``window`` set, pages that fall entirely below
  the window of every future query are released immediately — the window
  mask already excludes them, so paged decode holds O(window) KV per
  request where the full-context mapping would hold O(position).
* **prefix-cache admission credit**: when the pool has a
  :class:`~repro.serving.kv_cache.PrefixCache` and the request carries its
  token ids, admission matches the prompt against the per-shard radix trie
  and *credits* the hit pages against the budget — a request whose prompt
  is mostly cached system prompt admits with near-zero new pages. On
  placement the hit pages are refcount-attached into the block table and
  ``prefilled`` skips past them (a fully-covered prompt COW-clones its
  last page so the final-token recompute chunk never writes a shared
  page); the engine promotes freshly-prefilled full prompt pages back into
  the trie via :meth:`ChunkedScheduler.note_prefilled`. Budgets count
  refcount-0 cache pages as available (``PagePool.available_in``) since
  ``alloc`` reclaims them on demand — retained cache never stalls
  admission a cache-less pool would have granted. Incompatible with
  ``window`` (shared pages must be immutable; a window releases them).
* **graceful degradation** (opt-in): ``max_queue``/``shed_watermark``
  bound the backlog at :meth:`submit` — a request that would overflow the
  queue or outrun the pool's spare capacity is rejected with a typed
  :class:`~repro.resilience.recovery.ShedError` (the client backs off)
  instead of being silently enqueued into an unservable backlog.
  ``deadline_steps`` (config default, overridable per request) evicts
  requests that have aged past their step budget at the top of each
  :meth:`plan` — queued or running — freeing their pages for work that can
  still meet its deadline. Evictions are loud: the rid lands in
  ``StepPlan.expired`` and the request's ``status`` becomes ``"deadline"``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.resilience.recovery import ShedError
from repro.serving.kv_cache import PagePool


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int
    page_size: int
    prefill_chunk: int
    max_pages_per_seq: int
    watermark: int = 0  # free pages kept in reserve at admission (per shard)
    window: Optional[int] = None  # sliding window: release dead pages
    dp_shards: int = 1  # batch-slot/sub-pool partitions (EP x DP serving)
    # graceful degradation (None = disabled, the seed behavior):
    deadline_steps: Optional[int] = None  # evict requests older than this
    max_queue: Optional[int] = None  # shed submits past this queue depth
    shed_watermark: Optional[int] = None  # shed when spare pages dip below


@dataclasses.dataclass
class SchedRequest:
    rid: int
    prompt_len: int  # current prompt (grows by generated tokens on preempt)
    max_new_tokens: int
    orig_prompt_len: int = 0
    admit_seq: int = -1  # admission order; -1 = never admitted
    slot: int = -1
    prefilled: int = 0  # prompt tokens already in the cache
    generated: int = 0  # output tokens emitted (across preemptions)
    gen_base: int = 0  # outputs folded into prompt_len by preemption
    logical_pages: int = 0  # logical pages ever allocated (monotone)
    preemptions: int = 0
    done: bool = False
    submit_step: int = 0  # scheduler step count at submit (deadline clock)
    deadline_steps: Optional[int] = None  # per-request deadline override
    status: str = "ok"  # "ok" | "deadline" (evicted past its deadline)
    tokens: Optional[np.ndarray] = None  # prompt ids (prefix-cache key)
    prefix_hit_tokens: int = 0  # prompt tokens served from cache (last admit)

    @property
    def in_prefill(self) -> bool:
        return self.prefilled < self.prompt_len

    @property
    def decode_pos(self) -> int:
        """Cache position the next decode step writes: the prompt plus the
        outputs emitted since the last (re)prefill, minus the one output
        that has not been fed back yet."""
        return self.prompt_len + (self.generated - self.gen_base) - 1


@dataclasses.dataclass
class PrefillChunk:
    rid: int
    slot: int
    start: int  # offset into the request's current full token list
    length: int  # real tokens this chunk (<= prefill_chunk)
    final: bool  # True => chunk logits emit the first/next output token


@dataclasses.dataclass
class StepPlan:
    prefills: List[PrefillChunk]
    decode_slots: List[int]
    preempted: List[int]  # rids evicted while building this plan
    expired: List[int] = dataclasses.field(default_factory=list)  # deadline
    # COW clones from prefix-cache admission: (src_phys, dst_phys) device
    # page copies the engine must apply BEFORE running this step's chunks
    cow_copies: List[Tuple[int, int]] = dataclasses.field(default_factory=list)


class ChunkedScheduler:
    def __init__(self, cfg: SchedulerConfig, pool: PagePool):
        assert pool.page_size == cfg.page_size
        assert pool.num_shards == cfg.dp_shards, (pool.num_shards, cfg.dp_shards)
        assert cfg.max_batch % cfg.dp_shards == 0, (cfg.max_batch, cfg.dp_shards)
        if pool.prefix is not None and cfg.window is not None:
            raise ValueError(
                "prefix cache and sliding window are mutually exclusive: "
                "shared pages must be immutable, a window releases them"
            )
        self.cfg = cfg
        self.pool = pool
        self.slots_per_shard = cfg.max_batch // cfg.dp_shards
        self.queue: Deque[SchedRequest] = deque()
        self.running: Dict[int, SchedRequest] = {}  # slot -> request
        self.requests: Dict[int, SchedRequest] = {}  # rid -> request
        self.tables = np.full((cfg.max_batch, cfg.max_pages_per_seq), -1, np.int64)
        self._admit_counter = 0
        self.peak_resident_requests = 0  # max concurrent running (bench)
        self.step_count = 0  # plan() calls; the deadline clock
        self.shed_count = 0  # submits rejected by max_queue/shed_watermark
        self.deadline_evictions = 0
        self.prefix_hit_tokens = 0  # prompt tokens served from cache (total)

    # -- submission ---------------------------------------------------------
    def submit(self, rid: int, prompt_len: int, max_new_tokens: int,
               deadline_steps: Optional[int] = None,
               tokens: Optional[np.ndarray] = None) -> None:
        total = prompt_len + max_new_tokens
        need = self.pool.pages_for(total)
        if need > self.cfg.max_pages_per_seq:
            raise ValueError(
                f"request {rid}: {total} tokens need {need} pages "
                f"> max_pages_per_seq={self.cfg.max_pages_per_seq}"
            )
        # with a sliding window dead pages are released as decode advances,
        # so the live set is bounded by the window span, not the total.
        # A request lives entirely in one shard's sub-pool, so the bound is
        # per-shard capacity, not the aggregate.
        live = self._live_bound(total)
        if live > self.pool.pages_per_shard:
            raise ValueError(
                f"request {rid}: needs {live} live pages > per-shard pool "
                f"of {self.pool.pages_per_shard}"
            )
        # load shedding: reject at the door (typed, actionable) rather than
        # queueing work the engine cannot serve in bounded time
        if (self.cfg.max_queue is not None
                and len(self.queue) >= self.cfg.max_queue):
            self.shed_count += 1
            raise ShedError(
                f"request {rid} shed: queue depth {len(self.queue)} at "
                f"max_queue={self.cfg.max_queue}; back off and resubmit"
            )
        if self.cfg.shed_watermark is not None:
            backlog = sum(
                self._live_bound(r.prompt_len + r.max_new_tokens)
                for r in self.queue
            )
            free = sum(
                self.pool.available_in(sh)
                for sh in range(self.cfg.dp_shards)
            )
            if free - self.cfg.shed_watermark < live + backlog:
                self.shed_count += 1
                raise ShedError(
                    f"request {rid} shed: needs {live} pages + {backlog} "
                    f"queued, but only {free} free "
                    f"(shed_watermark={self.cfg.shed_watermark}); back off "
                    "and resubmit"
                )
        if tokens is not None:
            assert len(tokens) == prompt_len, (len(tokens), prompt_len)
        req = SchedRequest(
            rid=rid, prompt_len=prompt_len, max_new_tokens=max_new_tokens,
            orig_prompt_len=prompt_len, submit_step=self.step_count,
            deadline_steps=deadline_steps, tokens=tokens,
        )
        self.requests[rid] = req
        self.queue.append(req)

    # -- queries ------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.running)

    def block_table(self, slot: int) -> np.ndarray:
        return self.tables[slot]

    def shard_of_slot(self, slot: int) -> int:
        """DP shard owning ``slot``: contiguous slot blocks, matching the
        mesh 'data' sharding of the decode batch rows."""
        return slot // self.slots_per_shard

    # -- planning -----------------------------------------------------------
    def plan(self) -> StepPlan:
        self.step_count += 1
        preempted: List[int] = []
        cow_copies: List[Tuple[int, int]] = []
        expired = self._expire()
        self._admit(cow_copies)
        self.peak_resident_requests = max(
            self.peak_resident_requests, len(self.running)
        )
        prefills: List[PrefillChunk] = []
        # oldest first, so page pressure evicts the newest work
        for slot, req in sorted(self.running.items(), key=lambda kv: kv[1].admit_seq):
            if self.running.get(slot) is not req:
                continue  # evicted by an older request earlier in this loop
            if not req.in_prefill:
                continue
            length = min(self.cfg.prefill_chunk, req.prompt_len - req.prefilled)
            end = req.prefilled + length
            # release dead window pages BEFORE allocating for this chunk —
            # and only up to the pre-chunk boundary: the chunk's earliest
            # query (position `start`) still sees kpos > start - window
            self._release_dead(req, stored=req.prefilled)
            if not self._ensure_pages(req, end, preempted):
                continue  # stalled this step; oldest-first makes it retry
            prefills.append(PrefillChunk(
                rid=req.rid, slot=slot, start=req.prefilled, length=length,
                final=(end == req.prompt_len),
            ))
            req.prefilled = end
        decode_slots: List[int] = []
        for slot, req in sorted(self.running.items(), key=lambda kv: kv[1].admit_seq):
            if self.running.get(slot) is not req:
                continue  # evicted by an older request earlier in this loop
            if req.in_prefill or req.rid in {c.rid for c in prefills}:
                continue
            if self._ensure_pages(req, req.decode_pos + 1, preempted):
                decode_slots.append(slot)
        # a request whose chunk was planned above may have been evicted by an
        # older request's allocation — its pages are gone, drop its actions
        if preempted:
            gone = set(preempted)
            prefills = [c for c in prefills if c.rid not in gone]
            # a preempted hit-request's COW target page was freed with it
            live_cows = []
            for src, dst in cow_copies:
                holder = next((r for r in self.running.values()
                               if dst in self.pool.owned(r.rid)), None)
                if holder is not None:
                    live_cows.append((src, dst))
            cow_copies = live_cows
        return StepPlan(prefills, decode_slots, preempted, expired, cow_copies)

    def on_token(self, slot: int, done: bool) -> None:
        """Record one output token for ``slot`` (from a decode step or a
        final prefill chunk); frees everything when the request is done."""
        req = self.running[slot]
        req.generated += 1
        if done:
            req.done = True
            self.pool.free_request(req.rid)
            self.tables[slot] = -1
            del self.running[slot]
        else:
            # generated was just bumped, so decode_pos == tokens now stored
            self._release_dead(req, stored=req.decode_pos)

    def oldest_request_age(self) -> int:
        """Steps since the oldest live (queued or running) request was
        submitted — the engine health snapshot's staleness headline."""
        live = list(self.queue) + list(self.running.values())
        if not live:
            return 0
        return self.step_count - min(r.submit_step for r in live)

    # -- internals ----------------------------------------------------------
    def _expire(self) -> List[int]:
        """On-time eviction: terminate every queued/running request whose
        age exceeds its deadline (per-request override, else the config
        default). Pages are freed immediately so the reclaimed capacity
        serves requests that can still meet their deadlines."""
        out: List[int] = []
        for req in list(self.queue) + list(self.running.values()):
            dl = (req.deadline_steps if req.deadline_steps is not None
                  else self.cfg.deadline_steps)
            if dl is None or self.step_count - req.submit_step <= dl:
                continue
            if req.slot >= 0:
                self.pool.free_request(req.rid)
                self.tables[req.slot] = -1
                del self.running[req.slot]
            else:
                self.queue.remove(req)
            req.done = True
            req.status = "deadline"
            self.deadline_evictions += 1
            out.append(req.rid)
        return out

    def _admit(self, cow_copies: Optional[List[Tuple[int, int]]] = None) -> None:
        while self.queue:
            free_slots = [
                s for s in range(self.cfg.max_batch) if s not in self.running
            ]
            if not free_slots:
                return
            req = self.queue[0]
            need = self._live_bound(req.prompt_len)
            # Pages already promised to admitted-but-still-prefilling
            # requests count against the budget, so two large prompts
            # cannot both be admitted into the same free sub-pool. An idle
            # shard waives the watermark — a request that fits its raw
            # sub-pool must always be admittable (deadlock avoidance).
            # Budgets are per shard; the head request takes the free slot
            # whose shard has the most headroom (ties -> lowest slot, which
            # at dp_shards=1 is exactly the original FIFO slot choice).
            # Prefix-cache hit pages count as credit: a cached prompt needs
            # only its uncached tail from the budget, so hit requests admit
            # with near-zero new pages.
            best_slot, best_headroom, best_credit = None, None, 0
            for slot in free_slots:
                shard = self.shard_of_slot(slot)
                credit = len(self._prefix_match(req, shard))
                headroom = self._shard_budget(shard) + credit
                if best_headroom is None or headroom > best_headroom:
                    best_slot, best_headroom, best_credit = slot, headroom, credit
            if best_headroom < need:
                return  # head-of-line blocking preserves FIFO order
            self.queue.popleft()
            req.slot = best_slot
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
            self.running[req.slot] = req
            if best_credit:
                self._attach_prefix(req, cow_copies if cow_copies is not None
                                    else [])

    def _prefix_match(self, req: SchedRequest, shard: int) -> List[int]:
        """Cached pages covering ``req``'s prompt head on ``shard`` (empty
        without a prefix cache or prompt tokens)."""
        if self.pool.prefix is None or req.tokens is None:
            return []
        return self.pool.prefix.match(req.tokens, shard)

    def _attach_prefix(self, req: SchedRequest,
                       cow_copies: List[Tuple[int, int]]) -> None:
        """Refcount-attach the cached prefix pages into ``req``'s block
        table and skip ``prefilled`` past them. A fully-covered prompt
        COW-clones its last page: the final-token recompute chunk (which
        must run — its logits emit the first output token) scatters into
        the private clone, never into a shared page."""
        shard = self.shard_of_slot(req.slot)
        pages = self.pool.prefix.acquire(req.rid, req.tokens, shard)
        if not pages:
            return
        ps = self.cfg.page_size
        if len(pages) * ps >= req.prompt_len:
            new = self.pool.cow(req.rid, pages[-1])
            if new is None:  # shard dry: shrink the hit by one page instead
                self.pool.detach(req.rid, [pages[-1]])
                pages = pages[:-1]
            else:
                cow_copies.append((pages[-1], new))
                pages = pages[:-1] + [new]
        if not pages:
            return
        for j, p in enumerate(pages):
            self.tables[req.slot, j] = p
        req.logical_pages = len(pages)
        req.prefilled = min(len(pages) * ps, req.prompt_len - 1)
        req.prefix_hit_tokens = req.prefilled
        self.prefix_hit_tokens += req.prefilled

    def note_prefilled(self, rid: int, covered: int) -> int:
        """Engine callback after a prefill chunk actually ran: promote the
        request's freshly-written private pages covering full *original
        prompt* token runs ``[0, covered)`` into the prefix cache. Returns
        pages newly promoted. No-op without a cache / prompt tokens, or if
        the request was preempted before the chunk's effects were
        recorded."""
        req = self.requests[rid]
        if (self.pool.prefix is None or req.tokens is None or req.slot < 0
                or self.running.get(req.slot) is not req):
            return 0
        full = min(covered, len(req.tokens)) // self.cfg.page_size
        if full <= 0:
            return 0
        return self.pool.prefix.insert(
            rid, req.tokens, full, self.tables[req.slot]
        )

    def ensure_lookahead(self, slot: int, extra: int) -> int:
        """Map pages for up to ``extra`` tokens beyond the next decode
        write WITHOUT preemption — speculative lookahead must not evict
        admitted work. Returns the lookahead actually backed by pages
        (falls back toward 0 when the shard is tight)."""
        req = self.running[slot]
        shard = self.shard_of_slot(slot)
        while extra > 0:
            need = self.pool.pages_for(req.decode_pos + 1 + extra)
            n_new = need - req.logical_pages
            if n_new <= 0:
                break
            if n_new <= self.pool.available_in(shard):
                pages = self.pool.alloc(req.rid, n_new, shard=shard)
                if pages is not None:
                    for i, p in enumerate(pages):
                        self.tables[slot, req.logical_pages + i] = p
                    req.logical_pages = need
                    break
            extra -= 1
        return max(extra, 0)

    def _shard_budget(self, shard: int) -> int:
        """Allocatable pages of ``shard``'s sub-pool (free + reclaimable
        refcount-0 cache pages) minus its admission reserve (watermark +
        pages committed to still-prefilling residents). A resident's
        commitment counts every page backing it — private and
        shared-referenced (``PagePool.held``) — so a prefix-hit request
        reserves only its uncached tail."""
        residents = [
            r for r in self.running.values()
            if self.shard_of_slot(r.slot) == shard
        ]
        committed = sum(
            max(0, self._live_bound(r.prompt_len) - self.pool.held(r.rid))
            for r in residents if r.in_prefill
        )
        reserve = self.cfg.watermark + committed if residents else 0
        return self.pool.available_in(shard) - reserve

    def _live_bound(self, tokens: int) -> int:
        """Peak live pages a span of ``tokens`` can pin. With a sliding
        window, at most ``window + prefill_chunk - 1`` KV positions are
        live at once (the window span plus the chunk being written), and a
        span of L positions straddles at most pages_for(L) + 1 pages."""
        need = self.pool.pages_for(tokens)
        if self.cfg.window is not None:
            span = self.cfg.window + max(self.cfg.prefill_chunk - 1, 0)
            need = min(need, self.pool.pages_for(span) + 1)
        return need

    def _ensure_pages(self, req: SchedRequest, upto_tokens: int,
                      preempted: List[int]) -> bool:
        """Allocate pages (from ``req``'s shard sub-pool) so logical slots
        [0, upto_tokens) are mapped, evicting strictly-younger same-shard
        requests if that sub-pool runs dry. False if the request must stall
        this step."""
        need = self.pool.pages_for(upto_tokens)
        shard = self.shard_of_slot(req.slot)
        while need > req.logical_pages:
            n_new = need - req.logical_pages
            pages = self.pool.alloc(req.rid, n_new, shard=shard)
            if pages is None:
                if self.pool.available_in(shard) >= n_new:
                    # the sub-pool could have satisfied this: a transient
                    # alloc failure (fault injection / flaky allocator),
                    # not genuine pressure — stall this step and retry
                    # instead of evicting innocents
                    return False
                victim = self._youngest_running(older_than=req, shard=shard)
                if victim is None:
                    sh_seqs = [
                        r.admit_seq for r in self.running.values()
                        if self.shard_of_slot(r.slot) == shard
                    ]
                    # "too small" only when everything non-reclaimable in
                    # the shard already backs this request (held counts
                    # private + shared-referenced pages; refcount-0 cache
                    # pages would have been reclaimed by alloc)
                    if req.admit_seq == min(sh_seqs) and (
                        self.pool.used_pages_in(shard)
                        - self.pool.evictable_in(shard)
                        == self.pool.held(req.rid)
                    ):
                        raise RuntimeError(
                            f"page pool shard ({self.pool.pages_per_shard} "
                            f"pages) too small for request {req.rid} alone"
                        )
                    return False
                self._preempt(victim)
                preempted.append(victim.rid)
                continue
            for i, p in enumerate(pages):
                self.tables[req.slot, req.logical_pages + i] = p
            req.logical_pages = need
        return True

    def _youngest_running(self, older_than: SchedRequest,
                          shard: int) -> Optional[SchedRequest]:
        """Youngest running request in ``shard`` strictly younger than
        ``older_than`` — only its pages can relieve that shard's pool."""
        cands = [
            r for r in self.running.values()
            if r.admit_seq > older_than.admit_seq
            and self.shard_of_slot(r.slot) == shard
        ]
        return max(cands, key=lambda r: r.admit_seq) if cands else None

    def _preempt(self, victim: SchedRequest) -> None:
        """Evict by recompute: free the pages, fold generated tokens into
        the prompt, requeue at the front."""
        self.pool.free_request(victim.rid)
        self.tables[victim.slot] = -1
        del self.running[victim.slot]
        victim.prompt_len = victim.orig_prompt_len + victim.generated
        victim.gen_base = victim.generated
        victim.prefilled = 0
        victim.logical_pages = 0
        victim.slot = -1
        victim.admit_seq = -1
        victim.preemptions += 1
        victim.prefix_hit_tokens = 0  # re-admission re-attaches from the trie
        self.queue.appendleft(victim)

    def _release_dead(self, req: SchedRequest, stored: int) -> None:
        """With a sliding window, free pages no future query can see. A
        future query at position >= ``stored`` masks kpos <= pos - window,
        so page j is dead once (j+1)*ps - 1 <= stored - window."""
        w = self.cfg.window
        if w is None:
            return
        ps = self.cfg.page_size
        dead = []
        for j in range(req.logical_pages):
            phys = self.tables[req.slot, j]
            if phys >= 0 and (j + 1) * ps - 1 <= stored - w:
                dead.append((j, int(phys)))
        if dead:
            self.pool.release(req.rid, [p for _, p in dead])
            for j, _ in dead:
                self.tables[req.slot, j] = -1

    def apply_defrag(self, mapping: Dict[int, int]) -> None:
        """Rewrite block tables after ``PagePool.defrag`` (the engine
        permutes the device pool with the same mapping)."""
        for old, new in mapping.items():
            self.tables[self.tables == old] = -2 - new  # two-phase to avoid clashes
        neg = self.tables <= -2
        self.tables[neg] = -2 - self.tables[neg]
