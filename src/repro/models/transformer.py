"""Transformer block stacks: dense, MoE, SSM, hybrid (jamba), enc-dec.

Layers are organized as (periods x slots): a *slot* is one block kind
(mixer in {attn, ssm} x ffn in {dense, moe, none}); the stack repeats the
slot list ``periods`` times via ``lax.scan`` over stacked parameters. This
keeps the HLO size O(slots) regardless of depth (critical for compiling
72-layer Jamba on 512 fake devices) and is the PP-replacement documented in
DESIGN.md. Heterogeneous patterns (jamba's M M M A M M M M mixer period,
MoE-every-2nd-layer) become slot lists.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.moe import moe_apply, moe_decl
from repro.models.attention import attention_apply, attention_decl, gqa_apply, gqa_decl
from repro.models.layers import mlp_apply, mlp_decl, norm_apply, norm_decl
from repro.models.ssm import ssm_apply, ssm_cache_decl, ssm_decl
from repro.sharding.rules import FoldingPlan, ParamDecl


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str  # 'attn' | 'ssm'
    ffn: str  # 'dense' | 'moe' | 'none'
    cross_attn: bool = False
    causal: bool = True


def build_slots(cfg: ModelConfig) -> List[BlockSpec]:
    """Slot list for one period of the decoder stack."""
    moe = cfg.moe
    moe_freq = moe.moe_layer_freq if moe is not None else 1
    if cfg.family == "ssm":
        return [BlockSpec("ssm", "dense" if cfg.d_ff else "none")]
    if cfg.family == "hybrid":
        pat = cfg.hybrid_pattern or "M"
        period = len(pat)
        if moe is not None and period % moe_freq != 0:
            period = period * moe_freq
        slots = []
        for i in range(period):
            mixer = "ssm" if (cfg.hybrid_pattern or "M")[i % len(cfg.hybrid_pattern or "M")] == "M" else "attn"
            ffn = "dense"
            if moe is not None and (i % moe_freq) == (moe_freq - 1):
                ffn = "moe"
            slots.append(BlockSpec(mixer, ffn))
        return slots
    # dense / moe / vlm / encdec-decoder
    slots = []
    for i in range(moe_freq):
        ffn = "moe" if (moe is not None and i == moe_freq - 1) else "dense"
        slots.append(
            BlockSpec("attn", ffn, cross_attn=(cfg.family == "encdec"))
        )
    return slots


def periods_for(cfg: ModelConfig, slots: List[BlockSpec]) -> int:
    assert cfg.num_layers % len(slots) == 0, (cfg.num_layers, len(slots))
    return cfg.num_layers // len(slots)


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------


def block_decl(cfg: ModelConfig, spec: BlockSpec) -> Dict[str, Any]:
    decls: Dict[str, Any] = {"norm1": norm_decl(cfg.d_model, cfg.norm_type)}
    decls["mixer"] = ssm_decl(cfg) if spec.mixer == "ssm" else attention_decl(cfg)
    if spec.cross_attn:
        decls["norm_cross"] = norm_decl(cfg.d_model, cfg.norm_type)
        decls["cross"] = gqa_decl(cfg)
    if spec.ffn != "none":
        decls["norm2"] = norm_decl(cfg.d_model, cfg.norm_type)
        if spec.ffn == "moe":
            assert cfg.moe is not None
            decls["ffn"] = moe_decl(cfg, cfg.moe)
        else:
            decls["ffn"] = mlp_decl(cfg.d_model, cfg.d_ff)
    return decls


def block_apply(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    spec: BlockSpec,
    params,
    x: jax.Array,
    positions: jax.Array,
    rng: Optional[jax.Array] = None,
    train: bool = False,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_view: Optional[Dict[str, jax.Array]] = None,
    cross_ctx: Optional[Tuple[jax.Array, jax.Array]] = None,  # (enc_out, enc_pos)
    use_kernel: bool = False,
    return_cache: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]], Dict[str, jax.Array]]:
    aux: Dict[str, jax.Array] = {}
    h = norm_apply(params["norm1"], x, cfg.norm_type, cfg.norm_eps)
    new_cache: Dict[str, jax.Array] = {}
    if spec.mixer == "ssm":
        mix, c = ssm_apply(
            cfg, plan, params["mixer"], h,
            cache.get("ssm") if cache else None, return_state=return_cache,
        )
        if c is not None:
            new_cache["ssm"] = c
    else:
        if spec.mixer == "attn" and not spec.causal:
            mix, c = gqa_apply(
                cfg, plan, params["mixer"], h, positions, causal=False,
                use_kernel=use_kernel,
            )
        else:
            mix, c = attention_apply(
                cfg, plan, params["mixer"], h, positions,
                cache.get("attn") if cache else None, cache_view,
                return_kv=return_cache, use_kernel=use_kernel,
            )
        if c is not None:
            new_cache["attn"] = c
    x = x + mix

    if spec.cross_attn:
        assert cross_ctx is not None or (cache is not None and "cross" in cache)
        h = norm_apply(params["norm_cross"], x, cfg.norm_type, cfg.norm_eps)
        if cache is not None and "cross" in cache:
            ck, cv, cp = cache["cross"]["k"], cache["cross"]["v"], cache_view["enc_pos"]
            new_cache["cross"] = cache["cross"]
        else:
            enc_out, cp = cross_ctx
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, params["cross"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, params["cross"]["wv"])
        cx, _ = gqa_apply(
            cfg, plan, params["cross"], h, positions, cross_kv=(ck, cv, cp)
        )
        x = x + cx

    if spec.ffn != "none":
        h = norm_apply(params["norm2"], x, cfg.norm_type, cfg.norm_eps)
        if spec.ffn == "moe":
            y, aux = moe_apply(
                cfg, cfg.moe, plan, params["ffn"], h, rng, train, use_kernel
            )
        else:
            y = mlp_apply(params["ffn"], h)
            if plan is not None:
                y = plan.constrain(y, "fold_batch", None, None)
        x = x + y
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Stack: scan over periods
# ---------------------------------------------------------------------------


def _stack_decl_one(cfg: ModelConfig, spec: BlockSpec, periods: int):
    """Block decls with a leading stacked 'layers' dim of size ``periods``."""
    decls = block_decl(cfg, spec)

    def stack(d: ParamDecl) -> ParamDecl:
        return ParamDecl((periods,) + d.shape, ("layers",) + d.axes, d.init, d.dtype)

    return jax.tree.map(stack, decls, is_leaf=lambda d: isinstance(d, ParamDecl))


def stack_decl(cfg: ModelConfig, slots: List[BlockSpec], periods: int) -> Dict[str, Any]:
    return {f"slot{i}": _stack_decl_one(cfg, s, periods) for i, s in enumerate(slots)}


AUX_KEYS = ("load_balance_loss", "z_loss")


def stack_apply(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    slots: List[BlockSpec],
    params: Dict[str, Any],
    x: jax.Array,
    positions: jax.Array,
    rng: Optional[jax.Array] = None,
    train: bool = False,
    cache: Optional[Dict[str, Any]] = None,
    cache_view: Optional[Dict[str, jax.Array]] = None,
    cross_ctx=None,
    use_kernel: bool = False,
    return_cache: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, Any]], Dict[str, jax.Array]]:
    """params[slot_i] leaves have leading (periods,) dim; scanned.
    cache mirrors the structure with the same leading dim."""
    periods = jax.tree.leaves(params["slot0"])[0].shape[0]
    keys = (
        jax.random.split(rng, periods * len(slots)).reshape(periods, len(slots), -1)
        if rng is not None
        else jnp.zeros((periods, len(slots), 2), jnp.uint32)
    )

    def body(carry, xs):
        h, aux_acc = carry
        layer_params, layer_cache, layer_keys = xs
        new_caches = {}
        for i, spec in enumerate(slots):
            sk = f"slot{i}"
            ck = layer_cache.get(sk) if layer_cache else None
            k_i = layer_keys[i] if rng is not None else None
            h, nc, aux = block_apply(
                cfg, plan, spec, layer_params[sk], h, positions, k_i, train,
                ck, cache_view, cross_ctx, use_kernel, return_cache,
            )
            if nc is not None:
                new_caches[sk] = nc
            for k in AUX_KEYS:
                if k in aux:
                    aux_acc = {**aux_acc, k: aux_acc[k] + aux[k]}
        return (h, aux_acc), (new_caches or None)

    if cfg.remat != "none" and train:
        body = jax.checkpoint(body, prevent_cse=False)

    aux0 = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    (x, aux), new_cache = jax.lax.scan(body, (x, aux0), (params, cache, keys))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Cache declarations
# ---------------------------------------------------------------------------


def block_cache_decl(
    cfg: ModelConfig, spec: BlockSpec, batch: int, cache_len: int, enc_len: int = 0
) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    dt = jnp.dtype(cfg.dtype)
    if spec.mixer == "ssm":
        out["ssm"] = ssm_cache_decl(cfg, batch)
    elif cfg.use_mla:
        m = cfg.mla
        out["attn"] = {
            "ckv": ParamDecl(
                (batch, cache_len, m.kv_lora_rank), ("batch", "cache_seq", None), "zeros", dt
            ),
            "krope": ParamDecl(
                (batch, cache_len, m.qk_rope_head_dim), ("batch", "cache_seq", None), "zeros", dt
            ),
        }
    else:
        kv, hd = cfg.num_kv_heads, cfg.head_dim_
        out["attn"] = {
            "k": ParamDecl(
                (batch, cache_len, kv, hd), ("batch", "cache_seq", None, None), "zeros", dt
            ),
            "v": ParamDecl(
                (batch, cache_len, kv, hd), ("batch", "cache_seq", None, None), "zeros", dt
            ),
        }
    if spec.cross_attn:
        kv, hd = cfg.num_kv_heads, cfg.head_dim_
        out["cross"] = {
            "k": ParamDecl(
                (batch, enc_len, kv, hd), ("batch", None, "kv_heads", None), "zeros", dt
            ),
            "v": ParamDecl(
                (batch, enc_len, kv, hd), ("batch", None, "kv_heads", None), "zeros", dt
            ),
        }
    return out


def stack_cache_decl(
    cfg: ModelConfig,
    slots: List[BlockSpec],
    periods: int,
    batch: int,
    cache_len: int,
    enc_len: int = 0,
) -> Dict[str, Any]:
    def stack(d: ParamDecl) -> ParamDecl:
        return ParamDecl((periods,) + d.shape, ("layers",) + d.axes, d.init, d.dtype)

    out = {}
    for i, s in enumerate(slots):
        c = block_cache_decl(cfg, s, batch, cache_len, enc_len)
        out[f"slot{i}"] = jax.tree.map(
            stack, c, is_leaf=lambda d: isinstance(d, ParamDecl)
        )
    return out
