"""Top-level language model: embeddings + stack(s) + head, with three entry
points used by the launcher and dry-run:

* ``forward``     — training/prefill forward over full sequences.
* ``loss_fn``     — CE over the (padded, vocab-sharded) logits + MoE aux.
* ``decode_step`` — one new token against the ring KV/SSM cache (serve_step).
* ``paged_forward`` / ``decode_step_paged`` — chunked prefill and decode
  against the block-table page pool (serving cache_mode="paged").

Multimodal stubs (DESIGN.md carve-out): ``vlm`` consumes a precomputed patch
-embedding prefix; ``encdec`` (audio) consumes precomputed frame embeddings
on the encoder side. Both are supplied by ``input_specs`` as arrays of the
right shape — the backbone is fully implemented, the frontend is not.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import (
    cross_entropy,
    embed_apply,
    embed_decl,
    norm_apply,
    norm_decl,
    unembed_apply,
)
from repro.models.transformer import (
    BlockSpec,
    build_slots,
    periods_for,
    stack_apply,
    stack_cache_decl,
    stack_decl,
)
from repro.sharding.rules import FoldingPlan, ParamDecl


def model_decl(cfg: ModelConfig) -> Dict[str, Any]:
    slots = build_slots(cfg)
    periods = periods_for(cfg, slots)
    decls: Dict[str, Any] = {
        "embed": embed_decl(cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings),
        "stack": stack_decl(cfg, slots, periods),
        "final_norm": norm_decl(cfg.d_model, cfg.norm_type),
    }
    if cfg.family == "encdec":
        enc_slots = [BlockSpec("attn", "dense", causal=False)]
        assert cfg.num_encoder_layers > 0
        decls["encoder"] = stack_decl(cfg, enc_slots, cfg.num_encoder_layers)
        decls["encoder_norm"] = norm_decl(cfg.d_model, cfg.norm_type)
    if cfg.quant_weights == "int8":
        # serving-side int8 expert weights: expert decls become int8 and
        # gain bf16 per-output-channel scale decls that keep the leading
        # ("expert", ...) axis, so EP sharding splits scales with experts
        from repro.core.quant import quantize_decls

        decls = quantize_decls(decls)
    return decls


def _encode(cfg, plan, params, frames: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Run the (non-causal) encoder over stub frame embeddings (B,Se,D)."""
    B, Se, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    enc_slots = [BlockSpec("attn", "dense", causal=False)]
    x = frames.astype(jnp.dtype(cfg.dtype))
    if plan is not None:
        x = plan.constrain(x, "batch", None, None)
    x, _, _ = stack_apply(cfg, plan, enc_slots, params["encoder"], x, pos)
    x = norm_apply(params["encoder_norm"], x, cfg.norm_type, cfg.norm_eps)
    return x, pos


def forward(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    params,
    batch: Dict[str, jax.Array],
    rng: Optional[jax.Array] = None,
    train: bool = False,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (logits over text positions, aux). batch keys:
    tokens (B,St); vlm: + embeds (B,P,D); encdec: + frames (B,Se,D)."""
    tokens = batch["tokens"]
    B, St = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    x = embed_apply(params["embed"], tokens, dtype)
    prefix = 0
    cross_ctx = None
    if cfg.family == "vlm":
        emb = batch["embeds"].astype(x.dtype)
        prefix = emb.shape[1]
        x = jnp.concatenate([emb, x], axis=1)
    elif cfg.family == "encdec":
        cross_ctx = _encode(cfg, plan, params, batch["frames"])
    S = x.shape[1]
    if plan is not None:
        x = plan.constrain(x, "fold_batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    slots = build_slots(cfg)
    x, _, aux = stack_apply(
        cfg, plan, slots, params["stack"], x, positions, rng, train,
        cross_ctx=cross_ctx, use_kernel=use_kernel,
    )
    x = norm_apply(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    if prefix:
        x = x[:, prefix:]
    logits = unembed_apply(params["embed"] if cfg.tie_embeddings else params["embed"], x)
    if plan is not None:
        logits = plan.constrain(logits, "fold_batch", None, "vocab")
    return logits, aux


def loss_fn(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    params,
    batch: Dict[str, jax.Array],
    rng: Optional[jax.Array] = None,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(cfg, plan, params, batch, rng, train=True, use_kernel=use_kernel)
    ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    loss = ce + sum(aux.values())
    metrics = {"loss": loss, "ce": ce, **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def cache_decl(
    cfg: ModelConfig, batch: int, cache_len: int, enc_len: int = 0
) -> Dict[str, Any]:
    """Cache structure for decode. cache_len = min(seq_len, sliding_window)."""
    if cfg.sliding_window is not None:
        cache_len = min(cache_len, cfg.sliding_window)
    slots = build_slots(cfg)
    periods = periods_for(cfg, slots)
    decls: Dict[str, Any] = {
        "pos": ParamDecl((batch,), ("batch",), "zeros", jnp.int32),
        "slot_pos": ParamDecl((batch, cache_len), ("batch", "cache_seq"), "zeros", jnp.int32),
        "stack": stack_cache_decl(cfg, slots, periods, batch, cache_len, enc_len),
    }
    return decls


def decode_step(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    params,
    cache: Dict[str, Any],
    tokens: jax.Array,  # (B,) next input token ids
    use_kernel: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step: writes token at cache position, returns fp32 logits
    (B, padded_vocab) for the next token and the updated cache."""
    B = tokens.shape[0]
    pos = cache["pos"]  # (B,)
    W = cache["slot_pos"].shape[1]
    slot = (pos % W).astype(jnp.int32)
    slot_pos = cache["slot_pos"].at[jnp.arange(B), slot].set(pos)
    # unfilled slots must stay invalid: init slot_pos to -1 via pos==0 reset
    slot_pos = jnp.where(
        (cache["pos"][:, None] == 0)
        & (jnp.arange(W)[None, :] != slot[:, None]),
        -1,
        slot_pos,
    )
    cache_view = {"slot": slot, "slot_pos": slot_pos}
    if cfg.family == "encdec":
        enc_len = jax.tree.leaves(cache["stack"]["slot0"]["cross"])[0].shape[2]
        cache_view["enc_pos"] = jnp.broadcast_to(
            jnp.arange(enc_len, dtype=jnp.int32), (B, enc_len)
        )

    x = embed_apply(params["embed"], tokens[:, None], jnp.dtype(cfg.dtype))  # (B,1,D)
    if plan is not None:
        x = plan.constrain(x, "batch", None, None)
    positions = pos[:, None]

    slots = build_slots(cfg)
    x, new_stack, _ = stack_apply(
        cfg, plan, slots, params["stack"], x, positions,
        cache=cache["stack"], cache_view=cache_view, use_kernel=use_kernel,
    )
    x = norm_apply(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    logits = unembed_apply(params["embed"], x)[:, 0]
    if plan is not None:
        logits = plan.constrain(logits, "batch", "vocab")
    new_cache = {"pos": pos + 1, "slot_pos": slot_pos, "stack": new_stack}
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged-KV decode / chunked prefill (serving/kv_cache.py drives these)
# ---------------------------------------------------------------------------


def paged_stack_decl(cfg: ModelConfig, num_pages: int, page_size: int) -> Dict[str, Any]:
    """KV page-pool declarations: per layer-slot ``(P, num_pages, page_size,
    KV, hd)`` k/v pools shared by every sequence. By convention the LAST
    page (index ``num_pages - 1``) is the trash page — padded positions
    scatter there and it never appears in a block table; callers allocating
    N usable pages must decl N + 1. Under EP x DP serving the pool is a
    concatenation of per-DP-shard strides, each ending in its own trash
    page (``serving.kv_cache.PagePool`` owns that layout; rows then pass a
    per-row ``trash_page`` to :func:`paged_forward` so idle writes stay in
    their shard's stride).

    Paged mode covers GQA attention stacks only (dense / moe / vlm-as-text
    families); MLA, SSM and cross-attention configs keep the ring cache.

    ``cfg.quant_kv == "int8"`` switches the k/v payload to int8 and adds
    per-token, per-kv-head f32 scale sidecar leaves (``k_scale``/
    ``v_scale``, head dim collapsed to 1) to the same pool subtree. The
    sidecars keep the page axis at 1 and the page_size axis at 2, so every
    pool-tree operation (``copy_pages`` COW, ``permute_pool`` defrag,
    ``pool_sharding`` DP split, the first-leaf shape introspection below)
    moves scales with their pages structurally."""
    slots = build_slots(cfg)
    periods = periods_for(cfg, slots)
    assert not cfg.use_mla and all(
        s.mixer == "attn" and not s.cross_attn for s in slots
    ), "paged KV cache supports GQA attention stacks only"
    kv, hd = cfg.num_kv_heads, cfg.head_dim_
    quant = getattr(cfg, "quant_kv", "none") == "int8"
    dt = jnp.dtype(jnp.int8) if quant else jnp.dtype(cfg.dtype)

    def pool():
        kv_decl = lambda: ParamDecl(
            (periods, num_pages, page_size, kv, hd),
            ("layers", None, None, None, None), "zeros", dt,
        )
        attn = {"k": kv_decl(), "v": kv_decl()}
        if quant:
            scale_decl = lambda: ParamDecl(
                (periods, num_pages, page_size, kv, 1),
                ("layers", None, None, None, None), "zeros", jnp.float32,
            )
            attn["k_scale"] = scale_decl()
            attn["v_scale"] = scale_decl()
        return {"attn": attn}

    return {"stack": {f"slot{i}": pool() for i in range(len(slots))}}


def paged_forward(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    params,
    pool: Dict[str, Any],
    tokens: jax.Array,  # (B, S) chunk of token ids (right-padded per bucket)
    pos_start: jax.Array,  # (B,) absolute position of tokens[:, 0]
    page_table: jax.Array,  # (B, max_pages) int32 page ids, -1 = unassigned
    valid_len: jax.Array,  # (B,) real tokens in this chunk (0 = idle slot)
    use_kernel: bool = False,
    trash_page: Optional[jax.Array] = None,  # (B,) per-row trash page id
    return_all_logits: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One forward over the page-table cache view: S > 1 is a prefill chunk
    (attends to previously-written pages + the chunk itself, causally),
    S == 1 is single-token decode. Logical KV slot ``j`` of sequence ``b``
    lives at ``pool[page_table[b, j // ps], j % ps]`` — the identity
    position mapping (pages never wrap, unlike the ring cache).

    Writes for padded / idle positions are routed to the trash page, so the
    compiled step is shared across every request in a length bucket.
    ``trash_page`` overrides the default last-page convention per row: the
    EP x DP engine passes each batch row its DP shard's own trash page so
    idle writes never cross the shard's stride of the page axis.
    Returns (fp32 logits (B, padded_vocab) at each row's last valid
    position, updated pool).

    ``return_all_logits=True`` unembeds every chunk position instead —
    logits (B, S, padded_vocab) — which is what the speculative-decoding
    verify step needs: position j's logits give the target model's next
    token after draft token j, so one chunk scores k drafts at once
    (positions >= valid_len are pad garbage; callers mask by length)."""
    B, S = tokens.shape
    leaf = jax.tree.leaves(pool["stack"])[0]  # (P, num_pages, ps, KV, hd)
    num_pages, ps = leaf.shape[1], leaf.shape[2]
    maxP = page_table.shape[1]
    trash = (
        jnp.full((B, 1), num_pages - 1, jnp.int32)
        if trash_page is None else trash_page.astype(jnp.int32)[:, None]
    )

    positions = pos_start[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    pvalid = jnp.arange(S, dtype=jnp.int32)[None, :] < valid_len[:, None]
    wp = jnp.take_along_axis(page_table, positions // ps, axis=1)  # (B, S)
    wp = jnp.where(pvalid & (wp >= 0), wp, trash)
    wo = positions % ps
    seq_lens = pos_start + valid_len
    kpos = jnp.arange(maxP * ps, dtype=jnp.int32)
    k_pos = jnp.where(
        (kpos[None, :] < seq_lens[:, None]) & (page_table[:, kpos // ps] >= 0),
        kpos[None, :], -1,
    )
    cache_view = {
        "page_table": page_table, "k_pos": k_pos,
        "write_page": wp, "write_offset": wo, "seq_lens": seq_lens,
    }

    x = embed_apply(params["embed"], tokens, jnp.dtype(cfg.dtype))
    if plan is not None:
        x = plan.constrain(x, "batch", None, None)
    slots = build_slots(cfg)
    x, new_stack, _ = stack_apply(
        cfg, plan, slots, params["stack"], x, positions,
        cache=pool["stack"], cache_view=cache_view, use_kernel=use_kernel,
    )
    x = norm_apply(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    if return_all_logits:
        logits = unembed_apply(params["embed"], x)  # (B, S, V)
        if plan is not None:
            logits = plan.constrain(logits, "batch", None, "vocab")
        return logits, {"stack": new_stack}
    last = jnp.maximum(valid_len - 1, 0)
    xl = x[jnp.arange(B), last][:, None]  # (B, 1, D)
    logits = unembed_apply(params["embed"], xl)[:, 0]
    if plan is not None:
        logits = plan.constrain(logits, "batch", "vocab")
    return logits, {"stack": new_stack}


def decode_step_paged(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    params,
    pool: Dict[str, Any],
    tokens: jax.Array,  # (B,) next input token ids
    pos: jax.Array,  # (B,) absolute position to write
    page_table: jax.Array,  # (B, max_pages)
    active: jax.Array,  # (B,) 1 for live slots, 0 for idle
    use_kernel: bool = False,
    trash_page: Optional[jax.Array] = None,  # (B,) per-row trash page id
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Single-token paged decode: ``paged_forward`` with a length-1 chunk.
    Idle slots write to the trash page and emit garbage logits (ignored by
    the engine)."""
    return paged_forward(
        cfg, plan, params, pool, tokens[:, None], pos, page_table,
        active.astype(jnp.int32), use_kernel=use_kernel,
        trash_page=trash_page,
    )


def prefill_forward(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    params,
    batch: Dict[str, jax.Array],
    cache_len: Optional[int] = None,
    use_kernel: bool = False,
    valid_len: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Fused prefill: one full-sequence forward that also emits a decode-
    ready cache (prefill_32k lowers this). For sliding-window configs the
    last W keys are ring-packed into their slots.

    ``valid_len`` (B,) enables length-bucketed prefill: tokens are
    right-padded to a shared bucket shape, logits are taken at each row's
    last *valid* position, and the pad slots are marked invalid in
    ``slot_pos`` (decode then overwrites them in order). Callers must keep
    the padded length <= the ring size so padding never wraps over valid
    entries."""
    tokens = batch["tokens"]
    B, St = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    x = embed_apply(params["embed"], tokens, dtype)
    prefix = 0
    cross_ctx = None
    if cfg.family == "vlm":
        emb = batch["embeds"].astype(x.dtype)
        prefix = emb.shape[1]
        x = jnp.concatenate([emb, x], axis=1)
    elif cfg.family == "encdec":
        cross_ctx = _encode(cfg, plan, params, batch["frames"])
    S = x.shape[1]
    if plan is not None:
        x = plan.constrain(x, "fold_batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    slots = build_slots(cfg)
    x, seq_cache, _ = stack_apply(
        cfg, plan, slots, params["stack"], x, positions,
        cross_ctx=cross_ctx, use_kernel=use_kernel, return_cache=True,
    )
    x = norm_apply(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    if valid_len is None:
        total = jnp.full((B,), S, jnp.int32)
        xl = x[:, -1:]
    else:
        total = prefix + valid_len.astype(jnp.int32)
        xl = x[jnp.arange(B), total - 1][:, None]
    logits = unembed_apply(params["embed"], xl)[:, 0]

    # ---- pack the per-layer seq caches into the ring-buffer layout -------
    W = cache_len or S
    if cfg.sliding_window is not None:
        W = min(W, cfg.sliding_window)
    Wc = min(W, S)
    ring_slots = (S - Wc + jnp.arange(Wc)) % W  # where the last Wc keys go

    def pack(full):  # full: (P, B, S, ...) stacked seq cache
        buf = jnp.zeros(full.shape[:2] + (W,) + full.shape[3:], full.dtype)
        return buf.at[:, :, ring_slots].set(full[:, :, S - Wc :])

    def pack_tree(c):
        out = {}
        for k, v in c.items():
            if k == "ssm":
                out[k] = v  # state caches carry no seq dim
            elif k == "cross":
                out[k] = v
            else:
                out[k] = jax.tree.map(pack, v)
        return out

    stack_cache = {sk: pack_tree(c) for sk, c in (seq_cache or {}).items()}
    if cfg.family == "encdec":
        enc_out, _ = cross_ctx
        for i in range(len(slots)):
            sk = f"slot{i}"
            ck = jnp.einsum("bsd,pdhk->pbshk", enc_out, params["stack"][sk]["cross"]["wk"])
            cv = jnp.einsum("bsd,pdhk->pbshk", enc_out, params["stack"][sk]["cross"]["wv"])
            stack_cache[sk]["cross"] = {"k": ck, "v": cv}
    slot_pos = jnp.full((B, W), -1, jnp.int32)
    slot_pos = slot_pos.at[:, ring_slots].set(
        jnp.broadcast_to(jnp.arange(S - Wc, S, dtype=jnp.int32), (B, Wc))
    )
    if valid_len is not None:
        # pad slots stay invalid; decode overwrites them position-in-order
        slot_pos = jnp.where(slot_pos >= total[:, None], -1, slot_pos)
    cache = {
        "pos": total,
        "slot_pos": slot_pos,
        "stack": stack_cache,
    }
    return logits, cache


def prefill_reference(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    params,
    batch: Dict[str, jax.Array],
    cache_len: Optional[int] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Fill a decode cache by running decode_step over the prompt via scan.
    Oracle for prefill_forward in tests."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = cache_len or S
    from repro.sharding.rules import init_from_decls

    decls = cache_decl(cfg, B, cache_len, enc_len=batch.get("frames", jnp.zeros((B, 0, cfg.d_model))).shape[1])
    cache = jax.tree.map(
        lambda d: jnp.zeros(d.shape, d.dtype),
        decls,
        is_leaf=lambda d: isinstance(d, ParamDecl),
    )
    # slot_pos starts invalid
    cache["slot_pos"] = jnp.full_like(cache["slot_pos"], -1)
    if cfg.family == "encdec":
        enc_out, _ = _encode(cfg, plan, params, batch["frames"])
        new_cross = {}
        slots = build_slots(cfg)
        periods = periods_for(cfg, slots)
        for i in range(len(slots)):
            sk = f"slot{i}"
            wk = params["stack"][sk]["cross"]["wk"]
            wv = params["stack"][sk]["cross"]["wv"]
            ck = jnp.einsum("bsd,pdhk->pbshk", enc_out, wk)
            cv = jnp.einsum("bsd,pdhk->pbshk", enc_out, wv)
            cache["stack"][sk]["cross"] = {"k": ck, "v": cv}

    def step(cache, tok):
        logits, cache = decode_step(cfg, plan, params, cache, tok)
        return cache, logits

    cache, logits = jax.lax.scan(step, cache, tokens.T)
    return logits[-1], cache
