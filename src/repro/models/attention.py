"""Attention variants: GQA (llama/qwen/stablelm/jamba), MLA (minicpm3),
sliding-window, and decode against a ring-buffer OR paged (block-table)
KV cache.

Two compute paths:

* ``direct`` — materializes the score matrix; used for short sequences and
  single-token decode.
* ``blockwise`` — lax.scan over KV blocks with online softmax (flash-style in
  pure jnp). This is the XLA path that keeps prefill_32k / train_4k peak
  memory bounded; the Pallas ``flash_attention`` kernel (kernels/) is the
  TPU-optimized version of the same schedule and is validated against it.

Sharding: in ``tp`` mode heads shard the 'model' axis; in ``cp`` mode (head
count not divisible by the axis — see FoldingPlan) the *sequence* dim of the
attention activations shards the 'model' axis instead, the TPU analogue of
Megatron context parallelism. Decode shards the KV-cache sequence axis.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import norm_apply, norm_decl, rope_apply
from repro.sharding.rules import FoldingPlan, ParamDecl

NEG_INF = -1e30
# §Perf Q2: 2048 (was 8192) — at train_4k the direct path materializes
# (B,KV,G,S,S) fp32 score chains through softmax fwd+bwd (~2 TB/step for
# qwen3); the blockwise online-softmax keeps them fusion-local.
_BLOCKWISE_MIN_SEQ = 2048
_KV_BLOCK = 1024


# ---------------------------------------------------------------------------
# Core attention math (shared by GQA and MLA-train)
# ---------------------------------------------------------------------------


def _mask(
    q_pos: jax.Array, k_pos: jax.Array, window: Optional[int], causal: bool = True
) -> jax.Array:
    """(B,Sq,Sk) validity mask: causal, windowed, and slot-valid (k_pos>=0)."""
    q = q_pos[:, :, None].astype(jnp.int32)
    k = k_pos[:, None, :].astype(jnp.int32)
    m = k >= 0
    if causal:
        m &= k <= q
    if window is not None:
        m &= k > q - window
    return m


def attention_core(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    causal: bool = True,
    use_kernel: bool = False,
) -> jax.Array:
    """q: (B,Sq,H,dk) k: (B,Sk,KV,dk) v: (B,Sk,KV,dv); H % KV == 0.
    q_pos: (B,Sq), k_pos: (B,Sk). Returns (B,Sq,H,dv).

    ``use_kernel=True`` routes training/prefill shapes to the Pallas flash
    kernel (kernels/flash_attention.py), which is differentiable via its
    custom_vjp — the kernel assumes the contiguous right-aligned positions
    every full-sequence caller passes, so decode (ring-buffer ``k_pos``)
    and mismatched head dims fall back to the XLA paths below."""
    B, Sq, H, dk = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    dv = v.shape[-1]
    scale = scale if scale is not None else dk**-0.5
    if use_kernel and Sq == Sk and Sq > 8 and dk == dv:
        from repro.kernels.ops import flash_attention

        return flash_attention(
            q, k, v, causal=causal, window=window, scale=float(scale)
        ).astype(v.dtype)
    qg = q.reshape(B, Sq, KV, G, dk)

    # Decode (Sq small): the direct path keeps the KV cache's sequence
    # sharding intact — scores (B,KV,G,Sq,Sk) shard over Sk and the softmax
    # reduces via tiny stat all-reduces. The blockwise reshape would break
    # the Sk sharding and all-gather the entire cache every layer (§Perf D1).
    if Sq <= 8 or Sk <= _BLOCKWISE_MIN_SEQ or Sk % _KV_BLOCK != 0:
        scores = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32
        ) * scale
        mask = _mask(q_pos, k_pos, window, causal)[:, None, None]
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum(
            "bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
        return out.reshape(B, Sq, H, dv).astype(v.dtype)

    # ---- blockwise online-softmax path (flash-style, memory bounded) ----
    out = _blockwise_attention(
        qg, k, v, q_pos, k_pos,
        window if window is not None else -1, scale, causal,
    )
    return out.reshape(B, Sq, H, dv).astype(v.dtype)


def _bw_forward(qg, k, v, q_pos, k_pos, window: int, scale: float, causal: bool):
    """Online-softmax forward over KV blocks. qg: (B,Sq,KV,G,dk).
    Returns (out fp32 (B,Sq,KV,G,dv), m, l)."""
    B, Sq, KV, G, dk = qg.shape
    Sk, dv = k.shape[1], v.shape[-1]
    nb = Sk // _KV_BLOCK
    k_b = k.reshape(B, nb, _KV_BLOCK, KV, dk).transpose(1, 0, 2, 3, 4)
    v_b = v.reshape(B, nb, _KV_BLOCK, KV, dv).transpose(1, 0, 2, 3, 4)
    kp_b = k_pos.reshape(B, nb, _KV_BLOCK).transpose(1, 0, 2)

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, dv), jnp.float32)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, kpb = xs
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, kb, preferred_element_type=jnp.float32
        ) * scale
        msk = _mask(q_pos, kpb, None if window < 0 else window, causal)[:, None, None]
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bkgqs,bskd->bqkgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (k_b, v_b, kp_b))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _blockwise_attention(qg, k, v, q_pos, k_pos, window: int, scale: float, causal: bool):
    out, _, _ = _bw_forward(qg, k, v, q_pos, k_pos, window, scale, causal)
    return out


def _bw_fwd(qg, k, v, q_pos, k_pos, window, scale, causal):
    out, m, l = _bw_forward(qg, k, v, q_pos, k_pos, window, scale, causal)
    return out, (qg, k, v, q_pos, k_pos, out, m, l)


def _bw_bwd(window, scale, causal, res, dout):
    """Flash-attention backward (§Perf Q3): recompute per-block
    probabilities from the saved (m, l) softmax stats — autodiff through the
    fwd scan would instead SAVE every (B,KV,G,Sq,block) probability tensor,
    forfeiting the whole memory win of the online softmax."""
    qg, k, v, q_pos, k_pos, out, m, l = res
    B, Sq, KV, G, dk = qg.shape
    Sk, dv = k.shape[1], v.shape[-1]
    nb = Sk // _KV_BLOCK
    k_b = k.reshape(B, nb, _KV_BLOCK, KV, dk).transpose(1, 0, 2, 3, 4)
    v_b = v.reshape(B, nb, _KV_BLOCK, KV, dv).transpose(1, 0, 2, 3, 4)
    kp_b = k_pos.reshape(B, nb, _KV_BLOCK).transpose(1, 0, 2)

    dout = dout.astype(jnp.float32)
    # delta[b,k,g,q] = sum_d dout * out  (the softmax Jacobian diagonal term)
    delta = jnp.einsum("bqkgd,bqkgd->bkgq", dout, out)

    def step(dq_acc, xs):
        kb, vb, kpb = xs
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, kb, preferred_element_type=jnp.float32
        ) * scale
        msk = _mask(q_pos, kpb, None if window < 0 else window, causal)[:, None, None]
        s = jnp.where(msk, s, NEG_INF)
        prob = jnp.exp(s - m[..., None]) / l[..., None]  # (B,KV,G,Sq,bk)
        dv_b = jnp.einsum(
            "bkgqs,bqkgd->bskd", prob, dout, preferred_element_type=jnp.float32
        )
        dp = jnp.einsum(
            "bqkgd,bskd->bkgqs", dout, vb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        ds = prob * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum(
            "bkgqs,bskd->bqkgd", ds, kb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        dk_b = jnp.einsum(
            "bkgqs,bqkgd->bskd", ds, qg.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return dq_acc, (dk_b, dv_b)

    dq0 = jnp.zeros((B, Sq, KV, G, dk), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(step, dq0, (k_b, v_b, kp_b))
    dk = dk_b.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KV, dk).astype(k.dtype)
    dvv = dv_b.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KV, dv).astype(v.dtype)
    return dq.astype(qg.dtype), dk, dvv, None, None


_blockwise_attention.defvjp(_bw_fwd, _bw_bwd)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def gqa_decl(cfg: ModelConfig) -> Dict[str, Any]:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    dt = jnp.bfloat16
    decls: Dict[str, Any] = {
        "wq": ParamDecl((D, H, hd), ("embed", "heads", "head_dim"), "fan_in", dt),
        "wk": ParamDecl((D, KV, hd), ("embed", "kv_heads", "head_dim"), "fan_in", dt),
        "wv": ParamDecl((D, KV, hd), ("embed", "kv_heads", "head_dim"), "fan_in", dt),
        "wo": ParamDecl((H, hd, D), ("heads", "head_dim", "embed"), "fan_in", dt),
    }
    if cfg.qkv_bias:
        decls["bq"] = ParamDecl((H, hd), ("heads", "head_dim"), "zeros", dt)
        decls["bk"] = ParamDecl((KV, hd), ("kv_heads", "head_dim"), "zeros", dt)
        decls["bv"] = ParamDecl((KV, hd), ("kv_heads", "head_dim"), "zeros", dt)
    return decls


def _constrain_qkv(plan: Optional[FoldingPlan], t: jax.Array, kind: str, decode: bool):
    if plan is None:
        return t
    if decode or plan.attn_mode == "tp":
        return plan.constrain(t, "fold_batch", None, kind, None)
    return plan.constrain(t, "fold_batch", "attn_seq", None, None)  # cp mode


def gqa_apply(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    params,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_view: Optional[Dict[str, jax.Array]] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    causal: bool = True,
    return_kv: bool = False,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B,S,D). ``cache``/``cache_view`` set => decode. Two cache views:
    ring (``slot``/``slot_pos``, S == 1) and paged (``page_table`` et al.,
    S >= 1 so chunked prefill shares the path — see model.paged_forward).
    ``cross_kv`` = (k, v, k_pos) precomputed encoder memory (cross-attn).
    Returns (out, updated_cache_layer)."""
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]

    if cross_kv is not None:
        k, v, k_pos = cross_kv
        out = attention_core(q, k, v, positions, k_pos, None, causal=False)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), None

    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    q = rope_apply(q, positions, cfg.rope_theta)
    k = rope_apply(k, positions, cfg.rope_theta)

    decode = cache is not None
    q = _constrain_qkv(plan, q, "heads", decode)
    k = _constrain_qkv(plan, k, "kv_heads", decode)
    v = _constrain_qkv(plan, v, "kv_heads", decode)

    if not decode:
        out = attention_core(
            q, k, v, positions, positions,
            cfg.sliding_window if causal else None, causal=causal,
            use_kernel=use_kernel,
        )
        if return_kv:
            cache = {"k": k, "v": v}
    elif cache_view is not None and "page_table" in cache_view:
        # ---- paged decode / chunked prefill against the shared page pool --
        # cache is the pool slice for this layer: (num_pages, ps, KV, hd).
        # cache_view: page_table (B, maxP); write_page/write_offset (B, S)
        # physical scatter targets (invalid positions -> the trash page);
        # k_pos (B, maxP*ps) logical slot validity; seq_lens (B,).
        # S covers decode (1), chunked prefill, AND the speculative-decode
        # verify step (S = draft_k + 1, per-row lengths via seq_lens): the
        # gather path below is length-generic, only the S==1 Pallas decode
        # kernel is specialized. Write discipline with a prefix cache: a
        # row's table may reference *shared* (refcounted) prefix pages, but
        # wp only ever targets pages past the row's prefilled boundary —
        # the scheduler COW-clones a shared page before any chunk can
        # scatter into it, so shared KV is read-only here by construction.
        wp, wo = cache_view["write_page"], cache_view["write_offset"]
        if "k_scale" in cache:
            # int8 pages: quantize on scatter (per-token, per-kv-head
            # symmetric scales — the granularity an incremental write can
            # commit without retouching the rest of the page) and store the
            # scale in the sidecar leaf at the same (page, offset). The COW
            # discipline above covers the sidecar too: it lives in the same
            # pool subtree, so a shared page's scales are cloned with it.
            from repro.core.quant import dequantize_kv, quantize_kv

            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            k_cache = cache["k"].at[wp, wo].set(kq)
            v_cache = cache["v"].at[wp, wo].set(vq)
            ks_cache = cache["k_scale"].at[wp, wo].set(ks)
            vs_cache = cache["v_scale"].at[wp, wo].set(vs)
            if use_kernel and S == 1:
                from repro.kernels.ops import paged_attention_q8

                out = paged_attention_q8(
                    q[:, 0], k_cache, v_cache, ks_cache, vs_cache,
                    cache_view["page_table"], cache_view["seq_lens"],
                    window=cfg.sliding_window,
                )[:, None]
            else:
                KVh, hd = k_cache.shape[2], k_cache.shape[3]
                bt = jnp.maximum(cache_view["page_table"], 0)
                kg = dequantize_kv(
                    k_cache[bt], ks_cache[bt], q.dtype
                ).reshape(B, -1, KVh, hd)
                vg = dequantize_kv(
                    v_cache[bt], vs_cache[bt], q.dtype
                ).reshape(B, -1, KVh, hd)
                out = attention_core(
                    q, kg, vg, positions, cache_view["k_pos"], cfg.sliding_window
                )
            cache = {"k": k_cache, "v": v_cache,
                     "k_scale": ks_cache, "v_scale": vs_cache}
            return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), cache
        k_cache = cache["k"].at[wp, wo].set(k)
        v_cache = cache["v"].at[wp, wo].set(v)
        if use_kernel and S == 1:
            from repro.kernels.ops import paged_attention

            out = paged_attention(
                q[:, 0], k_cache, v_cache, cache_view["page_table"],
                cache_view["seq_lens"], window=cfg.sliding_window,
            )[:, None]
        else:
            KVh, hd = k_cache.shape[2], k_cache.shape[3]
            bt = jnp.maximum(cache_view["page_table"], 0)
            kg = k_cache[bt].reshape(B, -1, KVh, hd)
            vg = v_cache[bt].reshape(B, -1, KVh, hd)
            out = attention_core(
                q, kg, vg, positions, cache_view["k_pos"], cfg.sliding_window
            )
        cache = {"k": k_cache, "v": v_cache}
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), cache
    else:
        assert S == 1 and cache_view is not None
        slot = cache_view["slot"]  # (B,) int32 — ring-buffer write index
        k_cache = jax.vmap(lambda c, s, u: jax.lax.dynamic_update_slice(c, u, (s, 0, 0)))(
            cache["k"], slot, k
        )
        v_cache = jax.vmap(lambda c, s, u: jax.lax.dynamic_update_slice(c, u, (s, 0, 0)))(
            cache["v"], slot, v
        )
        if plan is not None:
            k_cache = plan.constrain(k_cache, "batch", "cache_seq", None, None)
            v_cache = plan.constrain(v_cache, "batch", "cache_seq", None, None)
        out = attention_core(
            q, k_cache, v_cache, positions, cache_view["slot_pos"],
            cfg.sliding_window,
        )
        cache = {"k": k_cache, "v": v_cache}
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, cache


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention) — MiniCPM3 / DeepSeek-V2 style
# ---------------------------------------------------------------------------


def mla_decl(cfg: ModelConfig) -> Dict[str, Any]:
    m = cfg.mla
    assert m is not None
    D, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    dt = jnp.bfloat16
    return {
        "wq_a": ParamDecl((D, m.q_lora_rank), ("embed", "lora"), "fan_in", dt),
        "q_norm": norm_decl(m.q_lora_rank),
        "wq_b": ParamDecl((m.q_lora_rank, H, qk), ("lora", "heads", "head_dim"), "fan_in", dt),
        "wkv_a": ParamDecl(
            (D, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "lora"), "fan_in", dt
        ),
        "kv_norm": norm_decl(m.kv_lora_rank),
        "wkv_b": ParamDecl(
            (m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
            ("lora", "heads", "head_dim"),
            "fan_in",
            dt,
        ),
        "wo": ParamDecl((H, m.v_head_dim, D), ("heads", "head_dim", "embed"), "fan_in", dt),
    }


def mla_apply(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    params,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_view: Optional[Dict[str, jax.Array]] = None,
    return_kv: bool = False,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    m = cfg.mla
    assert m is not None
    B, S, D = x.shape
    H, nope, rope_d = cfg.num_heads, m.qk_nope_head_dim, m.qk_rope_head_dim
    scale = (nope + rope_d) ** -0.5

    q_lat = norm_apply(params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["wq_a"]))
    q = jnp.einsum("bsr,rhk->bshk", q_lat, params["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope_apply(q_rope, positions, cfg.rope_theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    ckv = norm_apply(params["kv_norm"], ckv_full[..., : m.kv_lora_rank])
    k_rope = rope_apply(
        ckv_full[..., m.kv_lora_rank :][:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]

    if cache is None:
        # training/prefill: expand the latent to per-head K/V (non-absorbed)
        kv = jnp.einsum("bsr,rhk->bshk", ckv, params["wkv_b"])
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope_d))], -1
        )
        qf = jnp.concatenate([q_nope, q_rope], -1)
        if plan is not None:
            mode = "attn_seq" if plan.attn_mode == "cp" else None
            if mode:
                qf = plan.constrain(qf, "fold_batch", "attn_seq", None, None)
                k = plan.constrain(k, "fold_batch", "attn_seq", None, None)
                v = plan.constrain(v, "fold_batch", "attn_seq", None, None)
        out = attention_core(qf, k, v, positions, positions, cfg.sliding_window,
                             scale, use_kernel=use_kernel)
        out = jnp.einsum("bshv,hvd->bsd", out, params["wo"])
        return out, ({"ckv": ckv, "krope": k_rope} if return_kv else None)

    # ---- absorbed decode: attend in the compressed latent space ----------
    assert S == 1 and cache_view is not None
    slot = cache_view["slot"]
    ckv_cache = jax.vmap(lambda c, s, u: jax.lax.dynamic_update_slice(c, u, (s, 0)))(
        cache["ckv"], slot, ckv
    )
    krope_cache = jax.vmap(lambda c, s, u: jax.lax.dynamic_update_slice(c, u, (s, 0)))(
        cache["krope"], slot, k_rope
    )
    if plan is not None:
        ckv_cache = plan.constrain(ckv_cache, "batch", "cache_seq", None)
        krope_cache = plan.constrain(krope_cache, "batch", "cache_seq", None)

    w_uk = params["wkv_b"][..., :nope]  # (r, H, nope)
    w_uv = params["wkv_b"][..., nope:]  # (r, H, v_dim)
    q_lat_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
    scores = (
        jnp.einsum("bqhr,bsr->bhqs", q_lat_abs, ckv_cache, preferred_element_type=jnp.float32)
        + jnp.einsum("bqhp,bsp->bhqs", q_rope, krope_cache, preferred_element_type=jnp.float32)
    ) * scale
    mask = _mask(positions, cache_view["slot_pos"], cfg.sliding_window)[:, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(ckv_cache.dtype)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", probs, ckv_cache)
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat, w_uv)
    out = jnp.einsum("bshv,hvd->bsd", o, params["wo"])
    return out, {"ckv": ckv_cache, "krope": krope_cache}


def attention_decl(cfg: ModelConfig) -> Dict[str, Any]:
    return mla_decl(cfg) if cfg.use_mla else gqa_decl(cfg)


def attention_apply(cfg, plan, params, x, positions, cache=None, cache_view=None,
                    return_kv=False, use_kernel=False):
    if cfg.use_mla:
        return mla_apply(cfg, plan, params, x, positions, cache, cache_view,
                         return_kv, use_kernel=use_kernel)
    return gqa_apply(cfg, plan, params, x, positions, cache, cache_view,
                     return_kv=return_kv, use_kernel=use_kernel)
