"""Shared transformer building blocks: norms, RoPE, embeddings, SwiGLU MLP.

Functional style: every component has ``<name>_decl`` returning a pytree of
:class:`ParamDecl` (shape + logical sharding axes + init) and a pure
``<name>_apply``. All matmul compute runs in the model dtype (bf16 by
default); norms, softmax and the loss accumulate in fp32, matching the
paper's bf16 + Megatron-default numerics.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import ParamDecl

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_decl(d_model: int, norm_type: str = "rmsnorm") -> Dict[str, ParamDecl]:
    decls = {"scale": ParamDecl((d_model,), ("embed",), "ones", jnp.float32)}
    if norm_type == "layernorm":
        decls["bias"] = ParamDecl((d_model,), ("embed",), "zeros", jnp.float32)
    return decls


def norm_apply(params, x: jax.Array, norm_type: str = "rmsnorm", eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if norm_type == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"] + params["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_apply(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # positions broadcast: (..., seq) -> (..., seq, 1, half)
    angles = positions[..., None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------


def embed_decl(padded_vocab: int, d_model: int, tie: bool) -> Dict[str, ParamDecl]:
    decls = {
        "embedding": ParamDecl(
            (padded_vocab, d_model), ("vocab", "embed"), "normal:0.02", jnp.float32
        )
    }
    if not tie:
        decls["unembedding"] = ParamDecl(
            (padded_vocab, d_model), ("vocab", "embed"), "normal:0.02", jnp.float32
        )
    return decls


def embed_apply(params, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return params["embedding"].astype(dtype)[tokens]


def unembed_apply(params, x: jax.Array) -> jax.Array:
    """Returns fp32 logits over the padded vocab."""
    table = params.get("unembedding", params["embedding"])
    return jnp.einsum(
        "...d,vd->...v", x, table.astype(x.dtype), preferred_element_type=jnp.float32
    )


# ---------------------------------------------------------------------------
# SwiGLU MLP (the FFN the paper upcycles into experts)
# ---------------------------------------------------------------------------


def mlp_decl(d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Dict[str, ParamDecl]:
    return {
        "w_gate": ParamDecl((d_model, d_ff), ("embed", "ff"), "fan_in", dtype),
        "w_up": ParamDecl((d_model, d_ff), ("embed", "ff"), "fan_in", dtype),
        "w_down": ParamDecl((d_ff, d_model), ("ff", "embed"), "fan_in", dtype),
    }


def mlp_apply(params, x: jax.Array) -> jax.Array:
    gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("...f,fd->...d", hidden, params["w_down"])


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_size: int) -> jax.Array:
    """Mean CE over tokens; logits fp32 over the padded vocab. Padded vocab
    entries participate in the partition function (Megatron semantics) but
    never appear as labels."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + jnp.squeeze(
        jax.lax.stop_gradient(m), -1
    )
    label_logit = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - label_logit)
