"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: intra-chunk quadratic
(attention-like) term + inter-chunk linear recurrence carried by a
``lax.scan`` over chunks. Decode is the O(1)-state recurrent step — this is
what makes long_500k tractable for the SSM/hybrid architectures.

Sharding: d_inner ('ssm_inner') and SSD heads ('ssm_heads') shard the
'model' axis (Mamba-2 official TP); the small per-group B/C projections are
replicated.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import norm_apply, norm_decl
from repro.sharding.rules import FoldingPlan, ParamDecl


def ssm_decl(cfg: ModelConfig) -> Dict[str, Any]:
    s = cfg.ssm
    assert s is not None
    D = cfg.d_model
    di, nh, ng, dn = s.d_inner(D), s.nheads(D), s.ngroups, s.d_state
    conv_dim = di + 2 * ng * dn
    dt = jnp.bfloat16
    # softplus^-1(x) ~= log(x) for small x: dt in [1e-3, 1e-1]
    lo, hi = math.log(s.dt_min), math.log(s.dt_max)
    return {
        "in_proj_z": ParamDecl((D, di), ("embed", "ssm_inner"), "fan_in", dt),
        "in_proj_x": ParamDecl((D, conv_dim), ("embed", "ssm_inner"), "fan_in", dt),
        "in_proj_dt": ParamDecl((D, nh), ("embed", "ssm_heads"), "fan_in", dt),
        "conv_w": ParamDecl((conv_dim, s.d_conv), ("ssm_inner", None), "fan_in", jnp.float32),
        "conv_b": ParamDecl((conv_dim,), ("ssm_inner",), "zeros", jnp.float32),
        "dt_bias": ParamDecl((nh,), ("ssm_heads",), f"uniform:{lo}:{hi}", jnp.float32),
        "A_log": ParamDecl((nh,), ("ssm_heads",), "uniform:0.0:2.77", jnp.float32),
        "D_skip": ParamDecl((nh,), ("ssm_heads",), "ones", jnp.float32),
        "gate_norm": norm_decl(di),
        "out_proj": ParamDecl((di, D), ("ssm_inner", "embed"), "fan_in", dt),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """Stable 'segment sum' producing the (..., s, s) lower-tri decay logits:
    out[..., i, j] = sum_{k=j+1..i} x[..., k] for j < i, -inf above diag."""
    s = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((s, s), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, L, H, P)  — already multiplied by nothing; dt applied inside
    dt: jax.Array,  # (B, L, H) fp32, post-softplus
    A: jax.Array,  # (H,) fp32 (negative)
    Bm: jax.Array,  # (B, L, G, N)
    Cm: jax.Array,  # (B, L, G, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    rep = h // g

    # §Perf M2: einsum inputs in the ACTIVATION dtype (bf16 in production,
    # fp32 in tests) with fp32 accumulation; decay math stays fp32. This is
    # the same precision policy as the official SSD GPU kernel.
    cd = x.dtype
    dA = dt * A  # (B,L,H) fp32
    xdt = (x.astype(jnp.float32) * dt[..., None]).astype(cd)  # (B,L,H,P)

    def c_(t, feat_dims):  # reshape to chunks
        return t.reshape((b, nc, chunk) + feat_dims)

    x_c = c_(xdt, (h, p))
    dA_c = c_(dA, (h,)).transpose(0, 3, 1, 2)  # (B,H,nc,cs) fp32
    B_c = jnp.repeat(c_(Bm.astype(cd), (g, n)), rep, axis=3)  # (B,nc,cs,H,N)
    C_c = jnp.repeat(c_(Cm.astype(cd), (g, n)), rep, axis=3)

    # ---- intra-chunk (diagonal blocks): quadratic attention-like term ----
    L = jnp.exp(_segsum(dA_c)).astype(cd)  # (B,H,nc,cs,cs)
    Y_diag = jnp.einsum(
        "bcihn,bcjhn,bhcij,bcjhp->bcihp", C_c, B_c, L, x_c,
        preferred_element_type=jnp.float32,
    )

    # ---- chunk states and inter-chunk recurrence ----
    dA_cum = jnp.cumsum(dA_c, axis=-1)  # (B,H,nc,cs) fp32
    decay_to_end = jnp.exp(dA_cum[..., -1:] - dA_cum).astype(cd)  # (B,H,nc,cs)
    states = jnp.einsum(
        "bcjhn,bhcj,bcjhp->bchpn", B_c, decay_to_end, x_c,
        preferred_element_type=jnp.float32,
    )
    chunk_decay = jnp.exp(dA_cum[..., -1])  # (B,H,nc) fp32

    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )

    def step(carry, xs):
        st, dec = xs  # st: (B,H,P,N), dec: (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    final_state, prev_states = jax.lax.scan(
        step,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # ---- inter-chunk contribution ----
    state_decay = jnp.exp(dA_cum).astype(cd)  # decay chunk-start -> i
    Y_off = jnp.einsum(
        "bcihn,bchpn,bhci->bcihp", C_c, prev_states.astype(cd), state_decay,
        preferred_element_type=jnp.float32,
    )

    y = (Y_diag + Y_off).reshape(b, l, h, p)
    return y, final_state


def ssm_apply(
    cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    params,
    x: jax.Array,
    cache: Optional[Dict[str, jax.Array]] = None,
    return_state: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B,S,D). cache => single-token recurrent decode.
    cache = {'conv': (B, d_conv-1, conv_dim), 'state': (B,H,P,N)}."""
    s = cfg.ssm
    assert s is not None
    B_, S, D = x.shape
    di, nh, ng, dn = s.d_inner(D), s.nheads(D), s.ngroups, s.d_state
    hp = s.headdim
    conv_dim = di + 2 * ng * dn

    z = jnp.einsum("bsd,de->bse", x, params["in_proj_z"])
    xBC = jnp.einsum("bsd,de->bse", x, params["in_proj_x"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["in_proj_dt"]).astype(jnp.float32)
    if plan is not None:
        z = plan.constrain(z, "batch", None, "ssm_inner")
        xBC = plan.constrain(xBC, "batch", None, "ssm_inner")

    A = -jnp.exp(params["A_log"])
    dt = jax.nn.softplus(dt_raw + params["dt_bias"])

    if cache is None:
        # causal depthwise conv over the sequence
        w = params["conv_w"].astype(x.dtype)  # (conv_dim, k)
        pad = s.d_conv - 1
        xp = jnp.pad(xBC, ((0, 0), (pad, 0), (0, 0)))
        conv = sum(
            xp[:, i : i + S, :] * w[:, i] for i in range(s.d_conv)
        ) + params["conv_b"].astype(x.dtype)
        # activation-dtype silu (§Perf M1): fp32 here costs 2 full (B,S,conv)
        # round-trips per layer; bf16 sigmoid is well-conditioned.
        xBC = jax.nn.silu(conv)
        xs = xBC[..., :di].reshape(B_, S, nh, hp)
        Bm = xBC[..., di : di + ng * dn].reshape(B_, S, ng, dn)
        Cm = xBC[..., di + ng * dn :].reshape(B_, S, ng, dn)
        y, final_state = ssd_chunked(xs, dt, A, Bm, Cm, min(s.chunk_size, S))
        new_cache = None
        if return_state:
            # conv tail: last (d_conv-1) PRE-activation conv inputs
            tail = xp[:, S : S + pad, :] if pad else xp[:, :0, :]
            new_cache = {"conv": tail, "state": final_state}
    else:
        assert S == 1
        # conv ring: cache['conv'] holds the last (d_conv-1) xBC rows
        conv_buf = jnp.concatenate([cache["conv"], xBC], axis=1)  # (B, k, conv_dim)
        w = params["conv_w"].astype(jnp.float32)  # (conv_dim, k)
        conv = jnp.einsum("bkc,ck->bc", conv_buf.astype(jnp.float32), w) + params["conv_b"]
        xBC_t = jax.nn.silu(conv).astype(x.dtype)  # (B, conv_dim)
        xs = xBC_t[:, :di].reshape(B_, nh, hp).astype(jnp.float32)
        Bm = xBC_t[:, di : di + ng * dn].reshape(B_, ng, dn).astype(jnp.float32)
        Cm = xBC_t[:, di + ng * dn :].reshape(B_, ng, dn).astype(jnp.float32)
        rep = nh // ng
        Bh = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
        Ch = jnp.repeat(Cm, rep, axis=1)
        dt1 = dt[:, 0]  # (B,H)
        dA = jnp.exp(dt1 * A)  # (B,H)
        state = cache["state"].astype(jnp.float32)
        state = state * dA[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xs * dt1[..., None], Bh
        )
        y = jnp.einsum("bhpn,bhn->bhp", state, Ch)[:, None]  # (B,1,H,P)
        xs = xs[:, None]  # align shapes with train path for skip term
        new_cache = {"conv": conv_buf[:, 1:], "state": state}

    if cache is None:
        y = y + params["D_skip"][None, None, :, None] * xs.astype(jnp.float32) * 1.0
    else:
        y = y + params["D_skip"][None, None, :, None] * xs
    y = y.reshape(B_, S, di).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z)); gate in activation dtype (M1)
    y = y * jax.nn.silu(z)
    y = norm_apply(params["gate_norm"], y, "rmsnorm", cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, new_cache


def ssm_cache_decl(cfg: ModelConfig, batch: int) -> Dict[str, ParamDecl]:
    s = cfg.ssm
    assert s is not None
    D = cfg.d_model
    di, nh, ng, dn = s.d_inner(D), s.nheads(D), s.ngroups, s.d_state
    conv_dim = di + 2 * ng * dn
    return {
        "conv": ParamDecl(
            (batch, s.d_conv - 1, conv_dim), ("batch", None, "ssm_inner"), "zeros",
            jnp.dtype(cfg.dtype)
        ),
        "state": ParamDecl(
            (batch, nh, s.headdim, dn), ("batch", "ssm_heads", None, None), "zeros", jnp.float32
        ),
    }
