from repro.sharding.rules import (  # noqa: F401
    FoldingPlan,
    ParamDecl,
    init_from_decls,
    shardings_from_decls,
    specs_from_decls,
)
