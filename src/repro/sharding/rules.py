"""Logical-axis sharding rules + MoE Parallel Folding.

The paper (§3.2) decouples the parallel mapping of the Attention part
(TP x CP x DP x PP) from the MoE part (ETP x EP x EDP x PP) of each block so
that the communication-heavy groups of each part fold into the
high-bandwidth domain. On TPU we express this as a *rule table*: every
tensor dim carries a logical axis name, and the :class:`FoldingPlan` resolves
each name to mesh axes with divisibility-aware fallback. The same physical
mesh axis ('model') therefore plays

* tensor-parallel for attention tensors ('heads' -> model),
* context-parallel for attention activations when heads don't divide the
  axis ('attn_seq' -> model),
* expert-parallel for MoE tensors ('expert' -> model) when the expert count
  divides, expert-tensor-parallel otherwise ('expert_ff' -> model),

which is exactly the folding idea: attention and MoE communication both live
on the fast axis, with different logical roles per layer region.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Ordered candidate mesh-axis tuples per logical axis. ``None`` = replicated.
# Resolution picks the first candidate whose axes (a) all exist in the mesh,
# (b) are not already used by another dim of the same tensor, and (c) whose
# total size divides the dim.
RULES: Dict[str, Tuple[Optional[Tuple[str, ...]], ...]] = {
    "batch": (("pod", "data"), ("data",), None),
    # activation batch for the non-MoE (attention) part: on the paper-study
    # 3-D meshes the 'expert' axis folds into the attention DP group (MoE
    # Parallel Folding); the all-gather over 'expert' at the MoE boundary is
    # precisely Megatron's AllGather token dispatcher.
    "fold_batch": (
        ("pod", "data", "expert"), ("pod", "data"), ("data", "expert"),
        ("data",), None,
    ),
    "seq": (None,),
    # context-parallel attention activations (CP; folding for archs whose
    # head count does not divide the model axis)
    "attn_seq": (("model",), None),
    # decode-time KV cache sequence axis; prefers both axes for long_500k
    "cache_seq": (("data", "model"), ("model",), ("data",), None),
    "embed": (None,),
    "heads": (("model",), None),
    "kv_heads": (("model",), None),
    "head_dim": (None,),
    "ff": (("model",), None),
    "vocab": (("model",), None),
    "expert": (("expert",), ("model",), None),
    "expert_ff": (("model",), None),
    "layers": (None,),
    "ssm_heads": (("model",), None),
    "ssm_inner": (("model",), None),
    "ssm_group": (None,),
    "ssm_state": (None,),
    "lora": (None,),
    None: (None,),
}


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve_spec(
    mesh: Mesh,
    dims: Sequence[int],
    axes: Sequence[Optional[str]],
    overrides: Optional[Dict[str, Tuple[Optional[Tuple[str, ...]], ...]]] = None,
) -> P:
    """Resolve logical axes -> PartitionSpec with divisibility fallback."""
    assert len(dims) == len(axes), (dims, axes)
    rules = dict(RULES)
    if overrides:
        rules.update(overrides)
    used: set = set()
    out = []
    for dim, name in zip(dims, axes):
        choice: Optional[Tuple[str, ...]] = None
        for cand in rules.get(name, (None,)):
            if cand is None:
                choice = None
                break
            if not all(a in mesh.shape for a in cand):
                continue
            if any(a in used for a in cand):
                continue
            if dim % _axis_size(mesh, cand) != 0:
                continue
            choice = cand
            break
        if choice is None:
            out.append(None)
        else:
            used.update(choice)
            out.append(choice if len(choice) > 1 else choice[0])
    return P(*out)


@dataclasses.dataclass(frozen=True)
class FoldingPlan:
    """Per-(config, mesh) resolved parallel layout — the folding decision.

    * ``attn_mode``: 'tp' (heads shard the model axis) or 'cp' (attention
      activations shard sequence over the model axis instead).
    * ``moe_mode``: 'ep' (experts shard the ep_axis) or 'etp' (expert FFN
      hidden dim shards the model axis).
    * ``ep_axis``: mesh axis playing expert-parallel ('expert' on the
      paper-study 3-D meshes, 'model' on the production 2-D mesh).
    """

    mesh: Mesh
    attn_mode: str
    moe_mode: str
    ep_axis: Optional[str]
    ep_size: int
    batch_axes: Tuple[str, ...]
    # FSDP/ZeRO-3: additionally shard every weight's largest free dim over
    # 'data' (for archs whose TP/EP-sharded weights alone exceed HBM).
    fsdp: bool = False

    @staticmethod
    def make(cfg: Any, mesh: Mesh) -> "FoldingPlan":
        model_size = mesh.shape.get("model", 1)
        heads = getattr(cfg, "num_heads", 0)
        attn_mode = "tp" if heads and heads % model_size == 0 else "cp"
        moe_mode, ep_axis, ep_size = "etp", None, 1
        if getattr(cfg, "moe", None) is not None:
            E = cfg.moe.num_experts
            if "expert" in mesh.shape and E % mesh.shape["expert"] == 0:
                moe_mode, ep_axis, ep_size = "ep", "expert", mesh.shape["expert"]
            elif E % model_size == 0:
                moe_mode, ep_axis, ep_size = "ep", "model", model_size
        batch_axes = tuple(
            a for a in ("pod", "data") if a in mesh.shape
        )
        return FoldingPlan(
            mesh, attn_mode, moe_mode, ep_axis, ep_size, batch_axes,
            fsdp=bool(getattr(cfg, "fsdp", False)),
        )

    # -- activation constraint helpers ------------------------------------
    def spec(self, dims: Sequence[int], *axes: Optional[str]) -> P:
        return resolve_spec(self.mesh, dims, axes)

    def constrain(self, x: jax.Array, *axes: Optional[str]) -> jax.Array:
        spec = resolve_spec(self.mesh, x.shape, axes)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def sharding(self, dims: Sequence[int], *axes: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, resolve_spec(self.mesh, dims, axes))


# ---------------------------------------------------------------------------
# PartitionSpec <-> JSON (checkpoint manifests record the spec each leaf was
# SAVED under; restore re-resolves specs for the TARGET mesh via the decl
# tables above, so the recorded spec is provenance, not a constraint).
# ---------------------------------------------------------------------------


def spec_to_json(spec: Optional[P]) -> Optional[list]:
    if spec is None:
        return None
    return [list(p) if isinstance(p, tuple) else p for p in spec]


def spec_from_json(obj: Optional[Sequence]) -> Optional[P]:
    if obj is None:
        return None
    return P(*[tuple(p) if isinstance(p, list) else p for p in obj])


# ---------------------------------------------------------------------------
# Parameter declarations: single source of truth for shape/init/sharding.
# ---------------------------------------------------------------------------

InitFn = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


def _fan_in_normal(scale: float = 1.0) -> InitFn:
    def init(key, shape, dtype):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return init


def _normal(std: float) -> InitFn:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return init


def _zeros(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def _ones(key, shape, dtype):
    return jnp.ones(shape, dtype)


INITS: Dict[str, Callable[..., InitFn]] = {
    "fan_in": _fan_in_normal,
    "normal": _normal,
}


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    """Declarative parameter: shape + logical axes + init + dtype."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"  # fan_in | normal:<std> | zeros | ones
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def init_fn(self) -> InitFn:
        if self.init == "zeros":
            return _zeros
        if self.init == "ones":
            return _ones
        if self.init.startswith("normal"):
            std = float(self.init.split(":")[1]) if ":" in self.init else 0.02
            return _normal(std)
        if self.init.startswith("uniform"):
            _, lo, hi = self.init.split(":")
            lo, hi = float(lo), float(hi)

            def init(key, shape, dtype):
                return jax.random.uniform(
                    key, shape, jnp.float32, lo, hi
                ).astype(dtype)

            return init
        return _fan_in_normal(1.0)


def _is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def _used_axes(parts) -> set:
    used = set()
    for p in parts:
        if p is None:
            continue
        used.update(p if isinstance(p, tuple) else (p,))
    return used


def fsdp_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh, axis: str = "data") -> P:
    """Add data(+pod) sharding to the largest free divisible dim (ZeRO-1/3).
    On the multi-pod mesh the 'pod' axis joins the group so optimizer/FSDP
    state scales with the full data-parallel world size."""
    cand = tuple(
        a for a in (("pod", axis) if "pod" in mesh.shape else (axis,))
        if a in mesh.shape and mesh.shape[a] > 1
    )
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = _used_axes(parts)
    cand = tuple(a for a in cand if a not in used)
    if not cand:
        return spec
    # try the joint (pod, data) group first, then progressively smaller
    for group in (cand,) + ((cand[-1:],) if len(cand) > 1 else ()):
        size = int(np.prod([mesh.shape[a] for a in group]))
        best, best_dim = -1, 0
        for i, (dim, p) in enumerate(zip(shape, parts)):
            if p is None and dim % size == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best >= 0:
            parts[best] = group if len(group) > 1 else group[0]
            return P(*parts)
    return spec


def _resolve_decl(d: ParamDecl, plan: "FoldingPlan", overrides=None) -> P:
    spec = resolve_spec(plan.mesh, d.shape, d.axes, overrides)
    if plan.fsdp and "layers" in d.axes:  # weights only, not caches/scalars
        spec = fsdp_spec(spec, d.shape, plan.mesh)
    return spec


def init_from_decls(decls, key: jax.Array):
    """Materialize a pytree of ParamDecl into concrete parameters."""
    leaves, treedef = jax.tree.flatten(decls, is_leaf=_is_decl)
    keys = jax.random.split(key, len(leaves))
    vals = [d.init_fn()(k, d.shape, d.dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_from_decls(decls):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), decls, is_leaf=_is_decl
    )


def specs_from_decls(decls, plan: FoldingPlan, overrides=None):
    return jax.tree.map(
        lambda d: _resolve_decl(d, plan, overrides), decls, is_leaf=_is_decl
    )


def shardings_from_decls(decls, plan: FoldingPlan, overrides=None):
    return jax.tree.map(
        lambda d: NamedSharding(plan.mesh, _resolve_decl(d, plan, overrides)),
        decls,
        is_leaf=_is_decl,
    )
