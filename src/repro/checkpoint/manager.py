"""Distributed checkpoint manager: async double-buffered saves, atomic
commit, keep-last-k retention, elastic restore.

Save protocol (crash-safe by construction):

1. **Blocked phase** (training thread): join any in-flight write (double
   buffering depth 1), then host-copy every leaf's locally-addressable
   replica-0 shards (``snapshot_leaf`` — immediate ``np.array`` copies, so
   the jitted step may donate the device buffers the moment we return).
2. **Overlapped phase** (writer thread): write shard files into a hidden
   ``.tmp-step_*`` directory, write the manifest LAST, then atomically
   ``os.replace`` the tmp dir to ``step_XXXXXXXX``. A crash at any point
   leaves either the previous committed checkpoints untouched or a tmp dir
   that :func:`latest_step` ignores and the next manager instance sweeps.
3. After commit, prune committed checkpoints beyond ``keep_last``.

The manager stores plain nested-dict trees (see ``train/state.py`` for the
TrainState <-> tree mapping); restore takes an optional ``target`` tree of
``NamedSharding`` (same structure) and reshards each leaf on load — save
under EP on the study mesh, resume under ETP on the production mesh.

**Integrity + supervised recovery** (see ``checkpoint/sharded.py`` for the
checksum format):

* restore verifies before trusting: the requested step must pass deep
  (CRC) validation; with no explicit step, restore walks newest -> oldest
  and returns the newest checkpoint that VERIFIES, warning about every
  corrupt step it skipped — a torn or bit-flipped latest costs one
  checkpoint interval, never a silently-garbage TrainState. If nothing
  verifies, :class:`~repro.resilience.recovery.CheckpointCorruptionError`
  lists every step tried and why it failed.
* retention counts only *verified* checkpoints toward ``keep_last``: a
  corrupt latest can never evict the last good one. Corrupt step dirs are
  only reclaimed once they are older than the oldest retained verified
  step.
* shard writes retry with exponential backoff inside ``write_leaf``; a
  fault that outlasts the retries fails the save loudly (surfaced on the
  next :meth:`CheckpointManager.wait`), leaving previous checkpoints
  intact.
"""
from __future__ import annotations

import os
import re
import shutil
import threading
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint.sharded import (
    MANIFEST,
    flatten_tree,
    read_manifest,
    read_tree,
    snapshot_leaf,
    verify_checkpoint,
    write_leaf,
    write_manifest,
)
from repro.resilience.recovery import (
    CheckpointCorruptionError,
    ShardCorruptionError,
)

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _step_dir(step: int) -> str:
    return f"step_{step:08d}"


def list_steps(directory: str) -> List[int]:
    """Committed checkpoint steps (dirs with a manifest), ascending."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, MANIFEST)):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def step_verifies(directory: str, step: int, deep: bool = False) -> bool:
    """True if the committed ``step`` passes checkpoint validation."""
    try:
        verify_checkpoint(os.path.join(directory, _step_dir(step)), deep=deep)
        return True
    except ShardCorruptionError:
        return False


def verified_steps(directory: str, deep: bool = False) -> List[int]:
    """Committed steps that pass validation, ascending."""
    return [s for s in list_steps(directory) if step_verifies(directory, s, deep)]


def latest_verified_step(directory: str, deep: bool = True) -> Optional[int]:
    for s in reversed(list_steps(directory)):
        if step_verifies(directory, s, deep):
            return s
    return None


def restore_tree(
    directory: str,
    step: Optional[int] = None,
    target: Optional[Any] = None,
    verify: bool = True,
) -> Tuple[Any, Dict[str, Any]]:
    """Load a committed checkpoint -> (nested-dict tree, manifest).

    ``target``: optional pytree of ``NamedSharding`` (same nested-dict
    structure, or a flat ``key -> sharding`` dict); leaves without a target
    come back as plain host-committed ``jnp`` arrays.

    With ``verify`` (default): an explicit ``step`` must pass deep (CRC)
    validation or :class:`CheckpointCorruptionError` is raised — a pinned
    restore never falls back silently. With ``step=None`` the newest
    checkpoint that verifies wins; corrupt newer steps are skipped with a
    warning naming the corruption.
    """
    steps = list_steps(directory)
    assert steps, f"no committed checkpoint under {directory}"
    if step is not None:
        path = os.path.join(directory, _step_dir(step))
        if verify:
            try:
                verify_checkpoint(path, deep=True)
            except ShardCorruptionError as e:
                raise CheckpointCorruptionError(
                    f"checkpoint step {step} under {directory} failed "
                    f"validation: {e}"
                ) from e
        manifest = read_manifest(path)
        return read_tree(path, manifest, target), manifest
    tried: List[str] = []
    for s in reversed(steps):
        path = os.path.join(directory, _step_dir(s))
        try:
            if verify:
                verify_checkpoint(path, deep=True)
            manifest = read_manifest(path)
            tree = read_tree(path, manifest, target)
        except (ShardCorruptionError, OSError, ValueError, KeyError) as e:
            tried.append(f"step {s}: {e}")
            continue
        if tried:
            warnings.warn(
                f"restored step {s} from {directory} after skipping "
                f"{len(tried)} corrupt newer checkpoint(s): "
                + "; ".join(tried),
                stacklevel=2,
            )
        return tree, manifest
    raise CheckpointCorruptionError(
        f"no checkpoint under {directory} passes validation — tried "
        + "; ".join(tried)
    )


class CheckpointManager:
    """Async, atomic, retained checkpoints for one run directory."""

    def __init__(self, directory: str, keep_last: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self.last_blocked_s = 0.0  # wall time the training thread spent in save()
        self.restore_fallbacks = 0  # corrupt steps skipped across restores
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # structural-verification cache for retention: committed step dirs
        # are immutable, EXCEPT when a rollback re-saves the same step —
        # _write invalidates that entry after its commit.
        self._verify_cache: Dict[int, bool] = {}
        os.makedirs(directory, exist_ok=True)
        self._sweep_tmp()  # startup sweep: debris from any crashed writer

    # -- internals ---------------------------------------------------------

    def _sweep_tmp(self):
        """Remove uncommitted tmp dirs left by a crashed writer."""
        for name in os.listdir(self.directory):
            if name.startswith(".tmp-"):
                shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)

    def _write(self, snaps, step: int, meta: Optional[Dict]):
        tmp = os.path.join(self.directory, f".tmp-{_step_dir(step)}-{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        leaves = {
            key: write_leaf(tmp, key, entry, shards)
            for key, (entry, shards) in snaps.items()
        }
        write_manifest(tmp, step, leaves, meta)  # manifest last = commit point
        final = os.path.join(self.directory, _step_dir(step))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._verify_cache.pop(step, None)  # rollback may re-save a step
        self._prune()

    def _step_verified(self, step: int) -> bool:
        # deep (CRC) verification: a torn-but-payload-sized or bit-flipped
        # checkpoint must never count toward retention. Cached — each step
        # is scrubbed once per manager, in the overlapped writer thread
        # right after its own commit.
        if step not in self._verify_cache:
            self._verify_cache[step] = step_verifies(
                self.directory, step, deep=True
            )
        return self._verify_cache[step]

    def _prune(self):
        """Retention over VERIFIED checkpoints only: keep the newest
        ``keep_last`` steps that pass deep (CRC) validation; a corrupt
        latest therefore never evicts the last good checkpoint. Corrupt
        dirs are reclaimed once older than the oldest retained verified
        step (newer ones are left for the restore fallback to skip and for
        forensics)."""
        steps = list_steps(self.directory)
        good = [s for s in steps if self._step_verified(s)]
        keep = set(good[-self.keep_last:]) if self.keep_last > 0 else set()
        if not keep:
            return  # nothing verified: delete nothing
        oldest_kept = min(keep)
        for s in steps:
            if s not in keep and s < oldest_kept:
                shutil.rmtree(os.path.join(self.directory, _step_dir(s)),
                              ignore_errors=True)
                self._verify_cache.pop(s, None)

    # -- public API --------------------------------------------------------

    def wait(self):
        """Join the in-flight write (if any); re-raise a writer failure."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(
        self,
        tree: Any,
        step: int,
        meta: Optional[Dict] = None,
        blocking: Optional[bool] = None,
    ):
        """Checkpoint ``tree`` (nested dict of arrays) as ``step``.

        Returns after the blocked phase; the file write overlaps the next
        training steps unless ``blocking``.
        """
        t0 = time.perf_counter()
        self.wait()
        flat = flatten_tree(tree)
        snaps = {key: snapshot_leaf(val) for key, val in flat.items()}
        block = self.async_save is False if blocking is None else blocking
        if block:
            self._write(snaps, step, meta)
        else:
            def run():
                try:
                    self._write(snaps, step, meta)
                except BaseException as e:  # noqa: BLE001 — surfaced via wait()
                    self._error = e

            self._thread = threading.Thread(
                target=run, name=f"ckpt-write-{step}", daemon=True
            )
            self._thread.start()
        self.last_blocked_s = time.perf_counter() - t0

    def restore(self, step: Optional[int] = None, target: Optional[Any] = None,
                verify: bool = True):
        """Verified restore (see :func:`restore_tree`); also sweeps writer
        debris, so a manager opened purely to restore cleans up after a
        crashed predecessor. Counts corrupt-step fallbacks in
        ``restore_fallbacks``."""
        self.wait()
        self._sweep_tmp()
        before = latest_step(self.directory)
        out = restore_tree(self.directory, step, target, verify=verify)
        if step is None and before is not None and out[1]["step"] != before:
            self.restore_fallbacks += len(
                [s for s in list_steps(self.directory) if s > out[1]["step"]]
            )
        return out
