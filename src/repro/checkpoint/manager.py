"""Distributed checkpoint manager: async double-buffered saves, atomic
commit, keep-last-k retention, elastic restore.

Save protocol (crash-safe by construction):

1. **Blocked phase** (training thread): join any in-flight write (double
   buffering depth 1), then host-copy every leaf's locally-addressable
   replica-0 shards (``snapshot_leaf`` — immediate ``np.array`` copies, so
   the jitted step may donate the device buffers the moment we return).
2. **Overlapped phase** (writer thread): write shard files into a hidden
   ``.tmp-step_*`` directory, write the manifest LAST, then atomically
   ``os.replace`` the tmp dir to ``step_XXXXXXXX``. A crash at any point
   leaves either the previous committed checkpoints untouched or a tmp dir
   that :func:`latest_step` ignores and the next manager instance sweeps.
3. After commit, prune committed checkpoints beyond ``keep_last``.

The manager stores plain nested-dict trees (see ``train/state.py`` for the
TrainState <-> tree mapping); restore takes an optional ``target`` tree of
``NamedSharding`` (same structure) and reshards each leaf on load — save
under EP on the study mesh, resume under ETP on the production mesh.
"""
from __future__ import annotations

import os
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint.sharded import (
    MANIFEST,
    flatten_tree,
    read_manifest,
    read_tree,
    snapshot_leaf,
    write_leaf,
    write_manifest,
)

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _step_dir(step: int) -> str:
    return f"step_{step:08d}"


def list_steps(directory: str) -> List[int]:
    """Committed checkpoint steps (dirs with a manifest), ascending."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, MANIFEST)):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_tree(
    directory: str,
    step: Optional[int] = None,
    target: Optional[Any] = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Load a committed checkpoint -> (nested-dict tree, manifest).

    ``target``: optional pytree of ``NamedSharding`` (same nested-dict
    structure, or a flat ``key -> sharding`` dict); leaves without a target
    come back as plain host-committed ``jnp`` arrays.
    """
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no committed checkpoint under {directory}"
    path = os.path.join(directory, _step_dir(step))
    manifest = read_manifest(path)
    return read_tree(path, manifest, target), manifest


class CheckpointManager:
    """Async, atomic, retained checkpoints for one run directory."""

    def __init__(self, directory: str, keep_last: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self.last_blocked_s = 0.0  # wall time the training thread spent in save()
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)
        self._sweep_tmp()

    # -- internals ---------------------------------------------------------

    def _sweep_tmp(self):
        """Remove uncommitted tmp dirs left by a crashed writer."""
        for name in os.listdir(self.directory):
            if name.startswith(".tmp-"):
                shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)

    def _write(self, snaps, step: int, meta: Optional[Dict]):
        tmp = os.path.join(self.directory, f".tmp-{_step_dir(step)}-{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        leaves = {
            key: write_leaf(tmp, key, entry, shards)
            for key, (entry, shards) in snaps.items()
        }
        write_manifest(tmp, step, leaves, meta)  # manifest last = commit point
        final = os.path.join(self.directory, _step_dir(step))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._prune()

    def _prune(self):
        steps = list_steps(self.directory)
        for s in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(os.path.join(self.directory, _step_dir(s)), ignore_errors=True)

    # -- public API --------------------------------------------------------

    def wait(self):
        """Join the in-flight write (if any); re-raise a writer failure."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(
        self,
        tree: Any,
        step: int,
        meta: Optional[Dict] = None,
        blocking: Optional[bool] = None,
    ):
        """Checkpoint ``tree`` (nested dict of arrays) as ``step``.

        Returns after the blocked phase; the file write overlaps the next
        training steps unless ``blocking``.
        """
        t0 = time.perf_counter()
        self.wait()
        flat = flatten_tree(tree)
        snaps = {key: snapshot_leaf(val) for key, val in flat.items()}
        block = self.async_save is False if blocking is None else blocking
        if block:
            self._write(snaps, step, meta)
        else:
            def run():
                try:
                    self._write(snaps, step, meta)
                except BaseException as e:  # noqa: BLE001 — surfaced via wait()
                    self._error = e

            self._thread = threading.Thread(
                target=run, name=f"ckpt-write-{step}", daemon=True
            )
            self._thread.start()
        self.last_blocked_s = time.perf_counter() - t0

    def restore(self, step: Optional[int] = None, target: Optional[Any] = None):
        self.wait()
        return restore_tree(self.directory, step, target)
