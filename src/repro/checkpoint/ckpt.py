"""Flat (single-directory) checkpoints + the online upcycle-on-load path
(paper §3.1: "the dense checkpoint is sharded based on the specified
parallel training configuration, and weights are upcycled independently on
each device").

``save_checkpoint``/``load_checkpoint`` keep the seed-era params-only API
(used by launchers, examples, and ``upcycle_on_load``) but now ride the
sharded per-leaf writer from :mod:`repro.checkpoint.sharded`: saves touch
only locally-addressable shards (no host gather) and record each leaf's
PartitionSpec; loads accept an optional ``target`` sharding tree to reshard
on read. Format-1 manifests (one whole-array ``.npy`` per leaf) remain
loadable — ``load_checkpoint`` dispatches on ``manifest["format"]``.

Full train-state checkpoints (params + optimizer + RNG + data stream) live
in step-numbered subdirectories managed by
:class:`repro.checkpoint.manager.CheckpointManager`; this module is the
params-only flat layout those launchers still emit at end of run.

``upcycle_on_load`` composes load + :func:`repro.core.upcycle.upcycle_params`
under a single jit whose ``out_shardings`` come from the *MoE* parallel
plan, so the expert expansion materializes directly in sharded form — the
JAX rendition of NeMo online upcycling. No gathered (unsharded) copy of the
expanded expert weights ever exists.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.checkpoint.sharded import (
    flatten_tree,
    read_tree,
    snapshot_leaf,
    unflatten_tree,
    write_leaf,
    write_manifest,
)
from repro.sharding.rules import FoldingPlan, shardings_from_decls


def save_checkpoint(path: str, params, step: int = 0, meta: Optional[Dict] = None) -> None:
    """Params-only flat checkpoint into ``path`` (manifest written last)."""
    os.makedirs(path, exist_ok=True)
    flat = flatten_tree(params)
    leaves = {}
    for key, val in flat.items():
        entry, shards = snapshot_leaf(val)
        leaves[key] = write_leaf(path, key, entry, shards)
    write_manifest(path, step, leaves, meta)


def _load_v1(path: str, manifest: Dict[str, Any]) -> Dict[str, Any]:
    flat = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(os.path.join(path, info["file"]))
        if info["dtype"] == "bfloat16":
            arr = jnp.asarray(arr.view(jnp.bfloat16))
        flat[key] = jnp.asarray(arr)
    return unflatten_tree(flat)


def load_checkpoint(path: str, target: Optional[Any] = None) -> Dict[str, Any]:
    """Load a flat checkpoint; handles both manifest formats.

    ``target``: optional pytree of ``NamedSharding`` matching the params
    structure — leaves then materialize directly in the target layout
    (format-2 checkpoints only read the covering shard slices).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("format", 1) < 2:
        params = _load_v1(path, manifest)
        if target is not None:
            params = jax.device_put(params, target)
        return params
    return read_tree(path, manifest, target)


def upcycle_on_load(
    path: str,
    dense_cfg: ModelConfig,
    moe_cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    rng: jax.Array,
):
    """Load a dense checkpoint and upcycle it directly into the sharded MoE
    layout. Returns (moe_params, lowered_hlo_text) — the HLO is kept so
    tests/benchmarks can assert the expansion is collective-free."""
    from repro.core.upcycle import dense_input_shardings, upcycle_params
    from repro.models.model import model_decl

    fn = lambda dp: upcycle_params(dense_cfg, moe_cfg, dp, rng)
    if plan is None:
        return jax.jit(fn)(load_checkpoint(path)), None
    # shard the dense checkpoint per the *MoE* parallel config (paper §3.1):
    # the sharded loader materializes it in that layout directly
    in_sh = dense_input_shardings(dense_cfg, moe_cfg, plan)
    dense_params = load_checkpoint(path, target=in_sh)
    out_sh = shardings_from_decls(model_decl(moe_cfg), plan)
    jitted = jax.jit(fn, out_shardings=out_sh)
    lowered = jitted.lower(dense_params)
    hlo = lowered.compile().as_text()
    return jitted(dense_params), hlo
