"""Checkpointing: manifest + per-leaf .npy storage, and the online
upcycle-on-load path (paper §3.1: "the dense checkpoint is sharded based on
the specified parallel training configuration, and weights are upcycled
independently on each device").

``upcycle_on_load`` composes load + :func:`repro.core.upcycle.upcycle_params`
under a single jit whose ``out_shardings`` come from the *MoE* parallel
plan, so the expert expansion materializes directly in sharded form — the
JAX rendition of NeMo online upcycling. No gathered (unsharded) copy of the
expanded expert weights ever exists.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.sharding.rules import FoldingPlan, shardings_from_decls

_SEP = "::"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{_SEP}{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    tree: Dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save_checkpoint(path: str, params, step: int = 0, meta: Optional[Dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(params)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for key, val in flat.items():
        arr = np.asarray(jax.device_get(val))
        fname = key.replace(_SEP, "__") + ".npy"
        # bf16 has no numpy dtype; store as uint16 view + dtype tag
        if arr.dtype == jnp.bfloat16:
            np.save(os.path.join(path, fname), arr.view(np.uint16))
            manifest["leaves"][key] = {"file": fname, "dtype": "bfloat16"}
        else:
            np.save(os.path.join(path, fname), arr)
            manifest["leaves"][key] = {"file": fname, "dtype": str(arr.dtype)}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str) -> Dict[str, Any]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(os.path.join(path, info["file"]))
        if info["dtype"] == "bfloat16":
            arr = jnp.asarray(arr.view(jnp.bfloat16))
        flat[key] = jnp.asarray(arr)
    return _unflatten(flat)


def upcycle_on_load(
    path: str,
    dense_cfg: ModelConfig,
    moe_cfg: ModelConfig,
    plan: Optional[FoldingPlan],
    rng: jax.Array,
):
    """Load a dense checkpoint and upcycle it directly into the sharded MoE
    layout. Returns (moe_params, lowered_hlo_text) — the HLO is kept so
    tests/benchmarks can assert the expansion is collective-free."""
    from repro.core.upcycle import dense_input_shardings, upcycle_params
    from repro.models.model import model_decl

    dense_params = load_checkpoint(path)
    fn = lambda dp: upcycle_params(dense_cfg, moe_cfg, dp, rng)
    if plan is None:
        return jax.jit(fn)(dense_params), None
    # shard the dense checkpoint per the *MoE* parallel config (paper §3.1)
    in_sh = dense_input_shardings(dense_cfg, moe_cfg, plan)
    dense_params = jax.device_put(dense_params, in_sh)
    out_sh = shardings_from_decls(model_decl(moe_cfg), plan)
    jitted = jax.jit(fn, out_shardings=out_sh)
    lowered = jitted.lower(dense_params)
    hlo = lowered.compile().as_text()
    return jitted(dense_params), hlo
