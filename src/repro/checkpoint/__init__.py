from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint, upcycle_on_load  # noqa: F401
from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager,
    latest_step,
    latest_verified_step,
    list_steps,
    restore_tree,
    step_verifies,
    verified_steps,
)
from repro.checkpoint.sharded import verify_checkpoint  # noqa: F401
