from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint, upcycle_on_load  # noqa: F401
