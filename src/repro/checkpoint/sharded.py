"""Sharded per-leaf checkpoint I/O (manifest format 2).

Save path: every pytree leaf is written as its *locally-addressable* shards
only — one ``.npy`` file per (replica-0) device shard, with the shard's
index (per-dim [start, stop) ranges) and the leaf's PartitionSpec recorded
in the manifest. No full host-gather ever happens: the host copies exactly
the bytes its devices own, shard by shard. (The writer assumes a
single-controller host, as in this repo's fake-mesh runs; true multi-host
saves additionally need rank-tagged shard files and a manifest merge —
the manifest's per-shard index ranges are already the right metadata for
that.)

Restore path: :func:`read_leaf` reassembles a leaf either as a plain host
array (``sharding=None``) or *directly into a target sharding* via
``jax.make_array_from_callback`` — each target shard's callback reads only
the overlapping slices of the saved shard files (memory-mapped), so a
checkpoint saved under one mesh/FoldingPlan reshards onto a different one
(EP on the study mesh -> ETP on the production mesh) without materializing
a gathered copy.

Manifest leaf entry::

    {"dtype": "bfloat16", "shape": [512, 64], "spec": ["expert", null],
     "shards": [{"file": "k__0.npy", "index": [[0, 256], [0, 64]]}, ...]}

bf16 has no portable numpy storage dtype; shard files hold a uint16 view
plus the dtype tag (same convention as the format-1 checkpoints).

**Integrity:** every shard record carries a CRC32 over the exact bytes the
file stores (``crc32``) plus the payload size (``bytes``), written into the
manifest at save time. :func:`verify_checkpoint` re-validates a committed
step directory either *structurally* (manifest parses, every shard file
exists and is at least payload-sized — catches torn/truncated writes for
pennies) or *deeply* (full re-read + CRC — catches silent bit flips).
Restore paths verify before trusting (see ``checkpoint/manager.py``), and
shard I/O goes through bounded retry + exponential backoff
(:func:`repro.resilience.recovery.retry_io`). Fault-injection sites
``ckpt.shard_write`` / ``ckpt.shard_read`` thread the chaos harness through
this exact code path.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.resilience import faults
from repro.resilience.recovery import (
    InjectedFault,
    ShardCorruptionError,
    retry_io,
)
from repro.sharding.rules import spec_to_json

_SEP = "::"

MANIFEST = "manifest.json"
FORMAT = 2


def flatten_tree(tree, prefix: str = "") -> Dict[str, Any]:
    """Nested dicts -> flat ``a::b::c`` keys (leaves = anything non-dict)."""
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_tree(v, f"{prefix}{_SEP}{k}" if prefix else k))
    else:
        out[prefix] = tree
    return out


def unflatten_tree(flat: Dict[str, Any]):
    tree: Dict[str, Any] = {}
    for key, v in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _norm_index(index: Sequence[slice], shape: Sequence[int]) -> List[List[int]]:
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    return out


def snapshot_leaf(arr) -> Tuple[Dict[str, Any], List[Tuple[List[List[int]], np.ndarray]]]:
    """Host-copy a leaf's locally-addressable replica-0 shards.

    Returns ``(manifest_entry_sans_files, [(index, np_shard), ...])``. The
    numpy copies are made immediately (``np.array``), so the caller may hand
    the result to a background writer thread while the training step donates
    and overwrites the device buffers — the donation-safe host copy.
    """
    spec = None
    if isinstance(arr, jax.Array):
        sh = arr.sharding
        spec = spec_to_json(getattr(sh, "spec", None))
        shards = [
            (_norm_index(s.index, arr.shape), np.array(s.data))
            for s in arr.addressable_shards
            if s.replica_id == 0
        ]
        if not shards:  # pure replica on this host: keep one copy anyway
            s = arr.addressable_shards[0]
            shards = [(_norm_index(s.index, arr.shape), np.array(s.data))]
    else:
        a = np.asarray(arr)
        shards = [(_norm_index((slice(None),) * a.ndim, a.shape), np.array(a))]
    a0 = shards[0][1]
    dtype = "bfloat16" if a0.dtype == jnp.bfloat16 else str(a0.dtype)
    entry = {
        "dtype": dtype,
        "shape": list(np.asarray(arr).shape) if not isinstance(arr, jax.Array) else list(arr.shape),
        "spec": spec,
    }
    return entry, shards


def _crc(data: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(data).tobytes())


def _save_shard(path: str, fname: str, data: np.ndarray) -> None:
    """Write one shard file, then apply any injected write faults — the
    chaos harness corrupts the REAL bytes on disk, so validation is tested
    against exactly what a torn or flipped write would leave behind."""
    fp = os.path.join(path, fname)
    np.save(fp, data)
    for spec in faults.fire("ckpt.shard_write"):
        if spec.kind == "write_fail":
            if os.path.exists(fp):
                os.remove(fp)
            raise InjectedFault(f"injected shard write failure: {fname}")
        if spec.kind == "torn":
            faults.truncate_file(fp, spec.args.get("keep_fraction", 0.5))
        elif spec.kind == "bitflip":
            inj = faults.active()
            faults.flip_bit(fp, inj.rng if inj is not None else None)


def write_leaf(
    path: str,
    key: str,
    entry: Dict[str, Any],
    shards: List[Tuple[List[List[int]], np.ndarray]],
) -> Dict[str, Any]:
    """Write a snapshot's shard files under ``path``; returns the completed
    manifest entry (with file names + per-shard content checksums). Each
    shard write is retried with backoff, so a transient I/O failure costs a
    few milliseconds instead of the checkpoint."""
    base = key.replace(_SEP, "__")
    recs = []
    for i, (index, data) in enumerate(shards):
        fname = f"{base}__s{i}.npy" if len(shards) > 1 else f"{base}.npy"
        saved = data.view(np.uint16) if entry["dtype"] == "bfloat16" else data
        retry_io(_save_shard, path, fname, saved, what=f"ckpt write {fname}")
        recs.append({
            "file": fname, "index": index,
            "bytes": int(saved.nbytes), "crc32": _crc(saved),
        })
    return {**entry, "shards": recs}


def _load_shard(path: str, fname: str, dtype: str) -> np.ndarray:
    def load():
        for spec in faults.fire("ckpt.shard_read"):
            if spec.kind == "read_fail":
                raise InjectedFault(f"injected shard read failure: {fname}")
        return np.load(os.path.join(path, fname), mmap_mode="r")

    arr = retry_io(load, what=f"ckpt read {fname}")
    if dtype == "bfloat16":
        arr = arr.view(jnp.bfloat16)  # dtype view on the memmap — no copy
    return arr


def verify_shard(path: str, entry: Dict[str, Any], rec: Dict[str, Any]) -> None:
    """Deep-validate one shard file against its manifest record; raises
    :class:`ShardCorruptionError` naming the file and the mismatch."""
    fp = os.path.join(path, rec["file"])
    if not os.path.exists(fp):
        raise ShardCorruptionError(f"{fp}: shard file missing")
    try:
        arr = np.load(fp)  # full read, no mmap: the CRC covers every byte
    except Exception as e:  # noqa: BLE001 — any parse failure is corruption
        raise ShardCorruptionError(f"{fp}: unreadable shard ({e})") from e
    want_shape = tuple(hi - lo for lo, hi in rec["index"])
    if tuple(arr.shape) != want_shape:
        raise ShardCorruptionError(
            f"{fp}: shard shape {tuple(arr.shape)} != manifest index extent "
            f"{want_shape}"
        )
    if "crc32" in rec and _crc(arr) != rec["crc32"]:
        raise ShardCorruptionError(
            f"{fp}: content checksum mismatch (bit corruption) — expected "
            f"crc32 {rec['crc32']}, file hashes differently"
        )


def verify_checkpoint(path: str, deep: bool = True) -> Dict[str, Any]:
    """Validate a committed step directory; returns the manifest.

    ``deep=False`` is the structural pass (manifest parses, every shard
    file exists and holds at least its recorded payload bytes — catches
    torn writes without reading data). ``deep=True`` additionally re-reads
    every shard and checks its CRC32 (catches bit flips). Pre-checksum
    (PR-4 era) manifests verify structurally only — their records carry no
    ``crc32``/``bytes`` fields to check against.
    Raises :class:`ShardCorruptionError` on the first bad shard.
    """
    try:
        manifest = read_manifest(path)
    except Exception as e:  # noqa: BLE001
        raise ShardCorruptionError(f"{path}: unreadable manifest ({e})") from e
    for entry in manifest["leaves"].values():
        for rec in entry.get("shards", ()):
            fp = os.path.join(path, rec["file"])
            if not os.path.exists(fp):
                raise ShardCorruptionError(f"{fp}: shard file missing")
            if "bytes" in rec and os.path.getsize(fp) < rec["bytes"]:
                raise ShardCorruptionError(
                    f"{fp}: file holds {os.path.getsize(fp)} bytes < "
                    f"recorded payload {rec['bytes']} (torn write)"
                )
            if deep:
                verify_shard(path, entry, rec)
    return manifest


def _np_dtype(dtype: str):
    return jnp.bfloat16 if dtype == "bfloat16" else np.dtype(dtype)


def _assemble(
    path: str,
    entry: Dict[str, Any],
    block: Sequence[slice],
    cache: Dict[str, np.ndarray],
) -> np.ndarray:
    """Build the requested block of a leaf from the overlapping saved shards."""
    shape = entry["shape"]
    req = [
        (0 if s.start is None else s.start, d if s.stop is None else s.stop)
        for s, d in zip(block, shape)
    ]
    out = np.zeros([hi - lo for lo, hi in req], dtype=_np_dtype(entry["dtype"]))
    covered = 0
    for rec in entry["shards"]:
        inter = []
        for (rlo, rhi), (slo, shi) in zip(req, rec["index"]):
            lo, hi = max(rlo, slo), min(rhi, shi)
            if lo >= hi:
                inter = None
                break
            inter.append((lo, hi))
        if inter is None and len(shape) > 0:
            continue
        if rec["file"] not in cache:
            cache[rec["file"]] = _load_shard(path, rec["file"], entry["dtype"])
        data = cache[rec["file"]]
        if len(shape) == 0:
            return np.asarray(data).reshape(())
        dst = tuple(slice(lo - rlo, hi - rlo) for (lo, hi), (rlo, _) in zip(inter, req))
        src = tuple(slice(lo - slo, hi - slo) for (lo, hi), (slo, _) in zip(inter, rec["index"]))
        out[dst] = data[src]
        covered += int(np.prod([hi - lo for lo, hi in inter]))
    assert covered == out.size, (
        f"checkpoint shards do not cover requested block {req} "
        f"(covered {covered}/{out.size} elements)"
    )
    return out


def read_leaf(path: str, entry: Dict[str, Any], sharding=None) -> jax.Array:
    """Reassemble a saved leaf.

    ``sharding=None`` returns the full (host-assembled) array; with a target
    ``Sharding`` the leaf is built shard-by-shard via
    ``jax.make_array_from_callback`` so only the bytes each target device
    needs are read — the elastic-restore path.
    """
    shape = tuple(entry["shape"])
    cache: Dict[str, np.ndarray] = {}
    if sharding is None:
        full = _assemble(path, entry, (slice(None),) * len(shape), cache)
        return jnp.asarray(full)
    return jax.make_array_from_callback(
        shape, sharding, lambda idx: _assemble(path, entry, idx, cache)
    )


def read_tree(path: str, manifest: Dict[str, Any], target: Optional[Any] = None):
    """Manifest -> nested-dict tree; ``target`` (same structure, or flat) maps
    leaves to shardings for elastic restore. Shared by the flat-checkpoint
    loader and the step-dir manager."""
    flat_target = flatten_tree(target) if target is not None else {}
    flat = {
        key: read_leaf(path, entry, flat_target.get(key))
        for key, entry in manifest["leaves"].items()
    }
    return unflatten_tree(flat)


def write_manifest(path: str, step: int, leaves: Dict[str, Any], meta: Optional[Dict] = None):
    """Manifest is written LAST: a directory with a manifest is complete."""
    manifest = {"format": FORMAT, "step": step, "meta": meta or {}, "leaves": leaves}
    with open(os.path.join(path, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def read_manifest(path: str) -> Dict[str, Any]:
    with open(os.path.join(path, MANIFEST)) as f:
        return json.load(f)
