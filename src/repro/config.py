"""Configuration system for the upcycling framework.

Frozen dataclasses describing the model family, the MoE/upcycling recipe
(the paper's contribution), the parallel layout (MoE Parallel Folding), and
the training run. Every assigned architecture registers itself under
``repro.configs.<id>`` and is selectable via ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts recipe (paper §2, §3).

    ``capacity_factor=None`` means token-dropless training (infinite CF).
    Under the padded dispatchers the per-expert capacity then becomes the
    worst case (all tokens to one expert); prefer ``dispatcher="sorted"``
    for dropless runs — it is exactly dropless with no padding blow-up.
    ``router_type``:
      * ``mixtral`` — KeepTopK then Softmax over the k survivors (paper §5.2;
        preserves the dense function at upcycling init).
      * ``st``      — Softmax over all N experts then KeepTopK (keeps absolute
        router magnitudes; does NOT preserve the dense function for 1<k<N).
    ``dispatcher`` (token dispatch subsystem, ``repro.core.dispatch``):
      * ``allgather`` — global-view pjit, padded (E, C, D) layout with
        CF-bounded token dropping (Megatron-Core dispatcher #1, §3.2).
      * ``alltoall``  — shard_map + lax.all_to_all over the EP axis
        (dispatcher #2; preferred for small top-k, per the paper).
      * ``a2a_overlap`` — alltoall with the exchange split into double-
        buffered ppermute rounds that overlap expert compute (the serving
        decode schedule; same legality preconditions as alltoall).
      * ``sorted``    — argsort token permutation into a flat (T*k, D)
        expert-sorted buffer + per-expert group sizes (MegaBlocks-style);
        true dropless. Recommended with ``capacity_factor=None``.
    ``strict_dispatch``: raise instead of silently falling back to
    allgather when an EP dispatcher's preconditions fail — set by the
    mesh-mode serving engine, where the fallback forfeits expert
    parallelism without any visible signal.
    """

    num_experts: int = 8
    top_k: int = 2
    capacity_factor: Optional[float] = 4.0
    router_type: str = "mixtral"  # mixtral | st
    noisy_gating: bool = False  # Eq. (3) noisy top-k; off in paper main runs
    aux_loss_coef: float = 1e-2  # Switch-style load balance loss
    z_loss_coef: float = 1e-3  # router z-loss
    dispatcher: str = "allgather"  # allgather | alltoall | a2a_overlap | sorted
    strict_dispatch: bool = False  # error (not fallback) on illegal EP dispatch
    # dispatch-in-kernel: fold the sorted dispatcher's token gather and
    # gate-weighted combine into the grouped-GEMM prologue/epilogue (no
    # (N_pad, D) permuted buffer in HBM). Kernel path only; sorted-only.
    fused_dispatch: bool = False
    expert_d_ff: int = 0  # per-expert FFN hidden size (0 -> use model d_ff)
    moe_layer_freq: int = 1  # MoE every k-th layer (jamba: 2)
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    router_dtype: str = "float32"

    DISPATCHERS = ("allgather", "alltoall", "a2a_overlap", "sorted")

    def __post_init__(self):
        assert self.dispatcher in self.DISPATCHERS, self.dispatcher
        assert not (self.fused_dispatch and self.dispatcher != "sorted"), (
            "fused_dispatch folds the permutation into the grouped GEMM and "
            "only exists for the sorted dispatcher; got "
            f"dispatcher={self.dispatcher!r}"
        )

    def experts_ff(self, d_ff: int) -> int:
        return self.expert_d_ff or d_ff


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD (state-space duality) block parameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    ngroups: int = 8
    chunk_size: int = 256
    dt_min: float = 1e-3
    dt_max: float = 1e-1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def nheads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description. ``family`` controls the block stack:

    * ``dense``   — decoder-only transformer (GQA or MLA attention).
    * ``moe``     — decoder-only with MoE FFNs (``moe`` must be set).
    * ``ssm``     — attention-free Mamba-2 stack.
    * ``hybrid``  — interleaved Mamba/attention mixers (jamba), MoE optional.
    * ``encdec``  — encoder-decoder (seamless); encoder consumes stub
                    frame embeddings, decoder is a text decoder w/ cross-attn.
    * ``vlm``     — dense decoder that consumes a stub patch-embedding prefix.
    """

    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""  # citation for the config numbers

    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 500000.0
    qkv_bias: bool = False  # qwen2.5
    tie_embeddings: bool = False

    # Sub-quadratic attention variant for long-context decode (long_500k):
    # if set, attention is sliding-window with a ring-buffer KV cache.
    sliding_window: Optional[int] = None

    use_mla: bool = False
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # hybrid (jamba): mixer pattern per period, 'M'=mamba 'A'=attention.
    hybrid_pattern: str = ""
    # encdec
    num_encoder_layers: int = 0
    # vlm/audio stub frontend: number of prefix embedding positions the
    # frontend contributes (precomputed patch/frame embeddings).
    num_prefix_embeds: int = 0

    # numerics
    dtype: str = "bfloat16"
    # Serving-side low precision (core/quant.py): "int8" expert FFN weights
    # (per-expert per-output-channel scales, dequant fused into the Pallas
    # GEMMs) and "int8" KV pages (per-token scale sidecars in the page
    # pool). Inference-only — training and backward kernels stay `dtype`.
    quant_weights: str = "none"  # none | int8
    quant_kv: str = "none"  # none | int8
    # Megatron-style vocab padding so the vocab dim always shards.
    vocab_divisor: int = 2048

    # remat policy for the layer scan: 'none' | 'full' | 'dots'
    remat: str = "full"
    # FSDP/ZeRO-3: shard weights' largest free dim over 'data' as well
    # (jamba-398b / arctic-480b: TP/EP-sharded weights alone exceed HBM).
    fsdp: bool = False
    # gradient-accumulation microbatches for the train_4k shape (§Perf M4):
    # the Megatron microbatch knob — bounds per-microbatch activation memory
    # so the step fits HBM; grads accumulate in fp32 across microbatches.
    train_microbatches: int = 1

    QUANT_MODES = ("none", "int8")

    def __post_init__(self):
        assert self.quant_weights in self.QUANT_MODES, (
            f"quant_weights must be one of {self.QUANT_MODES}, "
            f"got {self.quant_weights!r}"
        )
        assert self.quant_kv in self.QUANT_MODES, (
            f"quant_kv must be one of {self.QUANT_MODES}, got {self.quant_kv!r}"
        )

    # ----- derived -----
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def padded_vocab(self) -> int:
        d = self.vocab_divisor
        return int(math.ceil(self.vocab_size / d) * d)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if long_500k decode is in-scope (sub-quadratic rule)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.family == "encdec":
            return False  # full-attn enc-dec; skip documented in DESIGN.md
        return self.sliding_window is not None or self.use_mla

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ----- parameter counting (Table 1 analog) -----
    def param_counts(self) -> Tuple[int, int]:
        """Returns (total_params, active_params) excluding vocab padding."""
        D, V, L = self.d_model, self.vocab_size, self.num_layers
        hd = self.head_dim_
        emb = V * D * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.use_mla and self.mla is not None:
                m = self.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = D * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk
                p += D * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                p += self.num_heads * m.v_head_dim * D
                return p
            p = D * self.num_heads * hd + 2 * D * self.num_kv_heads * hd
            p += self.num_heads * hd * D
            if self.qkv_bias:
                p += (self.num_heads + 2 * self.num_kv_heads) * hd
            return p

        def ffn_params(dff: int) -> int:
            return 3 * D * dff  # SwiGLU: gate, up, down

        def ssm_params() -> int:
            s = self.ssm or SSMConfig()
            di, nh, ng, ds = s.d_inner(D), s.nheads(D), s.ngroups, s.d_state
            p = D * (2 * di + 2 * ng * ds + nh)  # in_proj (z,x,B,C,dt)
            p += (di + 2 * ng * ds) * s.d_conv  # depthwise conv
            p += 2 * nh  # A_log, D
            p += di * D  # out_proj
            return p

        total = active = emb + D  # final norm
        per_layer_norms = 2 * D

        def moe_ffn(total_acc: int, active_acc: int) -> Tuple[int, int]:
            m = self.moe
            assert m is not None
            dff = m.experts_ff(self.d_ff)
            router = D * m.num_experts
            t = m.num_experts * ffn_params(dff) + router
            a = m.top_k * ffn_params(dff) + router
            if m.dense_residual:
                t += ffn_params(self.d_ff)
                a += ffn_params(self.d_ff)
            return total_acc + t, active_acc + a

        for i in range(L):
            total += per_layer_norms
            active += per_layer_norms
            if self.family == "ssm":
                total += ssm_params()
                active += ssm_params()
                continue
            if self.family == "hybrid" and self.hybrid_pattern:
                kind = self.hybrid_pattern[i % len(self.hybrid_pattern)]
                mix = ssm_params() if kind == "M" else attn_params()
            else:
                mix = attn_params()
            total += mix
            active += mix
            if self.moe is not None and (i % self.moe.moe_layer_freq) == (self.moe.moe_layer_freq - 1):
                total, active = moe_ffn(total, active)
            elif self.d_ff:
                total += ffn_params(self.d_ff)
                active += ffn_params(self.d_ff)
        if self.family == "encdec":
            # encoder layers: self-attn + ffn; decoder already counted above,
            # add cross-attention per decoder layer.
            for _ in range(self.num_encoder_layers):
                total += attn_params() + ffn_params(self.d_ff) + per_layer_norms
                active += attn_params() + ffn_params(self.d_ff) + per_layer_norms
            cross = L * (attn_params() + D)
            total += cross
            active += cross
        return total, active

    def flops_per_token(self, seq_len: int = 1) -> int:
        """Approximate forward FLOPs per token (2*active matmul params +
        attention score FLOPs). Used for Table 1 and MFU accounting."""
        _, active = self.param_counts()
        flops = 2 * active
        if self.family != "ssm":
            # causal attention: 2 * 2 * H * hd * S_avg per token
            n_attn = self.num_layers
            if self.family == "hybrid" and self.hybrid_pattern:
                per = self.hybrid_pattern
                n_attn = sum(1 for i in range(self.num_layers) if per[i % len(per)] == "A")
            flops += 4 * n_attn * self.num_heads * self.head_dim_ * (seq_len // 2)
        return flops


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Training run hyperparameters (paper §4.2 defaults, scaled)."""

    global_batch: int = 32
    seq_len: int = 512
    lr: float = 3e-5
    lr_min: float = 3e-7
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 1234
    zero1: bool = True  # shard optimizer state over the data axis
    # data blend (paper §4.1): two sources mixed 7:3
    blend_ratio: float = 0.7
    log_every: int = 10
    ckpt_every: int = 0  # 0 = off
    ckpt_dir: str = "/tmp/repro_ckpt"


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "mamba2-2.7b",
    "minicpm3-4b",
    "seamless-m4t-medium",
    "llama3.2-3b",
    "stablelm-1.6b",
    "jamba-1.5-large-398b",
    "qwen3-moe-30b-a3b",
    "llava-next-34b",
    "qwen2.5-14b",
    "arctic-480b",
    # paper's own models
    "llama3-8b",
    "llama3-e8t2",
)


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests:
    <=2 periods, d_model<=512, <=4 experts, tiny vocab."""
    kw: dict = dict(
        d_model=256,
        vocab_size=1024,
        vocab_divisor=128,
        num_prefix_embeds=16 if cfg.num_prefix_embeds else 0,
        fsdp=False,
    )
    if cfg.family == "ssm":
        kw.update(num_layers=2, ssm=dataclasses.replace(cfg.ssm, d_state=32, headdim=32, ngroups=4, chunk_size=16))
    elif cfg.family == "hybrid":
        # one full period of the mixer pattern (covers every slot kind)
        kw.update(
            num_layers=len(cfg.hybrid_pattern or "M"),
            ssm=dataclasses.replace(cfg.ssm, d_state=32, headdim=32, ngroups=4, chunk_size=16),
        )
    else:
        kw.update(num_layers=2)
    if cfg.family == "encdec":
        kw.update(num_encoder_layers=2)
    if cfg.num_heads:
        kw.update(num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 2) or 2, head_dim=64)
    if cfg.use_mla:
        kw.update(mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32))
    if cfg.d_ff:
        kw.update(d_ff=512)
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), expert_d_ff=0)
        if moe.dispatcher in ("alltoall", "a2a_overlap"):
            # smoke configs are single-host by definition: the EP-only
            # dispatchers have no plan to shard over and would trip strict
            # dispatch (REPRO_STRICT_DISPATCH=1 in tests/CI). 'allgather' is
            # what the fallback resolves to; EP-mesh tests opt back in
            # explicitly.
            moe = dataclasses.replace(moe, dispatcher="allgather")
        kw.update(moe=moe)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    return cfg.replace(name=cfg.name, **kw)


def with_dispatcher(cfg: ModelConfig, dispatcher: Optional[str]) -> ModelConfig:
    """Return ``cfg`` with its MoE token dispatcher overridden (no-op for
    dense configs or ``dispatcher=None``) — the launcher/Trainer/Engine hook
    for threading a ``--dispatcher`` choice without hand-editing the nested
    frozen config."""
    if dispatcher is None or cfg.moe is None:
        return cfg
    return cfg.replace(moe=dataclasses.replace(cfg.moe, dispatcher=dispatcher))


def get_config(arch: str) -> ModelConfig:
    """Load ``repro.configs.<arch>`` (dashes/dots -> underscores)."""
    mod_name = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.get_config()
    assert cfg.name == arch, (cfg.name, arch)
    return cfg
