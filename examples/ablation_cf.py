"""Capacity-factor ablation at example scale (paper Table 4 / Figure 2).

Pre-trains a small dense model once, upcycles it with CF in
{1, 2, dropless}, trains each briefly, and prints quality + dispatch-buffer
size + measured drop fraction. A lighter, narrated version of
``benchmarks/table4_cf.py``.

Run:  PYTHONPATH=src python examples/ablation_cf.py [--steps N]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, MoEConfig, TrainConfig
from repro.core.moe import capacity
from repro.core.upcycle import upcycle_config, upcycle_params
from repro.data.pipeline import make_train_iter
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()

    cfg = ModelConfig(name="abl-dense", family="dense", num_layers=2,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=512,
                      vocab_size=1024, vocab_divisor=128, remat="none")
    tcfg = TrainConfig(global_batch=8, seq_len=64, lr=1.5e-3, lr_min=1.5e-4,
                       warmup_steps=10, total_steps=args.steps, log_every=40, seed=0)
    data = lambda ss: make_train_iter(cfg.vocab_size, tcfg.seq_len,
                                      tcfg.global_batch, seed=0, sample_seed=ss)
    print(f"== pre-train dense ({args.steps} steps) ==")
    base = Trainer(cfg, tcfg, data_iter=data(1))
    base.run(args.steps)

    T = tcfg.global_batch * tcfg.seq_len
    print(f"\n{'CF':>9s} {'heldout_ce':>11s} {'ms/step':>8s} {'capacity':>9s} {'drop%':>6s}")
    for cf in (None, 2.0, 1.0):
        moe_cfg = upcycle_config(
            cfg, MoEConfig(num_experts=4, top_k=2, capacity_factor=cf),
            name=f"abl-e4t2-cf{cf}",
        )
        params = upcycle_params(cfg, moe_cfg, base.params, jax.random.PRNGKey(1))
        tr = Trainer(moe_cfg, tcfg, params=params, data_iter=data(2))
        t0 = time.perf_counter()
        tr.run(args.steps, log=lambda *_: None)
        dt = (time.perf_counter() - t0) / args.steps * 1e3
        # measured drop fraction on a probe batch
        from repro.core.moe import _dispatch_tables
        from repro.core.router import route
        from repro.models.layers import embed_apply

        b = {k: jnp.asarray(v) for k, v in next(data(3)).items()}
        x = embed_apply(tr.params["embed"], b["tokens"], jnp.float32).reshape(-1, cfg.d_model)
        r = jax.tree.map(lambda v: v[0], tr.params["stack"]["slot0"]["ffn"]["router"])
        gates, idx, _ = route(moe_cfg.moe, r, x)
        C = capacity(moe_cfg.moe, x.shape[0])
        _, sg = _dispatch_tables(idx, gates, 4, C)
        drop = 1 - float((np.asarray(sg) > 0).sum()) / (x.shape[0] * 2)
        label = "dropless" if cf is None else f"CF {cf}"
        print(f"{label:>9s} {tr.eval_loss(4):11.4f} {dt:8.1f} {C:9d} {100*drop:6.2f}")
    print("\nExpected (paper Table 4): CF1 fastest + only one dropping tokens;"
          "\ndropless no better than CF2 in quality.")


if __name__ == "__main__":
    main()
