"""Batched serving example: continuous-batching engine over a small MoE
model — prefill + slot-packed single-token decode with greedy sampling,
including requests longer than the batch (slot refill).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.config import ModelConfig, MoEConfig
from repro.models.model import model_decl
from repro.serving.engine import Request, ServingEngine
from repro.sharding.rules import init_from_decls


def main():
    cfg = ModelConfig(
        name="serve-moe", family="moe", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=0 or 256, vocab_size=1024, vocab_divisor=128,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0),
    )
    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=4, max_seq=64)

    rng = np.random.default_rng(0)
    requests = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                max_new_tokens=int(rng.integers(8, 24)))
        for i in range(10)  # 10 requests through 4 slots -> refill exercised
    ]
    t0 = time.perf_counter()
    outputs = engine.run(requests)
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outputs.values())
    print(f"served {len(requests)} requests / {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on CPU)")
    for rid in sorted(outputs)[:5]:
        print(f"  req {rid:2d} ({len(outputs[rid])} toks): {outputs[rid][:10]}...")


if __name__ == "__main__":
    main()
