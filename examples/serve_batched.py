"""Batched serving example: continuous-batching engine over a small MoE
model, run with BOTH cache backends:

* ``ring``  — dense ring-buffer KV, fused per-request prefill;
* ``paged`` — block-table page pool with chunked prefill, free-page
  admission, and preemption-by-recompute (vLLM-style).

Greedy decode is token-for-token identical across the two (asserted below);
the paged run reports how few KV bytes it actually pinned.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.config import ModelConfig, MoEConfig
from repro.models.model import model_decl
from repro.serving.engine import Request, ServingEngine
from repro.sharding.rules import init_from_decls


def make_requests(cfg, n=10):
    rng = np.random.default_rng(0)
    return [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, int(rng.integers(6, 40))).astype(np.int32),
                max_new_tokens=int(rng.integers(8, 24)))
        for i in range(n)  # 10 requests through 4 slots -> refill exercised
    ]


def main():
    cfg = ModelConfig(
        name="serve-moe", family="moe", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=0 or 256, vocab_size=1024, vocab_divisor=128,
        # dropless: ring==paged token parity is only guaranteed when no
        # tokens drop (finite-CF drop sets depend on dispatch-group size,
        # which chunked prefill changes)
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=None),
    )
    params = init_from_decls(model_decl(cfg), jax.random.PRNGKey(0))

    results = {}
    for mode, kw in [("ring", {}), ("paged", dict(page_size=8, prefill_chunk=16))]:
        engine = ServingEngine(cfg, params, max_batch=4, max_seq=64,
                               cache_mode=mode, **kw)
        requests = make_requests(cfg)
        t0 = time.perf_counter()
        outputs = engine.run(requests)
        dt = time.perf_counter() - t0
        total = sum(len(o) for o in outputs.values())
        kv = engine.kv_stats()
        print(f"[{mode:5s}] {len(requests)} requests / {total} tokens in "
              f"{dt:.2f}s ({total/dt:.1f} tok/s on CPU), "
              f"peak KV {kv['kv_bytes_peak']/1e6:.2f} MB")
        results[mode] = outputs
    assert results["ring"] == results["paged"], "engine parity violated"
    print("paged == ring, token for token")
    for rid in sorted(results["ring"])[:5]:
        o = results["ring"][rid]
        print(f"  req {rid:2d} ({len(o)} toks): {o[:10]}...")


if __name__ == "__main__":
    main()
