"""End-to-end training driver: pre-train a dense model, checkpoint it, then
ONLINE-upcycle the checkpoint to an 8-Expert Top-2 MoE (the paper's E8T2
recipe: CF=4, Mixtral router, cosine 3e-5->3e-7-style schedule scaled to
this budget) and train it for a few hundred steps on the 7:3 blend.

Default scale (~8M params) runs on a single CPU core in a few minutes; pass
--big for a ~100M-param model if you have the patience or a real chip.

Run:  PYTHONPATH=src python examples/train_upcycled.py [--big] [--steps N]
"""
import argparse

import jax

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.config import ModelConfig, MoEConfig, TrainConfig
from repro.core.upcycle import upcycle_config, upcycle_params
from repro.data.pipeline import make_train_iter
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true", help="~100M-param variant")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt", default="/tmp/repro_quick_dense")
    args = ap.parse_args()

    if args.big:
        dense_cfg = ModelConfig(
            name="upc-dense-100m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32000,
            vocab_divisor=1024, rope_theta=10000.0,
        )
        B, S = 8, 256
    else:
        dense_cfg = ModelConfig(
            name="upc-dense-8m", family="dense", num_layers=4, d_model=256,
            num_heads=4, num_kv_heads=2, d_ff=768, vocab_size=4096,
            vocab_divisor=512, rope_theta=10000.0, remat="none",
        )
        B, S = 8, 128
    t, _ = dense_cfg.param_counts()
    print(f"dense model: {t/1e6:.1f}M params")

    tcfg = TrainConfig(global_batch=B, seq_len=S, lr=6e-4, lr_min=6e-6,
                       warmup_steps=20, total_steps=args.steps, log_every=20, seed=0)
    it = make_train_iter(dense_cfg.vocab_size, S, B, seed=0)

    print(f"== phase 1: pre-train dense for {args.steps} steps ==")
    dense = Trainer(dense_cfg, tcfg, data_iter=it)
    dense.run(args.steps)
    save_checkpoint(args.ckpt, dense.params, step=args.steps)
    print(f"checkpoint saved to {args.ckpt}")

    print("\n== phase 2: online upcycle -> E8T2 (paper §4.2 recipe) ==")
    moe_cfg = upcycle_config(
        dense_cfg,
        MoEConfig(num_experts=8, top_k=2, capacity_factor=4.0, router_type="mixtral"),
    )
    dense_params = load_checkpoint(args.ckpt)
    moe_params = upcycle_params(dense_cfg, moe_cfg, dense_params, jax.random.PRNGKey(7))
    tm, am = moe_cfg.param_counts()
    print(f"E8T2: {tm/1e6:.1f}M total / {am/1e6:.1f}M active")

    print(f"\n== phase 3: train the upcycled MoE for {args.steps} steps ==")
    moe = Trainer(moe_cfg, tcfg, params=moe_params, data_iter=it)
    moe.run(args.steps)
    d_eval, m_eval = dense.eval_loss(4), moe.eval_loss(4)
    print(f"\nheld-out CE — dense: {d_eval:.4f}   upcycled E8T2: {m_eval:.4f}")
    print("(the MoE should match or beat the dense model: same warm start, more capacity)")


if __name__ == "__main__":
    main()
