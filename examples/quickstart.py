"""Quickstart: the paper's recipe end-to-end at laptop scale in ~1 minute.

1. Build a small dense llama-style model and train it briefly on the 7:3
   synthetic blend (standing in for the pre-trained dense checkpoint).
2. Upcycle it to a 4-Expert Top-2 MoE (paper §3.1): experts = copies of the
   FFN, router randomly initialized.
3. Verify the function-preserving init (paper §5.2 / Fig. 3): the MoE's
   logits equal the dense model's, because the Mixtral-type router's gates
   sum to 1 over identical experts.
4. Continue training the MoE and watch the loss drop below the dense line.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig, TrainConfig
from repro.core.upcycle import upcycle_config, upcycle_params
from repro.data.pipeline import make_train_iter
from repro.models.model import forward
from repro.train.trainer import Trainer


def main():
    dense_cfg = ModelConfig(
        name="quickstart-dense", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=1024, vocab_divisor=128,
    )
    tcfg = TrainConfig(global_batch=8, seq_len=64, lr=1e-3, lr_min=1e-4,
                       warmup_steps=10, total_steps=100, log_every=25, seed=0)
    it = make_train_iter(dense_cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=0)

    print("== 1. pre-train the dense model (stand-in for Llama 3-8B) ==")
    dense = Trainer(dense_cfg, tcfg, data_iter=it)
    dense.run(100)

    print("\n== 2. upcycle to a 4-Expert Top-2 MoE (paper §3.1) ==")
    moe_cfg = upcycle_config(
        dense_cfg, MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0,
                             router_type="mixtral"),
    )
    moe_params = upcycle_params(dense_cfg, moe_cfg, dense.params, jax.random.PRNGKey(1))
    td, ad = dense_cfg.param_counts()
    tm, am = moe_cfg.param_counts()
    print(f"dense: {td/1e6:.1f}M params -> MoE: {tm/1e6:.1f}M total / {am/1e6:.1f}M active")

    print("\n== 3. function-preserving init (paper Fig. 3) ==")
    batch = {k: jnp.asarray(v) for k, v in next(it).items()}
    ld, _ = jax.jit(lambda p, b: forward(dense_cfg, None, p, b))(dense.params, batch)
    lm, _ = jax.jit(lambda p, b: forward(moe_cfg, None, p, b))(moe_params, batch)
    diff = float(jnp.max(jnp.abs(ld - lm)))
    print(f"max |dense_logits - moe_logits| at init = {diff:.4f} (bf16 noise)")

    print("\n== 4. continue training the upcycled MoE ==")
    moe = Trainer(moe_cfg, tcfg, params=moe_params, data_iter=it)
    moe.run(100)
    print(f"\ndense held-out CE: {dense.eval_loss(4):.4f}")
    print(f"MoE   held-out CE: {moe.eval_loss(4):.4f}  (more capacity, same start)")


if __name__ == "__main__":
    main()
